"""Logical-axis sharding rules: params, activations, caches → mesh axes.

Mesh axes: optional "pod" (slow inter-pod links), "data" (DP / sequence
parallelism for long-context), "model" (TP / EP). The rules map every param
leaf by its role, inferred from the leaf path. Replicated-by-default keeps
the dry-run robust; hot leaves get explicit layouts:

  embed / head           : vocab → model
  attention wq/wk/wv     : out (heads) → model       [k, n] => (None, model)
  attention wo           : in  (heads) → model       => (model, None)
  mlp gate/up            : out (d_ff) → model
  mlp down               : in  (d_ff) → model
  moe experts            : expert axis → model (EP)
  mamba in_proj          : out (d_inner…) → model
  mamba out_proj         : in  (d_inner) → model
  quantized leaves       : qw/sw/la/lb follow the same axis as their w;
                           lb/la replicated when r is small (cheaper than
                           shard + all-gather of a skinny GEMM); adapter
                           factor pools alb/ala mirror lb/la with the
                           pool-slot axis replicated

Batch: ("pod", "data"); long-context decode (batch 1): KV cache seq → data.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis(mesh: Mesh, name: str) -> Optional[str]:
    return name if name in mesh.axis_names else None


def batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def _spec_for_path(path: str, ndim: int, mesh: Mesh, shard_lr: bool,
                   fsdp: bool = False, expert_2d: bool = False) -> P:
    model = _axis(mesh, "model")
    data = _axis(mesh, "data") if fsdp else None
    edata = _axis(mesh, "data") if expert_2d else None
    if model is None:
        return P()

    def last2(in_ax, out_ax):
        """Spec for a [..., in, out] leaf with leading stack dims replicated."""
        return P(*([None] * (ndim - 2) + [in_ax, out_ax]))

    def last1(ax):
        return P(*([None] * (ndim - 1) + [ax]))

    p = path
    # ---- moe stacked experts: expert axis → model (EP) --------------------
    if "/experts/" in p:
        leaf = p.rsplit("/", 1)[-1]
        per_expert_rank = {"qw": 2, "w": 2, "la": 2, "lb": 2,
                           "sw": 1, "m": 1, "b": 1}.get(leaf, 2)
        if leaf in ("gate", "up", "down"):
            per_expert_rank = 2           # fp stacked arrays keep their name
        spec = [None] * ndim
        e_ax = ndim - per_expert_rank - 1
        if 0 <= e_ax < ndim:
            spec[e_ax] = model
        if edata is not None and per_expert_rank == 2:
            # shard the d_ff dim over data too (huge-MoE serving: kimi-k2)
            is_down = "/down" in p
            name = p.rsplit("/", 1)[-1]
            two_d = name in ("qw", "w", "la", "lb")
            if name in ("gate", "up", "down"):
                two_d = True
            if two_d:
                # gate/up: [.., e, d, f] → f is out; down: [.., e, f, d] → f is in
                f_ax = ndim - 1 if not is_down else ndim - 2
                if name == "lb":        # [.., k, r] — k is the in dim
                    f_ax = ndim - 2 if is_down else None
                if name == "la":        # [.., r, n] — n is the out dim
                    f_ax = ndim - 1 if not is_down else None
                if name == "qw":        # [.., k/2, n]
                    f_ax = ndim - 1 if not is_down else ndim - 2
                if f_ax is not None and spec[f_ax] is None:
                    spec[f_ax] = edata
        return P(*spec)

    # ---- quantized leaves ------------------------------------------------
    if p.endswith("/qw") or p.endswith("/sw") or p.endswith("/la") \
            or p.endswith("/lb") or p.endswith("/m") \
            or p.endswith("/alb") or p.endswith("/ala"):
        base = p.rsplit("/", 1)[0]
        out_sharded = _col_sharded(base)
        in_sharded = _row_sharded(base)
        leaf = p.rsplit("/", 1)[1]
        if leaf == "qw":   # [k(/2), n]
            return last2(model if in_sharded else (data if out_sharded else None),
                         model if out_sharded else (data if in_sharded else None))
        if leaf == "sw":   # [n]
            return last1(model if out_sharded else None)
        if leaf == "m":    # [k]
            return last1(model if in_sharded else None)
        if leaf == "lb":   # [k, r]
            return last2(model if (in_sharded and shard_lr) else None, None)
        if leaf == "la":   # [r, n]
            return last2(None, model if (out_sharded and shard_lr) else None)
        # adapter factor pools mirror lb/la with the pool-slot axis (and any
        # leading stack dims) replicated — last2 already leaves them None
        if leaf == "alb":  # [P, k, ra]
            return last2(model if (in_sharded and shard_lr) else None, None)
        if leaf == "ala":  # [P, ra, n]
            return last2(None, model if (out_sharded and shard_lr) else None)

    # ---- embeddings ------------------------------------------------------
    if p.endswith("pos_embed"):
        return P(*([None] * ndim))
    if p.endswith("embed"):
        return P(model, data)
    if "/head/" in p or p.endswith("head/w"):
        return last2(data, model)

    # ---- fp linears ------------------------------------------------------
    if p.endswith("/w"):
        base = p[:-2]
        if _col_sharded(base):
            return last2(data, model)
        if _row_sharded(base):
            return last2(model, data)
        return P()
    if p.endswith("/b"):
        base = p[:-2]
        if _col_sharded(base):
            return last1(model)
        return P()

    # ---- mamba conv / norms / scalars: replicated ------------------------
    return P()


_COL = ("wq", "wk", "wv", "gate", "up", "in_proj")     # out-dim sharded
_ROW = ("wo", "down", "out_proj")                      # in-dim sharded


def _col_sharded(base: str) -> bool:
    return base.rsplit("/", 1)[-1] in _COL


def _row_sharded(base: str) -> bool:
    return base.rsplit("/", 1)[-1] in _ROW


def _paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out += _paths(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _paths(v, f"{prefix}/{i}")
    elif hasattr(tree, "_fields"):        # NamedTuple
        for k in tree._fields:
            out += _paths(getattr(tree, k), f"{prefix}/{k}")
    else:
        out.append((prefix, tree))
    return out


def _map_with_paths(fn, tree, prefix="", leaf_types=()):
    """Map ``fn(path, leaf)`` over a pytree; ``leaf_types`` instances are
    handed to ``fn`` whole instead of being recursed into."""
    if leaf_types and isinstance(tree, leaf_types):
        return fn(prefix, tree)
    if isinstance(tree, dict):
        return {k: _map_with_paths(fn, v, f"{prefix}/{k}", leaf_types)
                for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return type(tree)(*[_map_with_paths(fn, getattr(tree, k),
                                            f"{prefix}/{k}", leaf_types)
                            for k in tree._fields])
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_with_paths(fn, v, f"{prefix}/{i}", leaf_types)
                          for i, v in enumerate(tree))
    return fn(prefix, tree)


def _sanitize_sizes(spec: P, shape, sizes: dict) -> P:
    """Drop axes whose mesh size doesn't divide the dim (e.g. odd vocabs)."""
    out = []
    for i, ax in enumerate(tuple(spec)):
        if ax is None:
            out.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axs]))
        if shape[i] % total:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def _sanitize(spec: P, shape, mesh: Mesh) -> P:
    return _sanitize_sizes(spec, shape,
                           dict(zip(mesh.axis_names, mesh.devices.shape)))


def param_shardings(params, mesh: Mesh, shard_lr: bool = False,
                    fsdp: bool = False, expert_2d: bool = False):
    """NamedSharding tree matching ``params``."""
    def one(path, leaf):
        ndim = getattr(leaf, "ndim", 0)
        # scanned group stacks have a leading group axis -> replicated
        spec = _spec_for_path(path, ndim, mesh, shard_lr, fsdp, expert_2d)
        if len(spec) > ndim:
            spec = P(*spec[:ndim])
        spec = _sanitize(spec, getattr(leaf, "shape", ()), mesh)
        return NamedSharding(mesh, spec)
    return _map_with_paths(one, params)


def opt_shardings(opt_sds, param_shardings_tree):
    """Optimizer state shardings: mu/nu follow the params; step replicated."""
    from repro.train.optimizer import OptState
    mesh = jax.tree.leaves(
        param_shardings_tree,
        is_leaf=lambda x: isinstance(x, NamedSharding))[0].mesh
    return OptState(NamedSharding(mesh, P()),
                    param_shardings_tree, param_shardings_tree)


def data_sharding(mesh: Mesh, ndim: int = 2, *, seq_axis: Optional[int] = None,
                  batch_sharded: bool = True):
    """Sharding for [batch, seq, ...] inputs."""
    spec = [None] * ndim
    if batch_sharded:
        spec[0] = batch_axes(mesh)
    if seq_axis is not None:
        spec[seq_axis] = "data" if "data" in mesh.axis_names else None
        if spec[0] == ("pod", "data") or spec[0] == ("data",):
            spec[0] = "pod" if "pod" in mesh.axis_names else None
    return NamedSharding(mesh, P(*spec))


def _first_fit(spec: list, shape, dims, ax: str, size: int):
    """Put ``ax`` on the first still-free dim in ``dims`` that ``size``
    divides — the one fallback rule every cache branch shares."""
    for d in dims:
        if 0 <= d < len(spec) and spec[d] is None and shape[d] % size == 0:
            spec[d] = ax
            return


def cache_spec(path: str, shape, axis_sizes: dict, *,
               seq_to_data: bool = False) -> P:
    """Pure spec logic for one cache leaf (mesh-free, unit-testable).

    ``axis_sizes`` maps mesh axis name → size. Every branch (attention k/v,
    SSM conv/state) routes through the same :func:`_first_fit` +
    :func:`_sanitize_sizes` path, so a non-dividing dim (odd conv_dim, odd
    head count) degrades to replicated instead of producing an invalid
    sharding.
    """
    model = "model" if "model" in axis_sizes else None
    data = "data" if "data" in axis_sizes else None
    batch = tuple(a for a in ("pod", "data") if a in axis_sizes) or None
    model_size = axis_sizes.get("model", 1)
    ndim = len(shape)
    spec = [None] * ndim
    if path.endswith("/k") or path.endswith("/v"):
        # [*, b, cache_len, n_kv, hd]
        off = ndim - 4
        if not seq_to_data and batch is not None:
            spec[off + 0] = batch
        if seq_to_data and data is not None:
            spec[off + 1] = data
        if model is not None:
            # preference: kv-heads, then head_dim, then cache_len. Few-KV-
            # head archs (n_kv < TP) shard head_dim: the decode cache write
            # (dynamic-update-slice at a dynamic seq position) stays LOCAL;
            # attention contractions over hd psum across model. Sharding
            # cache_len instead makes XLA "involuntarily fully
            # rematerialize" (all-gather) the cache every layer — 310
            # GB/step on nemotron decode (EXPERIMENTS.md §Perf iteration 3).
            _first_fit(spec, shape, (off + 2, off + 3, off + 1),
                       model, model_size)
    elif path.endswith("/k_scale") or path.endswith("/v_scale"):
        # quantized-KV scale lanes [*, b, cache_len, n_kv]: follow the
        # batch/seq placement of their code lanes; model can only land on
        # kv-heads (there is no head_dim axis — when the codes shard hd the
        # tiny scales just stay replicated)
        off = ndim - 3
        if not seq_to_data and batch is not None:
            spec[off + 0] = batch
        if seq_to_data and data is not None:
            spec[off + 1] = data
        if model is not None:
            _first_fit(spec, shape, (off + 2,), model, model_size)
    elif path.endswith("/conv"):
        # [*, b, k-1, conv_dim]
        if not seq_to_data and batch is not None:
            spec[ndim - 3] = batch
        if model is not None:
            _first_fit(spec, shape, (ndim - 1,), model, model_size)
    elif path.endswith("/state"):
        # [*, b, nh, hd, ds]
        if not seq_to_data and batch is not None:
            spec[ndim - 4] = batch
        if model is not None:
            _first_fit(spec, shape, (ndim - 3,), model, model_size)
    # final guard for the batch axes (tuple sizes) and anything _first_fit
    # placed on a dim later found non-dividing
    return _sanitize_sizes(P(*spec), shape, axis_sizes)


def paged_pool_spec(path: str, shape, axis_sizes: dict, *,
                    seq_to_data: bool = False) -> P:
    """Spec for one paged block-pool leaf (mesh-free, unit-testable).

    k/v pools are ``[*, num_blocks, block_size, n_kv, hd]`` — there is no
    batch axis to shard (requests own *pages*, not rows), so the model
    axis first-fits over kv-heads, then head_dim, then block_size — the
    same preference order (and for the same reason: local decode writes)
    as the contiguous :func:`cache_spec`. ``seq_to_data`` spreads the
    *block* axis over data instead, the paged analogue of sharding cache
    length for SP long-context decode: pages of one request land on
    different data replicas.
    """
    ndim = len(shape)
    spec = [None] * ndim
    model = "model" if "model" in axis_sizes else None
    data = "data" if "data" in axis_sizes else None
    if (path.endswith("/k_scale") or path.endswith("/v_scale")) and ndim >= 3:
        # quantized-pool scale tiles [*, num_blocks, block_size, n_kv]:
        # same placement policy as the code pools, minus the head_dim
        # fallback (scales have none — they stay replicated when the codes
        # shard hd)
        off = ndim - 3
        if seq_to_data and data is not None:
            _first_fit(spec, shape, (off + 0,), data, axis_sizes["data"])
        if model is not None:
            _first_fit(spec, shape, (off + 2,), model,
                       axis_sizes.get("model", 1))
        return _sanitize_sizes(P(*spec), shape, axis_sizes)
    if not (path.endswith("/k") or path.endswith("/v")) or ndim < 4:
        return P()
    off = ndim - 4
    if seq_to_data and data is not None:
        _first_fit(spec, shape, (off + 0,), data, axis_sizes["data"])
    if model is not None:
        _first_fit(spec, shape, (off + 2, off + 3, off + 1),
                   model, axis_sizes.get("model", 1))
    return _sanitize_sizes(P(*spec), shape, axis_sizes)


def cache_shardings(caches, mesh: Mesh, *, seq_to_data: bool = False):
    """Shard KV caches: kv-heads → model; optionally cache seq → data (SP
    long-context decode). SSM caches: heads → model. Paged block pools
    route through :func:`paged_pool_spec` (no batch axis — pages are the
    unit of ownership, so only heads/head_dim/blocks are shardable)."""
    from repro.models.attention import PagedKVCache
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        if leaf is None:           # absent scale/qmax fields (bf16 caches)
            return None
        if isinstance(leaf, PagedKVCache):
            return PagedKVCache(*[
                None if getattr(leaf, f) is None else
                NamedSharding(mesh, paged_pool_spec(
                    f"{path}/{f}", getattr(leaf, f).shape, sizes,
                    seq_to_data=seq_to_data))
                for f in leaf._fields])
        spec = cache_spec(path, getattr(leaf, "shape", ()), sizes,
                          seq_to_data=seq_to_data)
        return NamedSharding(mesh, spec)

    return _map_with_paths(one, caches, leaf_types=(PagedKVCache,))
