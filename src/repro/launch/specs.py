"""Input templates (ShapeDtypeStruct) + shardings for every dry-run cell.

No device allocation happens here: params/opt/caches come from
``jax.eval_shape``; quantized serving params are synthesized structurally
(rank-64 compensation, int4-packed weights) — the calibration pass is an
offline one-time cost and irrelevant to the lowered serving program.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeCell, get_config, get_long_config
from repro.models import ModelConfig, init_caches, init_params
from repro.models.config import ModelConfig as MC
from repro.sharding import rules
from repro.train.optimizer import init_opt_state


SDS = jax.ShapeDtypeStruct


def params_template(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def quantized_template(params_sds, rank: int = 64, skip=("head", "router",
                                                         "encoder")):
    """Map fp linear leaves to W4A8+ASER serving leaves (structural only)."""
    def walk(node, path=""):
        if isinstance(node, dict):
            if "w" in node and not any(s in path for s in skip):
                w = node["w"]
                if w.ndim >= 2:
                    *lead, k, n = w.shape
                    lead = tuple(lead)
                    r = min(rank, k, n)
                    out = {
                        "qw": SDS(lead + (k // 2, n), jnp.int8),
                        "sw": SDS(lead + (n,), jnp.float32),
                        "m": SDS(lead + (k,), jnp.float32),
                        "lb": SDS(lead + (k, r), jnp.float32),
                        "la": SDS(lead + (r, n), jnp.float32),
                    }
                    if "b" in node:
                        out["b"] = node["b"]
                    return out
            out = {}
            for kk, v in node.items():
                if kk == "experts" and not any(s in path for s in skip):
                    out[kk] = _q_experts(v, rank)
                else:
                    out[kk] = walk(v, f"{path}/{kk}")
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{path}/{i}") for i, v in enumerate(node))
        return node

    return walk(params_sds)


def _q_experts(experts: dict, rank: int):
    out = {}
    for name, arr in experts.items():
        *lead, k, n = arr.shape
        lead = tuple(lead)
        r = min(rank, k, n)
        out[name] = {
            "qw": SDS(lead + (k // 2, n), jnp.int8),
            "sw": SDS(lead + (n,), jnp.float32),
            "m": SDS(lead + (k,), jnp.float32),
            "lb": SDS(lead + (k, r), jnp.float32),
            "la": SDS(lead + (r, n), jnp.float32),
        }
    return out


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    fn: Any
    args: tuple                 # ShapeDtypeStructs
    in_shardings: tuple
    arch: str = ""
    cell: str = ""
    donate: tuple = ()


def _token_sds(b, s):
    return SDS((b, s), jnp.int32)


def _frames_sds(cfg, b):
    return SDS((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)


def _mrope_sds(b, s):
    return SDS((3, b, s), jnp.int32)


def build_cell(arch: str, cell: ShapeCell, mesh: Mesh, *,
               fsdp_train: bool = True, expert_2d: Optional[bool] = None,
               quant_serve: bool = True, rank: int = 64,
               remat: bool = True, unroll: bool = True,
               opt_state_dtype: str = "float32",
               overrides: Optional[dict] = None) -> CellSpec:
    from .steps import make_decode_step, make_prefill_step, make_train_step_fn

    cfg = get_long_config(arch) if cell.name == "long_500k" else get_config(arch)
    # serving decode shouldn't pay remat; train uses it.
    # scan_unroll: XLA's cost analysis counts while-loop bodies once, so the
    # dry-run unrolls the layer scan to get true FLOPs/bytes/collectives.
    cfg = dataclasses.replace(cfg, remat=remat, scan_unroll=unroll)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if expert_2d is None:
        expert_2d = cfg.n_experts >= 128 or cfg.d_model >= 8192
    b, s = cell.global_batch, cell.seq_len

    p_sds = params_template(cfg)
    repl = NamedSharding(mesh, P())

    if cell.kind == "train":
        from repro.train.loop import TrainConfig
        from repro.train.optimizer import OptConfig
        fsdp = fsdp_train
        p_shard = rules.param_shardings(p_sds, mesh, fsdp=fsdp,
                                        expert_2d=expert_2d)
        tcfg = TrainConfig(opt=OptConfig(state_dtype=opt_state_dtype))
        sdt = jnp.bfloat16 if opt_state_dtype == "bfloat16" else jnp.float32
        opt_sds = jax.eval_shape(lambda p: init_opt_state(p, sdt), p_sds)
        opt_shard = rules.opt_shardings(opt_sds, p_shard)
        batch = {"tokens": _token_sds(b, s + 1)}
        batch_shard = {"tokens": rules.data_sharding(mesh, 2)}
        if cfg.family == "encdec":
            batch["frames"] = _frames_sds(cfg, b)
            batch_shard["frames"] = rules.data_sharding(mesh, 3)
        if cfg.mrope_sections:
            batch["mrope_positions"] = _mrope_sds(b, s)
            batch_shard["mrope_positions"] = NamedSharding(
                mesh, P(None, rules.batch_axes(mesh), None))
        fn = make_train_step_fn(cfg, tcfg)
        return CellSpec(fn, (p_sds, opt_sds, batch),
                        (p_shard, opt_shard, batch_shard), arch, cell.name)

    # ---- serving ----------------------------------------------------------
    q_sds = quantized_template(p_sds, rank=rank) if quant_serve else p_sds
    p_shard = rules.param_shardings(q_sds, mesh, fsdp=False,
                                    expert_2d=expert_2d)
    seq_to_data = cell.name == "long_500k"
    caches_sds = jax.eval_shape(lambda: init_caches(cfg, b, s))
    c_shard = rules.cache_shardings(caches_sds, mesh, seq_to_data=seq_to_data)

    if cell.kind == "prefill":
        fn = make_prefill_step(cfg)
        args = [q_sds, _token_sds(b, s), caches_sds]
        shards = [p_shard, rules.data_sharding(mesh, 2), c_shard]
        if cfg.family == "encdec":
            args.append(_frames_sds(cfg, b))
            shards.append(rules.data_sharding(mesh, 3))
        if cfg.mrope_sections:
            args.append(_mrope_sds(b, s))
            shards.append(NamedSharding(mesh, P(None, rules.batch_axes(mesh), None)))
        return CellSpec(fn, tuple(args), tuple(shards), arch, cell.name)

    # decode: one new token against a cache of length s
    fn = make_decode_step(cfg)
    tok = SDS((b,), jnp.int32)
    args = [q_sds, tok, caches_sds]
    tok_shard = NamedSharding(mesh, P(rules.batch_axes(mesh) if b > 1 else None))
    shards = [p_shard, tok_shard, c_shard]
    if cfg.mrope_sections:
        args.append(_mrope_sds(b, 1))
        shards.append(NamedSharding(
            mesh, P(None, rules.batch_axes(mesh) if b > 1 else None, None)))
    return CellSpec(fn, tuple(args), tuple(shards), arch, cell.name)
