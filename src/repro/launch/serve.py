"""Quantized serving driver: calibrate → ASER-quantize → batched generate.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
        --method aser_as --requests 4 --gen 16
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="aser_as",
                    help="registered recipe name, optionally with overrides "
                         "— e.g. aser_as, 'aser(base=gptq)' "
                         "(see repro.quant.registry.available())")
    ap.add_argument("--rank", type=int, default=None,
                    help="reconstruction rank (default 16 unless the method "
                         "string sets one inline)")
    ap.add_argument("--a-bits", type=int, default=None,
                    help="activation bits (default 8 unless set inline)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("bf16", "int8", "int4"),
                    help="KV-cache storage dtype (default bf16 unless the "
                         "method string sets kv_dtype inline)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernel path (interpret on CPU)")
    ap.add_argument("--autotune", default="off",
                    choices=("off", "cache", "force"),
                    help="measured-autotune mode: 'off' keeps modeled "
                         "kernel/plan decisions bit-for-bit, 'cache' "
                         "consults persisted measured winners "
                         "(~/.cache/repro/autotune_<backend>.json, falling "
                         "back to the checked-in baseline then the model), "
                         "'force' additionally measures on a cache miss at "
                         "engine build and persists the winner")
    ap.add_argument("--adapters", type=int, default=0,
                    help="serve N synthetic LoRA tenants multiplexed over "
                         "the one quantized base (requests round-robin "
                         "across them through the continuous-batching "
                         "scheduler); requires a quantized --method")
    ap.add_argument("--adapter-rank", type=int, default=8,
                    help="LoRA rank for the synthetic tenants")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="total per-request deadline in ms (enforced at "
                         "chunk boundaries; expired requests end TIMED_OUT "
                         "with partial tokens intact)")
    ap.add_argument("--ttft-ms", type=float, default=None,
                    help="first-token deadline in ms (queued requests past "
                         "it are shed as TIMED_OUT)")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bound the admission queue; submits over the cap "
                         "are load-shed with status REJECTED")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prefill into chunks of this many tokens, "
                         "interleaved with decode (0 = one-shot prefill); "
                         "cancel/TTFT deadlines are enforced at every "
                         "chunk boundary")
    ap.add_argument("--step-token-budget", type=int, default=0,
                    help="cap the tokens one scheduler step may spend "
                         "across prefill chunks + the decode chunk "
                         "(requires --prefill-chunk; 0 = unbudgeted)")
    args = ap.parse_args()

    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.models import init_params
    from repro.quant import calibrate, quantize_model, reduce_shared, registry
    from repro.runtime import RuntimeConfig
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32" if args.smoke else cfg.dtype)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    params = init_params(jax.random.PRNGKey(0), cfg)

    # flags act as defaults; inline overrides in --method win (passing both
    # a flag and the same key inline is an explicit registry error)
    overrides = {}
    if args.rank is not None:
        overrides["rank"] = args.rank
    elif "rank" not in args.method:
        overrides["rank"] = 16
    if args.a_bits is not None:
        overrides["a_bits"] = args.a_bits
    elif "a_bits" not in args.method:
        overrides["a_bits"] = 8
    if args.kv_dtype is not None:
        overrides["kv_dtype"] = args.kv_dtype
    if args.adapters > 0:
        overrides["adapter_rank"] = args.adapter_rank
        overrides["adapter_slots"] = args.adapters + 1   # + pinned base slot
    recipe = registry.resolve(args.method, **overrides)
    rt = dataclasses.replace(recipe.act.runtime(use_pallas=args.pallas),
                             autotune=args.autotune)
    if not recipe.is_noop:
        print(f"[serve] calibrating + quantizing with {args.method} "
              f"(W{recipe.base.bits}A{recipe.act.bits}, "
              f"rank {recipe.reconstructor.rank}, "
              f"KV {recipe.kv.dtype})")
        tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 32))
        tape = reduce_shared(tape, cfg)
        params = quantize_model(params, tape, recipe)

    scfg = recipe.kv.serve_config(max_len=args.prompt_len + args.gen)
    if args.prefill_chunk or args.step_token_budget:
        scfg = dataclasses.replace(scfg, prefill_chunk=args.prefill_chunk,
                                   step_token_budget=args.step_token_budget)

    if args.adapters > 0:
        if recipe.is_noop:
            raise SystemExit("--adapters needs a quantized --method "
                             "(adapter pools ride on quantized leaves)")
        from repro.serve.adapters import AdapterRegistry, install_pools
        from repro.serve.scheduler import Scheduler
        reg = AdapterRegistry.from_recipe(params, recipe)
        tenants = [reg.add(f"tenant-{i}") for i in range(args.adapters)]
        params = install_pools(params, slots=recipe.adapter.slots,
                               rank=recipe.adapter.rank)
        print(f"[serve] {args.adapters} tenants, rank "
              f"{recipe.adapter.rank} → pool "
              f"{reg.pool_bytes_per_adapter() / 1024:.1f} KiB/adapter")
        engine = Engine(params, cfg, scfg, rt=rt)
        sched = Scheduler(engine, adapters=reg, queue_cap=args.queue_cap,
                          ttft_ms=args.ttft_ms, deadline_ms=args.deadline_ms)
        prompts = corpus.sample(jnp.asarray(777), args.requests,
                                args.prompt_len)
        handles = []
        for i in range(args.requests):
            aid = tenants[i % args.adapters] if i % (args.adapters + 1) \
                else None                 # mixed traffic: base + tenants
            handles.append((aid, sched.submit(
                list(map(int, prompts[i])), args.gen, adapter_id=aid)))
        sched.run()
        print("[serve] generations (mixed adapter traffic):")
        for i, (aid, h) in enumerate(handles):
            toks, stats = h.poll(with_stats=True)
            print(f"  req {i} [{aid or 'base'}] "
                  f"({h.status.value}):", h.tokens)
        print(f"[serve] adapter pool: {sched.adapter_stats()}")
        print(f"[serve] lifecycle: {sched.lifecycle_stats()}")
        return

    # the recipe's KVQuantSpec picks the engine's cache storage
    engine = Engine(params, cfg, scfg, rt=rt)
    prompts = corpus.sample(jnp.asarray(777), args.requests, args.prompt_len)
    if (args.deadline_ms is not None or args.ttft_ms is not None
            or args.queue_cap is not None or args.prefill_chunk):
        # lifecycle controls live in the scheduler: route base traffic
        # through one instead of the static-batch generate() path
        from repro.serve.scheduler import Scheduler
        sched = Scheduler(engine, queue_cap=args.queue_cap,
                          ttft_ms=args.ttft_ms, deadline_ms=args.deadline_ms)
        handles = [sched.submit(list(map(int, prompts[i])), args.gen)
                   for i in range(args.requests)]
        sched.run()
        print("[serve] generations:")
        for i, h in enumerate(handles):
            print(f"  req {i} ({h.status.value}):", h.tokens)
        print(f"[serve] lifecycle: {sched.lifecycle_stats()}")
        return
    out = engine.generate(prompts, n_steps=args.gen)
    print("[serve] generations:")
    for i in range(args.requests):
        print("  req", i, ":", list(map(int, out[i])))


if __name__ == "__main__":
    main()
