"""Quantized serving driver: calibrate → ASER-quantize → batched generate.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
        --method aser_as --requests 4 --gen 16
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="aser_as",
                    choices=["fp16", "rtn", "llmint4", "smoothquant", "gptq",
                             "awq", "lorc", "l2qer", "aser", "aser_as"])
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernel path (interpret on CPU)")
    args = ap.parse_args()

    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.kernels import ops
    from repro.models import init_params
    from repro.quant import PTQConfig, calibrate, quantize_model, reduce_shared
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32" if args.smoke else cfg.dtype)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    params = init_params(jax.random.PRNGKey(0), cfg)

    if args.method != "fp16":
        print(f"[serve] calibrating + quantizing with {args.method} "
              f"(W4A{args.a_bits}, rank {args.rank})")
        tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 32))
        tape = reduce_shared(tape, cfg)
        params = quantize_model(params, tape,
                                PTQConfig(method=args.method, rank=args.rank))
        ops.set_act_bits(args.a_bits)
    ops.use_pallas(args.pallas)

    engine = Engine(params, cfg, ServeConfig(max_len=args.prompt_len + args.gen))
    prompts = corpus.sample(jnp.asarray(777), args.requests, args.prompt_len)
    out = engine.generate(prompts, n_steps=args.gen)
    print("[serve] generations:")
    for i in range(args.requests):
        print("  req", i, ":", list(map(int, out[i])))
    ops.use_pallas(False)
    ops.set_act_bits(8)


if __name__ == "__main__":
    main()
