"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --steps 100 \
        --data-par 2 --model-par 1 --batch 8 --seq 64 --smoke

On a real TPU fleet this binary runs once per host (jax.distributed
initializes from the TPU environment); on CPU it runs the same SPMD program
over ``--data-par × --model-par`` host devices. ``--smoke`` swaps in the
reduced config so the driver is exercisable anywhere.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    ndev = args.data_par * args.model_par
    if ndev > 1:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={ndev}")

    import jax
    import jax.numpy as jnp
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_params, param_count
    from repro.sharding import rules
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.optimizer import OptConfig, init_opt_state

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32" if args.smoke else cfg.dtype)
    mesh = make_local_mesh(args.data_par, args.model_par)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"[train] {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    p_sh = rules.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(init_opt_state(params),
                         rules.opt_shardings(init_opt_state(params), p_sh))

    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=10,
                                     total_steps=args.steps),
                       grad_accum=args.grad_accum)
    step_fn = make_train_step(cfg, tcfg)

    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
        if mgr.latest_step() is not None:
            start, st = mgr.restore_latest({"params": params, "opt": opt},
                                           shardings={"params": p_sh,
                                                      "opt": rules.opt_shardings(opt, p_sh)})
            params, opt = st["params"], st["opt"]
            print(f"[train] resumed from step {start}")

    with mesh:
        step_j = jax.jit(step_fn)
        for i in range(start, args.steps):
            toks = corpus.sample(jnp.asarray(i), args.batch, args.seq + 1)
            batch = {"tokens": jax.device_put(
                toks, rules.data_sharding(mesh, 2))}
            params, opt, m = step_j(params, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"[train] step {i:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e}", flush=True)
            if mgr and i and i % args.ckpt_every == 0:
                mgr.save(i, {"params": params, "opt": opt})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt})
        mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
