import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell, THREE compiles:
  1. full model, scan-over-layers  → memory_analysis (the "fits" proof) and
     the compile-success proof for the production program;
  2. probe with 1 repeating group, fully unrolled;
  3. probe with 2 repeating groups, fully unrolled.
XLA's HLO cost analysis counts while-loop bodies ONCE, so scanned stacks
under-report FLOPs/bytes/collectives. The stacks are layer-homogeneous, so
cost(G) = a + b·G exactly; probes (2) and (3) identify a and b and we report
cost(G_full). Encoder-decoder archs scale encoder layers with the same k
(whisper has equal encoder/decoder depth, so one slope suffices).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --cell train_4k

Writes one JSON record per cell to results/dryrun/<arch>_<cell>_<mesh>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.registry import (ARCH_IDS, ShapeCell, cells, get_config,
                                    get_long_config)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.roofline.analysis import (Roofline, collective_bytes,
                                     model_flops_estimate)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _compile(arch, cell, mesh, **kw):
    spec = build_cell(arch, cell, mesh, **kw)
    with mesh:
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings)
        lowered = jitted.lower(*spec.args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled):
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _probe_overrides(cfg, k: int):
    ov = {"n_layers": cfg.n_dense_layers + k * cfg.group_size}
    if cfg.family == "encdec":
        ov["n_encoder_layers"] = k
    return ov


def _extrapolate(c1, c2, g_full: int):
    """cost(G) = a + b·G from G=1,2 measurements."""
    def lin(v1, v2):
        b = v2 - v1
        a = v1 - b
        return max(a + b * g_full, 0.0)
    kinds = set(c1["coll"]) | set(c2["coll"])
    # sorted: set order is hash-seed dependent and this dict lands in the
    # results JSON — keep report diffs stable across processes
    coll = {kk: lin(c1["coll"].get(kk, 0.0), c2["coll"].get(kk, 0.0))
            for kk in sorted(kinds)}
    return {"flops": lin(c1["flops"], c2["flops"]),
            "bytes": lin(c1["bytes"], c2["bytes"]),
            "coll": coll}


def run_cell(arch: str, cell: ShapeCell, mesh_name: str, *,
             verbose: bool = True, out_dir: str = RESULTS_DIR,
             build_kwargs: dict | None = None, tag: str = ""):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    bk = dict(build_kwargs or {})
    cfg = get_long_config(arch) if cell.name == "long_500k" else get_config(arch)
    g_full = (cfg.n_layers - cfg.n_dense_layers) // cfg.group_size

    t0 = time.time()
    compiled_full = _compile(arch, cell, mesh, unroll=False, **bk)
    t_full = time.time() - t0
    mem = compiled_full.memory_analysis()

    t0 = time.time()
    base_ov = bk.pop("overrides", {})
    probes = {}
    for k in (1, 2):
        c = _compile(arch, cell, mesh, unroll=True,
                     overrides={**base_ov, **_probe_overrides(cfg, k)}, **bk)
        probes[k] = _cost_of(c)
    t_probe = time.time() - t0

    cost = _extrapolate(probes[1], probes[2], g_full)
    mf = model_flops_estimate(cfg, cell)
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes)
    roof = Roofline(arch=arch, cell=cell.name, mesh=mesh_name, chips=chips,
                    flops=cost["flops"], bytes_accessed=cost["bytes"],
                    coll_bytes=sum(cost["coll"].values()),
                    coll_breakdown=cost["coll"], model_flops=mf,
                    peak_mem_bytes=float(peak))
    rec = roof.to_dict()
    rec.update(t_compile_full_s=t_full, t_compile_probes_s=t_probe,
               arg_bytes=mem.argument_size_in_bytes,
               temp_bytes=mem.temp_size_in_bytes,
               out_bytes=mem.output_size_in_bytes,
               probe1=probes[1], probe2=probes[2], g_full=g_full)
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}_{cell.name}_{mesh_name}{('_' + tag) if tag else ''}"
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[OK] {arch:18s} {cell.name:12s} {mesh_name:6s} "
              f"mem/dev={rec['peak_mem_per_dev_gb']:.2f}GB "
              f"t_comp={rec['t_compute']:.4f}s t_mem={rec['t_memory']:.4f}s "
              f"t_coll={rec['t_collective']:.4f}s "
              f"bneck={rec['bottleneck'][:4]} "
              f"useful={rec['useful_flops_ratio']:.2f} "
              f"roofline={rec['roofline_fraction']:.3f} "
              f"(compile {t_full:.0f}s+{t_probe:.0f}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = args.arch.split(",") if args.arch else ARCH_IDS
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    failures = []
    for arch in archs:
        for cell in cells(arch):
            if args.cell and cell.name != args.cell:
                continue
            for mesh_name in meshes:
                path = os.path.join(args.out, f"{arch}_{cell.name}_{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    continue
                try:
                    run_cell(arch, cell, mesh_name, out_dir=args.out)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, cell.name, mesh_name, repr(e)))
                    print(f"[FAIL] {arch} {cell.name} {mesh_name}: {e}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
