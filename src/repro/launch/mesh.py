"""Mesh construction for the production fleet.

IMPORTANT: functions only — importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before importing anything).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across versions (axis_types grew in newer releases)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / elastic restore)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return _mesh((data, model), ("data", "model"))


def elastic_mesh(preferred=(("data", 16), ("model", 16))):
    """Build the largest mesh the surviving device set supports — node
    failures shrink the data axis first (model-parallel groups must stay
    complete, so the model axis is preserved when divisible)."""
    n = len(jax.devices())
    model = dict(preferred).get("model", 1)
    while model > 1 and n % model:
        model //= 2
    data = n // model
    return _mesh((data, model), ("data", "model"))
