"""Step functions lowered by the dry-run / drivers: train, prefill, decode."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, encode, forward, prepare_cross_caches
from repro.train.loop import TrainConfig, make_train_step


def make_prefill_step(cfg: ModelConfig):
    if cfg.family == "encdec":
        def prefill(params, tokens, caches, frames):
            enc_out = encode(params, cfg, frames)
            caches = prepare_cross_caches(params, cfg, enc_out, caches)
            logits, caches, _ = forward(params, cfg, tokens, caches=caches)
            return logits[:, -1], caches
        return prefill
    if cfg.mrope_sections:
        def prefill(params, tokens, caches, mrope_positions):
            logits, caches, _ = forward(params, cfg, tokens, caches=caches,
                                        mrope_positions=mrope_positions)
            return logits[:, -1], caches
        return prefill

    def prefill(params, tokens, caches):
        logits, caches, _ = forward(params, cfg, tokens, caches=caches)
        return logits[:, -1], caches
    return prefill


def make_decode_step(cfg: ModelConfig):
    if cfg.mrope_sections:
        def decode(params, tok, caches, mrope_positions):
            logits, caches, _ = forward(params, cfg, tok[:, None], caches=caches,
                                        mrope_positions=mrope_positions)
            return logits[:, 0], caches
        return decode

    def decode(params, tok, caches):
        logits, caches, _ = forward(params, cfg, tok[:, None], caches=caches)
        return logits[:, 0], caches
    return decode


def make_train_step_fn(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    tcfg = tcfg or TrainConfig()
    if cfg.family == "encdec":
        from repro.core.metrics import cross_entropy
        from repro.train.optimizer import adamw_update

        def step(params, opt_state, batch):
            def lf(p):
                enc_out = encode(p, cfg, batch["frames"])
                logits, _, aux = forward(p, cfg, batch["tokens"][:, :-1],
                                         train=True, encoder_out=enc_out)
                return cross_entropy(logits, batch["tokens"][:, 1:]) \
                    + tcfg.aux_weight * aux
            loss, grads = jax.value_and_grad(lf)(params)
            params, opt_state, m = adamw_update(tcfg.opt, params, grads, opt_state)
            return params, opt_state, dict(m, loss=loss)
        return step
    if cfg.mrope_sections:
        from repro.core.metrics import cross_entropy
        from repro.train.optimizer import adamw_update

        def step(params, opt_state, batch):
            def lf(p):
                logits, _, aux = forward(
                    p, cfg, batch["tokens"][:, :-1], train=True,
                    mrope_positions=batch["mrope_positions"])
                return cross_entropy(logits, batch["tokens"][:, 1:]) \
                    + tcfg.aux_weight * aux
            loss, grads = jax.value_and_grad(lf)(params)
            params, opt_state, m = adamw_update(tcfg.opt, params, grads, opt_state)
            return params, opt_state, dict(m, loss=loss)
        return step
    return make_train_step(cfg, tcfg)
