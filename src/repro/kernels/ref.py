"""Pure-jnp oracles for every Pallas kernel. Ground truth for tests.

Layouts (serving path):
  x        [m, k]   activations (bf16/f32)
  qw       [k//2, n] int8 — int4 pairs packed along k (low nibble = even k)
  sw       [n]      per-out-channel weight scale (f32)
  m_diag   [k]      ASER smoothing diagonal (f32; ones when A.S. off)
  lb       [k, r]   low-rank compensation (f32/bf16)
  la       [r, n]
Result:    [m, n]   f32
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import unpack_int4


def w4a8_linear_ref(x, qw, sw, m_diag, lb, la, *, a_bits: int = 8,
                    granularity: str = "per_token"):
    """Reference: smooth → per-token int quant → int matmul → dequant → + LR.

    ``qw`` is int4-packed ([k//2, n]) or plain int8 codes ([k, n]) — detected
    by shape against ``m_diag`` (the W8 setups store unpacked codes).
    ``granularity``: "per_token" (one scale per row of x, paper setup) or
    "per_tensor" (one scale for the whole activation block)."""
    x = x.astype(jnp.float32)
    x_s = x / m_diag[None, :]
    qmax = 2 ** (a_bits - 1) - 1
    amax = (jnp.max(jnp.abs(x_s), axis=1, keepdims=True)
            if granularity == "per_token"
            else jnp.max(jnp.abs(x_s)))
    sx = jnp.maximum(amax, 1e-8) / qmax
    xq = jnp.clip(jnp.round(x_s / sx), -qmax - 1, qmax).astype(jnp.int8)

    if qw.shape[0] * 2 == m_diag.shape[0]:
        w_codes = unpack_int4(qw.T).T        # [k, n] int8 in [-8, 7]
    else:
        w_codes = qw                          # already int8 codes [k, n]
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), w_codes.astype(jnp.int32),
        (((1,), (0,)), ((), ())))            # int32 [m, n]
    y = acc.astype(jnp.float32) * sx * sw[None, :]
    if lb.shape[-1]:          # rank 0 = no compensation: skip the epilogue
        y = y + (x_s @ lb.astype(jnp.float32)) @ la.astype(jnp.float32)
    return y


def act_quant_ref(x, m_diag, *, bits: int = 8):
    """Per-token symmetric quant of smoothed activations.

    Returns (codes int8 [m, k], scale f32 [m, 1])."""
    x = x.astype(jnp.float32) / m_diag[None, :]
    qmax = 2 ** (bits - 1) - 1
    sx = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True), 1e-8) / qmax
    codes = jnp.clip(jnp.round(x / sx), -qmax - 1, qmax).astype(jnp.int8)
    return codes, sx


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_cap: float = 0.0, kv_len=None):
    """Dense softmax attention oracle. q: [b, sq, h, d]; k/v: [b, skv, hkv, d]."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    n_rep = h // k.shape[2]
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
