"""Blocked causal flash attention (Pallas), the serve-prefill hot path.

Grid: (batch*heads, q_tiles). The KV loop runs inside the kernel body with
online-softmax accumulators in VMEM scratch; causal tiles beyond the query
block are never visited. Supports sliding windows and gemma2 logit caps.
GQA is handled by the wrapper (kv head index = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, *, bq: int, bkv: int, sq: int,
            skv: int, causal: bool, window: int, logit_cap: float,
            scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # [bq, d]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    n_kv = pl.cdiv(skv, bkv)
    if causal:
        # last kv block that intersects the causal frontier of this q block
        hi = jnp.minimum(((qi + 1) * bq + bkv - 1) // bkv, n_kv)
    else:
        hi = n_kv
    lo = 0
    if window > 0:
        lo = jnp.maximum((qi * bq - window) // bkv, 0)

    def body(kk, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(kk * bkv, bkv), :].astype(jnp.float32)  # [bkv, d]
        v = v_ref[pl.dslice(kk * bkv, bkv), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bkv]
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kv_pos = kk * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        mask = kv_pos < skv
        if causal:
            mask &= kv_pos <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                             "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, bq: int = 128, bkv: int = 128,
                    interpret: bool = True):
    """q: [b, sq, h, d]; k/v: [b, skv, hkv, d] → [b, sq, h, d]."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    scale = d ** -0.5

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1).reshape(b * h, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1).reshape(b * h, skv, d)

    bq_ = min(bq, sq)
    bkv_ = min(bkv, skv)
    pad_q = (-sq) % bq_
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    pad_kv = (-skv) % bkv_
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv

    grid = (b * h, sq_p // bq_)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq_, bkv=bkv_, sq=sq, skv=skv,
                          causal=causal, window=window, logit_cap=logit_cap,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq_, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, skv_p, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, skv_p, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq_, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out
