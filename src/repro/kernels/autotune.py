"""Measured autotune cache for the W4A8 kernel plans.

The routing decisions in ``repro.kernels.tuning`` are driven by a modeled
VMEM cost table — fine for "does this BlockSpec fit", useless for "which of
the fitting candidates is fastest", and (per ``BENCH_serve.json`` before
this subsystem) capable of hiding multi-x regressions: the modeled router
happily kept quantized decode 2–3× slower than fp. This module replaces
"model only" with **measure once, persist, consult**:

  * A versioned JSON cache of measured winners, keyed per backend
    (``~/.cache/repro/autotune_<backend>.json``; override the directory
    with ``$REPRO_AUTOTUNE_CACHE_DIR``). A checked-in baseline
    (``autotune_baseline.json`` next to this file) seeds fresh machines.
  * Measurement walks the exact candidate lattices ``tuning`` exports
    (``GEMM_BM/BN/BK_CANDIDATES``, ``FUSED_BN_CANDIDATES``, …) — the same
    lattices the static kernel-contract checker
    (``repro.analysis.contracts``) validates offline, so a cached winner
    can never name a BlockSpec the contracts don't cover (KC005 checks
    exactly this for every entry).
  * ``RuntimeConfig.autotune`` selects the mode: ``"off"`` reproduces the
    modeled decisions bit-for-bit, ``"cache"`` consults persisted winners
    and falls back to the model on a miss, ``"force"`` measures on miss.

Entry kinds
-----------
``w4a8_gemm``    — (bm, bn, bk) for the tiled GEMM, keyed
                   ``m<bucket>|k|n|r``. Measured by ``kernels_bench`` on
                   backends with compiled Pallas; on interpret-only
                   backends (CPU) wall-clock of the interpreter is
                   meaningless, so entries carry the modeled winner with
                   ``source: "model"`` — honestly labeled, same contract
                   checks.
``w4a8_fused``   — bn for the fused decode kernel, same key/caveats.
``fused_tiles``  — (bm, bn) for the tiled-m fused prefill variant.
``decode_plan``  — the serving-engine execution plan for quantized decode,
                   keyed by architecture signature. This one is genuinely
                   measured on every backend: candidates are end-to-end
                   formulations of the quantized linear stack (reference
                   scanned layout vs the prepared f32-code plan on an
                   unstacked layer list), timed through a decode-loop
                   proxy. See ``measure_decode_plan``.

The decode plan is where CPU serving wins or loses: inside a decode
``lax.scan``, XLA never hoists per-iteration slices of stacked layer
weights out of the while body, so every dot on a sliced operand lowers to
a naive loop an order of magnitude slower than the backend's GEMM path.
The ``prepared`` plan unpacks the int4 codes once at engine build into f32
code matrices (exact: |code·act| sums stay far below 2^24), folds the
weight scale and smoothing diagonal into them, stacks the low-rank factor
against the code matrix (one augmented GEMM instead of GEMM + two-dot
epilogue), and unstacks the layer axis into a Python-level
``models.model.LayerList`` so each weight reaches its dot as a whole
loop-invariant buffer. ``prepare_params`` applies exactly that transform.
"""
from __future__ import annotations

import functools
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import tuning as _tuning

CACHE_VERSION = 1

# decode_plan candidates: execution plans for the quantized serving stack.
#   "default"  — today's path: stacked groups scanned by lax.scan, packed
#                int4 leaves unpacked per step (reference/Pallas routing).
#   "prepared" — f32-code augmented leaves on an unstacked LayerList.
DECODE_PLANS = ("default", "prepared")

_BASELINE = Path(__file__).with_name("autotune_baseline.json")


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------

def gemm_key(m: int, k: int, n: int, r: int) -> str:
    return f"w4a8_gemm|m{_tuning._m_bucket(m)}|k{k}|n{n}|r{r}"


def fused_key(m: int, k: int, n: int, r: int) -> str:
    return f"w4a8_fused|m{_tuning._m_bucket(m)}|k{k}|n{n}|r{r}"


def fused_tiles_key(m: int, k: int, n: int, r: int) -> str:
    return f"fused_tiles|m{_tuning._m_bucket(m)}|k{k}|n{n}|r{r}"


def paged_key(block_size: int, group: int, hd: int,
              quantized: bool) -> str:
    return (f"paged_attention|b{block_size}|g{group}|h{hd}"
            f"|q{int(quantized)}")


def decode_plan_key(m: int, d_model: int, d_ff: int, r: int,
                    n_groups: int) -> str:
    return (f"decode_plan|m{_tuning._m_bucket(m)}|d{d_model}|ff{d_ff}"
            f"|r{r}|L{n_groups}")


# ---------------------------------------------------------------------------
# Entry validation (shared with analysis.contracts KC005)
# ---------------------------------------------------------------------------

def _parse_key(key: str) -> dict | None:
    """``kernel|m4|k256|…`` → {"kernel": ..., "m": 4, "k": 256, …}."""
    parts = key.split("|")
    out = {"kernel": parts[0]}
    for p in parts[1:]:
        i = 0
        while i < len(p) and not p[i].isdigit():
            i += 1
        if i == 0 or i == len(p):
            return None
        try:
            out[p[:i]] = int(p[i:])
        except ValueError:
            return None
    return out


def validate_entry(key: str, entry: dict,
                   budget: int = _tuning.VMEM_BUDGET) -> str | None:
    """KC001-style check of one cache entry against the exported lattices
    and the VMEM budget. Returns None when valid, else a reason string.
    Used both at consult time (a bad entry silently falls back to the
    model) and by the static contract checker's KC005 cache mode."""
    ks = _parse_key(key)
    if ks is None:
        return f"unparseable key {key!r}"
    kern = ks["kernel"]
    choice = entry.get("choice")
    if kern == "w4a8_gemm":
        if (not isinstance(choice, (list, tuple)) or len(choice) != 3
                or not all(isinstance(c, int) for c in choice)):
            return f"{key}: choice {choice!r} is not (bm, bn, bk)"
        bm, bn, bk = choice
        if bm not in _tuning.GEMM_BM_CANDIDATES \
                or bn not in _tuning.GEMM_BN_CANDIDATES \
                or bk not in _tuning.GEMM_BK_CANDIDATES:
            return f"{key}: ({bm},{bn},{bk}) outside the candidate lattice"
        vm = _tuning.vmem_bytes(min(bm, ks["m"]), min(bn, ks["n"]),
                                min(bk, ks["k"]), ks["r"])
        if vm > budget:
            return f"{key}: working set {vm} B over budget {budget} B"
    elif kern == "w4a8_fused":
        if not isinstance(choice, int):
            return f"{key}: choice {choice!r} is not an int bn"
        if choice not in _tuning.FUSED_BN_CANDIDATES and choice != ks["n"]:
            return f"{key}: bn {choice} outside the candidate lattice"
        vm = _tuning.fused_vmem_bytes(ks["m"], ks["k"],
                                      min(choice, ks["n"]), ks["r"])
        if vm > budget:
            return f"{key}: working set {vm} B over budget {budget} B"
    elif kern == "fused_tiles":
        if (not isinstance(choice, (list, tuple)) or len(choice) != 2
                or not all(isinstance(c, int) for c in choice)):
            return f"{key}: choice {choice!r} is not (bm, bn)"
        bm, bn = choice
        if bm not in _tuning.FUSED_BM_CANDIDATES \
                or (bn not in _tuning.FUSED_BN_CANDIDATES and bn != ks["n"]):
            return f"{key}: ({bm},{bn}) outside the candidate lattice"
        vm = _tuning.fused_vmem_bytes(min(bm, ks["m"]), ks["k"],
                                      min(bn, ks["n"]), ks["r"])
        if vm > budget:
            return f"{key}: working set {vm} B over budget {budget} B"
    elif kern == "decode_plan":
        if choice not in DECODE_PLANS:
            return f"{key}: plan {choice!r} not one of {DECODE_PLANS}"
    elif kern == "paged_attention":
        if not isinstance(choice, (bool, int)):
            return f"{key}: choice {choice!r} is not a routing verdict"
        if choice and _tuning.paged_vmem_bytes(
                ks["b"], ks["g"], ks["h"], bool(ks["q"])) > budget:
            return f"{key}: kernel routing over budget {budget} B"
    else:
        return f"{key}: unknown kernel {kern!r}"
    return None


# ---------------------------------------------------------------------------
# Cache store
# ---------------------------------------------------------------------------

def cache_dir() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE_DIR")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro"


def cache_path(backend: str | None = None) -> Path:
    backend = backend or jax.default_backend()
    return cache_dir() / f"autotune_{backend}.json"


class AutotuneCache:
    """One backend's measured-winner store.

    Load order: user cache file, else the checked-in baseline (when its
    backend matches), else empty. Every failure mode — missing file,
    corrupt JSON, stale version, wrong backend — degrades to an empty
    cache: consulting callers fall back to the modeled tables, they never
    raise. Writes are atomic (tmp + replace)."""

    def __init__(self, backend: str | None = None):
        self.backend = backend or jax.default_backend()
        self.path = cache_path(self.backend)
        self.entries: dict[str, dict] = {}
        self._loaded_from: str = "empty"
        for path, tag in ((self.path, "user"), (_BASELINE, "baseline")):
            loaded = self._read(path)
            if loaded is not None:
                self.entries = loaded
                self._loaded_from = tag
                break

    def _read(self, path: Path) -> dict | None:
        try:
            with open(path) as f:
                raw = json.load(f)
            if not isinstance(raw, dict):
                return None
            if raw.get("version") != CACHE_VERSION:
                return None
            if raw.get("backend") != self.backend:
                return None
            entries = raw.get("entries")
            if not isinstance(entries, dict):
                return None
            return {k: v for k, v in entries.items() if isinstance(v, dict)}
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            return None

    def save(self, path: Path | None = None) -> Path:
        path = path or self.path
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "backend": self.backend,
                       "entries": self.entries}, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    def get(self, key: str) -> dict | None:
        return self.entries.get(key)

    def put(self, key: str, choice, us: float | None,
            source: str = "measured") -> dict:
        entry = {"choice": choice, "us": us, "source": source}
        reason = validate_entry(key, entry)
        if reason is not None:
            raise ValueError(f"refusing to cache invalid entry: {reason}")
        self.entries[key] = entry
        _invalidate_selector_caches()
        return entry

    def demote(self, key: str, reason: str = "") -> bool:
        """Disable a measured winner (it lost to the path it displaced —
        see serve_bench's routed-vs-displaced assertion). Consults fall
        back to the model; the entry stays in the file as a tombstone so a
        refresh can see what was demoted and why."""
        e = self.entries.get(key)
        if e is None:
            return False
        e["disabled"] = True
        if reason:
            e["demoted_because"] = reason
        _invalidate_selector_caches()
        return True

    def lookup(self, key: str):
        """choice for a valid, enabled entry; None otherwise."""
        e = self.entries.get(key)
        if e is None or e.get("disabled"):
            return None
        if validate_entry(key, e) is not None:
            return None
        return e["choice"]


_CACHES: dict[str, AutotuneCache] = {}


def get_cache(backend: str | None = None) -> AutotuneCache:
    backend = backend or jax.default_backend()
    if backend not in _CACHES:
        _CACHES[backend] = AutotuneCache(backend)
    return _CACHES[backend]


def reset(backend: str | None = None) -> None:
    """Drop the in-process cache singleton(s) (tests, post-refresh)."""
    if backend is None:
        _CACHES.clear()
    else:
        _CACHES.pop(backend, None)
    _invalidate_selector_caches()


def _invalidate_selector_caches() -> None:
    # tuning's selectors memoize (shape, mode) → choice; cache content
    # changes (put/demote/reset) must drop those memos or they serve stale
    # winners for the life of the process.
    _tuning.select_gemm_blocks.cache_clear()


def lookup(key: str, mode: str, backend: str | None = None):
    """Consult the cache under a RuntimeConfig.autotune mode.

    ``"off"`` never touches the cache (modeled decisions, bit-for-bit).
    ``"cache"`` and ``"force"`` return a valid enabled entry's choice or
    None — measurement-on-miss for ``"force"`` is driven by the callers
    that can afford it (engine build, kernels_bench), never from inside a
    trace-time selector."""
    if mode == "off":
        return None
    return get_cache(backend).lookup(key)


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _best_time_us(fn, reps: int = 3) -> float:
    # benchmark timer: the sync IS the measurement  # repro: noqa[RA001]
    jax.block_until_ready(fn())          # repro: noqa[RA001]
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())      # repro: noqa[RA001]
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure_gemm_blocks(m: int, k: int, n: int, r: int, *,
                        interpret: bool | None = None,
                        reps: int = 3) -> tuple[tuple[int, int, int], float]:
    """Wall-clock the tiled GEMM over every in-budget lattice candidate.

    Returns (winner, best_us). Only meaningful on backends that compile
    Pallas (``interpret=False``); interpret-mode wall-clock measures the
    Python interpreter, not the kernel, so callers on CPU should record
    the modeled winner instead (``kernels_bench`` does exactly that and
    labels the entry ``source: "model"``)."""
    from .act_quant import act_quant as _act_quant
    from .w4a8_gemm import w4a8_gemm as _gemm
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    m_diag = jnp.abs(jax.random.normal(ks[1], (k,))) + 0.5
    qw = jax.random.randint(ks[2], (k // 2, n), -128, 128, jnp.int8)
    sw = jnp.abs(jax.random.normal(ks[3], (n,))) * 0.01 + 1e-3
    lb = jax.random.normal(ks[4], (k, r), jnp.float32) * 0.01
    la = jax.random.normal(ks[5], (r, n), jnp.float32) * 0.01
    xq, sx, xlr = _act_quant(x, m_diag, lb, interpret=interpret)
    best, best_us = None, float("inf")
    for bm in _tuning.GEMM_BM_CANDIDATES:
        for bn in _tuning.GEMM_BN_CANDIDATES:
            for bk in _tuning.GEMM_BK_CANDIDATES:
                bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
                if _tuning.vmem_bytes(bm_, bn_, bk_, r) > _tuning.VMEM_BUDGET:
                    continue
                us = _best_time_us(
                    lambda: _gemm(xq, sx, qw, sw, xlr, la, bm=bm_, bn=bn_,
                                  bk=bk_, interpret=interpret), reps)
                if us < best_us:
                    best, best_us = (bm, bn, bk), us
    if best is None:
        raise ValueError(f"no candidate fits VMEM for (m={m},k={k},n={n},r={r})")
    return best, best_us


def measure_fused_bn(m: int, k: int, n: int, r: int, *,
                     interpret: bool | None = None,
                     reps: int = 3) -> tuple[int, float]:
    """Wall-clock the fused decode kernel over in-budget bn candidates.
    Same interpret-mode caveat as ``measure_gemm_blocks``."""
    from .w4a8_fused import w4a8_fused as _fused
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    m_diag = jnp.abs(jax.random.normal(ks[1], (k,))) + 0.5
    qw = jax.random.randint(ks[2], (k // 2, n), -128, 128, jnp.int8)
    sw = jnp.abs(jax.random.normal(ks[3], (n,))) * 0.01 + 1e-3
    lb = jax.random.normal(ks[4], (k, r), jnp.float32) * 0.01
    la = jax.random.normal(ks[5], (r, n), jnp.float32) * 0.01
    best, best_us = None, float("inf")
    for bn in _tuning.FUSED_BN_CANDIDATES:
        bn_ = min(bn, n)
        if _tuning.fused_vmem_bytes(m, k, bn_, r) > _tuning.VMEM_BUDGET:
            continue
        us = _best_time_us(
            lambda: _fused(x, m_diag, qw, sw, lb, la, bn=bn_,
                           interpret=interpret), reps)
        if us < best_us:
            best, best_us = bn_, us
    if best is None:
        raise ValueError(f"no bn fits VMEM for (m={m},k={k},n={n},r={r})")
    return best, best_us


def measure_fused_tiles(m: int, k: int, n: int, r: int, *,
                        interpret: bool | None = None,
                        reps: int = 3) -> tuple[tuple[int, int], float]:
    """Wall-clock the tiled-m fused prefill kernel over in-budget
    (bm, bn) candidates. Same interpret-mode caveat as
    ``measure_gemm_blocks``."""
    from .w4a8_fused import w4a8_fused as _fused
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    m_diag = jnp.abs(jax.random.normal(ks[1], (k,))) + 0.5
    qw = jax.random.randint(ks[2], (k // 2, n), -128, 128, jnp.int8)
    sw = jnp.abs(jax.random.normal(ks[3], (n,))) * 0.01 + 1e-3
    lb = jax.random.normal(ks[4], (k, r), jnp.float32) * 0.01
    la = jax.random.normal(ks[5], (r, n), jnp.float32) * 0.01
    best, best_us = None, float("inf")
    for bm in _tuning.FUSED_BM_CANDIDATES:
        bm_ = min(bm, m)
        for bn in _tuning.FUSED_BN_CANDIDATES:
            bn_ = min(bn, n)
            if _tuning.fused_vmem_bytes(bm_, k, bn_, r) \
                    > _tuning.VMEM_BUDGET:
                continue
            us = _best_time_us(
                lambda: _fused(x, m_diag, qw, sw, lb, la, bn=bn_, bm=bm_,
                               interpret=interpret), reps)
            if us < best_us:
                best, best_us = (bm, bn_), us
    if best is None:
        raise ValueError(
            f"no (bm, bn) fits VMEM for (m={m},k={k},n={n},r={r})")
    return best, best_us


def _plan_leaves(d_model: int, d_ff: int, r: int, n_groups: int, seed: int = 0):
    """Synthetic quantized leaves for the decode-plan proxy: the per-group
    linear stack of a llama-style block (qkv/o + gate/up/down), stacked
    over the group axis like real serving params."""
    shapes = [(d_model, d_model), (d_model, d_model),   # wq, wo (+kv folded)
              (d_model, d_ff), (d_model, d_ff), (d_ff, d_model)]
    rng = np.random.default_rng(seed)
    leaves = []
    for (k, n) in shapes:
        leaves.append({
            "qw": jnp.asarray(rng.integers(-128, 128,
                                           (n_groups, k // 2, n), np.int8)),
            "sw": jnp.asarray(rng.random((n_groups, n), np.float32) * 0.01
                              + 1e-3),
            "m": jnp.asarray(rng.random((n_groups, k), np.float32) + 0.5),
            "lb": jnp.asarray(rng.standard_normal((n_groups, k, r))
                              .astype(np.float32) * 0.01),
            "la": jnp.asarray(rng.standard_normal((n_groups, r, n))
                              .astype(np.float32) * 0.01),
        })
    return leaves


def measure_decode_plan(m: int, d_model: int, d_ff: int, r: int,
                        n_groups: int, *, n_steps: int = 24,
                        reps: int = 3) -> tuple[str, dict[str, float]]:
    """Wall-clock the decode-plan candidates through a decode-loop proxy.

    The proxy is an N-step ``lax.scan`` whose body runs one group-stack of
    quantized linears per layer — the same structural skeleton as
    ``serve.Engine``'s decode loop (weights as jit arguments, layer
    iteration inside the step) so the measurement sees the same XLA
    behaviors the engine does: naive slice-fused dots for the scanned
    stacked layout, the backend GEMM path for prepared unstacked leaves.
    Returns (winner, {plan: us_per_step}). Honest wall-clock on every
    backend — this is the entry that makes quantized decode win or lose."""
    from . import ref as _ref
    leaves = _plan_leaves(d_model, d_ff, r, n_groups)
    x0 = jnp.asarray(np.random.default_rng(1)
                     .standard_normal((m, d_model)).astype(np.float32))

    def step_default(h, sliced):
        u = None
        for (qw, sw, m_diag, lb, la) in sliced:
            src = h if m_diag.shape[-1] == d_model else u
            y = _ref.w4a8_linear_ref(src, qw, sw, m_diag, lb, la)
            if y.shape[-1] == d_ff:
                u = y
            else:
                h = h + 0.001 * y
        return h / (1.0 + 0.001 * jnp.max(jnp.abs(h)))

    def run_default(x, *stacked):
        def dbody(h, _):
            def gbody(hh, sliced):
                return step_default(hh, sliced), None
            hh, _ = jax.lax.scan(gbody, h, tuple(stacked))
            return hh, None
        h, _ = jax.lax.scan(dbody, x, None, length=n_steps)
        return h

    def run_prepared(x, *flat_prepped):
        # flat_prepped: n_groups × leaves × (waug, blb, m, sw_keep) tuples,
        # unstacked at trace time — whole loop-invariant buffers.
        per_group = len(flat_prepped) // n_groups
        def dbody(h, _):
            hh = h
            for g in range(n_groups):
                u = None
                for (waug, blb, m_diag) in flat_prepped[g * per_group:
                                                        (g + 1) * per_group]:
                    src = hh if m_diag.shape[-1] == d_model else u
                    y = _aug_linear(src, waug, blb, m_diag)
                    if y.shape[-1] == d_ff:
                        u = y
                    else:
                        hh = hh + 0.001 * y
            return hh / (1.0 + 0.001 * jnp.max(jnp.abs(hh))), None
        h, _ = jax.lax.scan(dbody, x, None, length=n_steps)
        return h

    results: dict[str, float] = {}
    stacked = tuple(tuple(lv[k] for k in ("qw", "sw", "m", "lb", "la"))
                    for lv in leaves)
    f_def = jax.jit(run_default)
    results["default"] = _best_time_us(
        lambda: f_def(x0, *stacked), reps) / n_steps

    prepped = []
    for g in range(n_groups):
        for lv in leaves:
            pl = prepare_leaf({k: v[g] for k, v in lv.items()})
            prepped.append((pl["waug"], pl["blb"], pl["m"]))
    f_prep = jax.jit(run_prepared)
    results["prepared"] = _best_time_us(
        lambda: f_prep(x0, *prepped), reps) / n_steps

    winner = min(results, key=results.get)
    return winner, results


# ---------------------------------------------------------------------------
# The prepared decode plan
# ---------------------------------------------------------------------------

def _aug_linear(x, waug, blb, m_diag, qmax: int = 127):
    """The augmented-GEMM quantized linear on prepared leaves.

    y = [xq·sx | x@blb] @ waug, where waug = [[codes·sw], [la]] and
    blb = lb / m_diag. Same math as the reference chain (codes are exact
    in f32; only f32 reduction order differs — the scale fold and the
    low-rank epilogue ride inside the one augmented reduction)."""
    x = x.astype(jnp.float32)
    x_s = x / m_diag[None, :]
    sx = jnp.maximum(jnp.max(jnp.abs(x_s), axis=1, keepdims=True),
                     1e-8) / qmax
    xq = jnp.clip(jnp.round(x_s / sx), -qmax - 1, qmax)
    z = jnp.concatenate([xq * sx, x @ blb], axis=1)
    return z @ waug


def prepare_leaf(p: dict) -> dict:
    """Augment one quantized leaf dict with the prepared-plan arrays.

    Adds ``waug`` [(k+r), n] f32 (unpacked int4 codes × sw stacked over
    la) and ``blb`` [k, r] f32 (lb with the smoothing diagonal folded in).
    The original packed leaves stay — fallback paths (force_reference,
    adapter routing, weight-only) still work. Leaves carrying adapter
    pools are returned untouched: the adapter serving path is token-exact
    against a merged-weight reference *because* its reduction order is
    pinned (see ``ops.adapter_epilogue``); re-ordering the base linear
    under it would break that certification."""
    if "alb" in p:
        return p
    from repro.core.quantizers import unpack_int4
    qw, sw, m_diag = p["qw"], p["sw"], p["m"]
    lb, la = p["lb"], p["la"]
    wf = unpack_int4(qw.T).T.astype(jnp.float32)          # [k, n] codes
    waug = jnp.concatenate(
        [wf * sw[None, :].astype(jnp.float32),
         la.astype(jnp.float32)], axis=0)                 # [(k+r), n]
    blb = lb.astype(jnp.float32) / m_diag[:, None].astype(jnp.float32)
    q = dict(p)
    q["waug"], q["blb"] = waug, blb
    return q


def _prepare_tree(p):
    if isinstance(p, dict):
        if "qw" in p:
            return prepare_leaf(p)
        return {k: _prepare_tree(v) for k, v in p.items()}
    if isinstance(p, (list, tuple)):
        return type(p)(_prepare_tree(v) for v in p)
    return p


def prepare_params(params: dict) -> dict:
    """Apply the prepared decode plan to a quantized param tree.

    Unstacks ``params["groups"]`` into a :class:`models.model.LayerList`
    (Python-level layer iteration — see the module docstring for why) and
    augments every quantized leaf via :func:`prepare_leaf`. Non-quantized
    trees come back unchanged. The transform is pure and idempotent."""
    from repro.models.model import LayerList
    has_quant = any("qw" in d for d in _iter_dicts(params))
    if not has_quant:
        return params
    out = dict(params)
    groups = params.get("groups")
    if groups is not None and not isinstance(groups, LayerList):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        unstacked = [jax.tree.map(lambda a, i=i: a[i], groups)
                     for i in range(n_groups)]
        out["groups"] = LayerList(_prepare_tree(g) for g in unstacked)
    elif groups is not None:
        out["groups"] = LayerList(_prepare_tree(g) for g in groups)
    for key in ("prefix",):
        if key in out:
            out[key] = _prepare_tree(out[key])
    return out


def _iter_dicts(p):
    if isinstance(p, dict):
        yield p
        for v in p.values():
            yield from _iter_dicts(v)
    elif isinstance(p, (list, tuple)):
        for v in p:
            yield from _iter_dicts(v)


# ---------------------------------------------------------------------------
# Engine hook
# ---------------------------------------------------------------------------

def engine_plan_key(params, cfg, scfg) -> str | None:
    """The decode_plan cache key an engine with these (params, cfg, scfg)
    consults — or None when no plan applies (no quantized leaves, pooled
    adapters, no scanned groups). Shared by the engine-build hook below
    and serve_bench's routed-vs-displaced demotion."""
    quant_leaves = [d for d in _iter_dicts(params) if "qw" in d]
    if not quant_leaves:
        return None
    if any("alb" in d for d in quant_leaves):
        # pooled-adapter engines keep the pinned-reduction path everywhere
        return None
    groups = params.get("groups")
    if groups is None:
        return None
    r = quant_leaves[0]["lb"].shape[-1]
    from repro.models.model import LayerList
    if isinstance(groups, LayerList):
        n_groups = len(groups)
    else:
        n_groups = jax.tree.leaves(groups)[0].shape[0]
    m = getattr(scfg, "batch_slots", 1) or 1
    return decode_plan_key(m, cfg.d_model, cfg.d_ff, r, n_groups)


def maybe_prepare_engine_params(params, cfg, scfg, rt):
    """Engine-build hook: consult (or measure) the decode-plan entry and
    apply the winning plan to the engine's params.

    Returns (params, plan). ``rt.autotune == "off"`` or a cache miss in
    ``"cache"`` mode returns the params untouched — the engine then runs
    today's modeled routing bit-for-bit. ``"force"`` measures the plan on
    a miss and persists the winner."""
    if rt is None or rt.autotune == "off":
        return params, "default"
    key = engine_plan_key(params, cfg, scfg)
    if key is None:
        return params, "default"
    ks = _parse_key(key)
    m, r, n_groups = ks["m"], ks["r"], ks["L"]
    cache = get_cache()
    plan = cache.lookup(key)
    if plan is None and rt.autotune == "force":
        winner, results = measure_decode_plan(
            min(m, _tuning.DECODE_M_MAX), cfg.d_model, cfg.d_ff, r, n_groups)
        cache.put(key, winner, results[winner])
        cache.save()
        plan = winner
    if plan == "prepared":
        return prepare_params(params), "prepared"
    return params, "default"
