"""Block-size selection for the W4A8 kernels: modeled VMEM tile economics.

Wall-clock autotuning on CPU interpret mode is meaningless, so kernel
routing is driven by the same static cost model the benchmark harness
reports (``benchmarks/kernels_bench.py`` imports it from here): per-step
VMEM working set and arithmetic intensity per BlockSpec choice. Two
decisions live here:

  * ``use_fused_decode(m, k, n, r)`` — small-m (decode / GEMV) calls route
    to the single-pass fused kernel (``w4a8_fused``) when its whole-K
    working set fits the VMEM budget; everything else takes the two-kernel
    act_quant → w4a8_gemm pipeline.
  * ``select_gemm_blocks(m, k, n, r)`` — (bm, bn, bk) for the tiled GEMM:
    an explicit table of known-good shapes first, then a modeled search
    maximizing arithmetic intensity under the VMEM budget.
  * ``use_paged_kernel(...)`` — paged-KV decode attention routes to the
    scalar-prefetch paged-gather kernel (``paged_attention.py``) when one
    KV block plus the query group fits the budget; otherwise the caller
    falls back to the XLA gather path.

All are pure Python over static shapes — resolved at trace time, never
traced.
"""
from __future__ import annotations

import functools

# Per-core VMEM is ~16 MB; leave half for double buffering + the compiler's
# own spills. All budgets in bytes.
VMEM_BUDGET = 8 * 1024 * 1024

# Largest m that still counts as a decode/GEMV shape (one to a few tokens
# per sequence in the batch). Above this the MXU is fed well enough by the
# tiled path that recomputing the quant per n-tile stops paying for itself.
DECODE_M_MAX = 16

# Candidate lattices the block selectors search, exported as data so the
# static kernel-contract checker (``repro.analysis.contracts``) can walk
# the *entire* cross-product offline: every (bm, bn, bk) / bn candidate a
# selector could ever return must satisfy the kernel contracts, not just
# the ones today's serving shapes happen to hit.
FUSED_BN_CANDIDATES = (2048, 1024, 512, 256, 128)
FUSED_BM_CANDIDATES = (128, 64, 32, 16)
GEMM_BM_CANDIDATES = (128, 256, 512)
GEMM_BN_CANDIDATES = (128, 256, 512)
GEMM_BK_CANDIDATES = (256, 512, 1024)


def _autotune_lookup(key_fn, shape, mode):
    """Consult the measured autotune cache (``repro.kernels.autotune``).

    Returns the cached choice or None (mode "off", miss, demoted or
    contract-invalid entry — all fall back to the modeled search below).
    Imported lazily: autotune imports this module for the lattices."""
    if mode == "off":
        return None
    from . import autotune as _autotune
    return _autotune.lookup(key_fn(*shape), mode)


def vmem_bytes(bm: int, bn: int, bk: int, r: int) -> int:
    """Per-grid-step VMEM working set of the tiled w4a8 GEMM kernel."""
    return (bm * bk                    # xq int8
            + bk // 2 * bn             # packed weights
            + bk * bn                  # VPU-unpacked int8 weight tile
            + bm * bn * 4              # int32 accumulator
            + bm * 4 + bn * 4          # scales
            + bm * r * 4 + r * bn * 4  # low-rank epilogue
            )


def fused_vmem_bytes(m: int, k: int, bn: int, r: int) -> int:
    """Per-grid-step VMEM working set of the fused decode kernel.

    K is kept whole (per-token absmax needs the full row), so the
    activations, smoothing diagonal, and L_B all ride along in VMEM. The
    VPU-unpacked int8 weight tile (k·bn) dominates at whole-K — it must be
    counted or the "fits VMEM" gate that justifies fusion overcommits."""
    return (m * k * 4                  # x (f32 working copy)
            + k * 4                    # m_diag
            + m * k * 4                # xq (int32 codes feeding the dot)
            + k // 2 * bn              # packed weights
            + k * bn                   # VPU-unpacked int8 weight tile
            + m * bn * 4               # f32 accumulator / output tile
            + bn * 4                   # sw
            + k * r * 4 + r * bn * 4   # lb, la
            + m * r * 4                # xlr
            )


def use_fused_decode(m: int, k: int, n: int, r: int,
                     budget: int = VMEM_BUDGET,
                     autotune: str = "off") -> bool:
    """Route small-m calls to the fused single-pass kernel when it fits."""
    if m > DECODE_M_MAX:
        return False
    bn = fused_bn(m, k, n, r, budget=budget, autotune=autotune)
    return bn is not None


def fused_bn(m: int, k: int, n: int, r: int,
             budget: int = VMEM_BUDGET,
             autotune: str = "off") -> int | None:
    """Largest n-tile (multiple of 128, capped at n) that keeps the fused
    kernel's working set under budget; None if even bn=128 doesn't fit.
    With ``autotune != "off"`` a measured winner (validated against this
    same budget) takes precedence over the largest-fitting heuristic."""
    hit = _autotune_lookup(_fused_key, (m, k, n, r), autotune)
    if hit is not None and fused_vmem_bytes(m, k, min(hit, n), r) <= budget:
        return min(hit, n)
    for bn in FUSED_BN_CANDIDATES:
        bn_ = min(bn, n)
        if fused_vmem_bytes(m, k, bn_, r) <= budget:
            return bn_
    return None


def fused_tiles(m: int, k: int, n: int, r: int,
                budget: int = VMEM_BUDGET,
                autotune: str = "off") -> tuple[int, int] | None:
    """(bm, bn) for the tiled-m fused kernel at prefill shapes.

    Extends the fused single-pass chain (smooth → quant → GEMM → dequant →
    low-rank) past ``DECODE_M_MAX`` by tiling m as well as n: each grid
    step holds a ``bm``-row slab with K whole (the per-token absmax still
    needs full rows). Modeled choice: the largest row slab whose working
    set fits, then the widest n-tile — fewer grid steps, same per-step
    recompute. None when even the smallest tile overshoots (the two-kernel
    pipeline handles it)."""
    hit = _autotune_lookup(_fused_tiles_key, (m, k, n, r), autotune)
    if hit is not None:
        bm, bn = hit
        if fused_vmem_bytes(min(bm, m), k, min(bn, n), r) <= budget:
            return min(bm, m), min(bn, n)
    for bm in FUSED_BM_CANDIDATES:
        bm_ = min(bm, m)
        for bn in FUSED_BN_CANDIDATES:
            bn_ = min(bn, n)
            if fused_vmem_bytes(bm_, k, bn_, r) <= budget:
                return bm_, bn_
    return None


def use_fused_prefill(m: int, k: int, n: int, r: int,
                      budget: int = VMEM_BUDGET,
                      autotune: str = "off") -> bool:
    """Route prefill-m calls (m > DECODE_M_MAX) to the tiled-m fused
    kernel, sparing chunked prefill the act_quant → GEMM HBM round trip."""
    if m <= DECODE_M_MAX:
        return False
    return fused_tiles(m, k, n, r, budget=budget, autotune=autotune) \
        is not None


def _fused_key(m, k, n, r):
    from . import autotune as _autotune
    return _autotune.fused_key(m, k, n, r)


def _fused_tiles_key(m, k, n, r):
    from . import autotune as _autotune
    return _autotune.fused_tiles_key(m, k, n, r)


def _gemm_key(m, k, n, r):
    from . import autotune as _autotune
    return _autotune.gemm_key(m, k, n, r)


def gather_vmem_bytes(k: int, bn: int, r: int, ra: int) -> int:
    """Per-grid-step VMEM working set of the gathered-epilogue fused kernel.

    The grid is (row, n-tile), so each step holds one activation row
    (m = 1), the whole-K weight tile, the base low-rank factors, and **one**
    adapter's gathered (alb, ala) factor blocks — the adapter pool itself
    never enters VMEM. The index vector rides in SMEM (scalar prefetch) and
    is not counted."""
    return (fused_vmem_bytes(1, k, bn, r)
            + k * ra * 4                   # gathered alb block
            + ra * bn * 4                  # gathered ala tile
            + ra * 4)                      # x_s @ alb intermediate


def fused_gather_bn(k: int, n: int, r: int, ra: int,
                    budget: int = VMEM_BUDGET) -> int | None:
    """Largest n-tile that keeps the gathered fused kernel under budget."""
    for bn in FUSED_BN_CANDIDATES:
        bn_ = min(bn, n)
        if gather_vmem_bytes(k, bn_, r, ra) <= budget:
            return bn_
    return None


def use_fused_gather(m: int, k: int, n: int, r: int, ra: int,
                     budget: int = VMEM_BUDGET) -> bool:
    """Route adapter-routed decode calls to the gathered fused kernel.

    Same decode-shape gate as ``use_fused_decode``; above ``DECODE_M_MAX``
    (or over budget) the caller computes the base linear through its normal
    route and adds the adapter term via the XLA batched-gather epilogue."""
    if m > DECODE_M_MAX:
        return False
    return fused_gather_bn(k, n, r, ra, budget=budget) is not None


def paged_vmem_bytes(block_size: int, group: int, hd: int,
                     quantized: bool = False) -> int:
    """Per-grid-step VMEM working set of the paged-gather decode kernel.

    One physical KV block (k + v), the kv-head's query group, the
    [group, block_size] score tile, and the online-softmax scratch. The
    block table and frontier lengths ride in SMEM (scalar prefetch) and
    are not counted against VMEM. ``quantized`` pools add the raw int8
    code tiles plus the f32 per-slot scale tiles of the dequant epilogue
    (the f32 working copies above are counted either way).
    """
    return (2 * block_size * hd * 4        # k, v block (f32 working copies)
            + group * hd * 4               # q group
            + group * block_size * 4       # score tile
            + 2 * group * 4                # m, l scratch
            + group * hd * 4               # acc scratch
            + group * hd * 4               # out tile
            + (2 * block_size * hd         # int8 code tiles as DMA'd
               + 2 * block_size * 4        # k/v scale tiles
               if quantized else 0))


def use_paged_kernel(batch: int, nb: int, block_size: int, group: int,
                     hd: int, budget: int = VMEM_BUDGET,
                     quantized: bool = False,
                     autotune: str = "off") -> bool:
    """Route paged decode attention to the Pallas paged-gather kernel.

    Decode is m = 1 token per row by construction; the only way the kernel
    doesn't pay for itself is when a block step's working set spills VMEM
    (huge head_dim × block_size) — then the XLA gather path is the safer
    bet. ``nb``/``batch`` only scale the grid, not the per-step footprint.
    ``quantized`` adds the dequant epilogue's tiles to the modeled set.
    A measured routing verdict (autotune cache, kind "paged_attention")
    overrides the modeled fit check — but only toward the *fallback*:
    a measured "kernel loses here" is trusted, a measured "kernel wins"
    still has to fit the budget.
    """
    fits = paged_vmem_bytes(block_size, group, hd, quantized) <= budget
    if autotune != "off":
        from . import autotune as _autotune
        hit = _autotune.lookup(
            _autotune.paged_key(block_size, group, hd, quantized), autotune)
        if hit is not None:
            return bool(hit) and fits
    return fits


# Known-good BlockSpecs for recurring serving shapes, keyed by
# (m_bucket, k, n, r_padded). m is bucketed to the next power of two so one
# entry covers a range of batch sizes. Filled from the modeled sweep in
# benchmarks/kernels_bench.py; the heuristic below is the fallback.
GEMM_BLOCK_TABLE: dict[tuple[int, int, int, int], tuple[int, int, int]] = {
    (128, 2048, 2048, 64): (128, 512, 512),
    (256, 4096, 4096, 64): (256, 256, 512),
    (512, 2048, 8192, 64): (256, 256, 1024),
}


def _m_bucket(m: int) -> int:
    b = 1
    while b < m:
        b *= 2
    return b


@functools.lru_cache(maxsize=512)
def select_gemm_blocks(m: int, k: int, n: int, r: int,
                       budget: int = VMEM_BUDGET,
                       autotune: str = "off") -> tuple[int, int, int]:
    """(bm, bn, bk) for the tiled GEMM: measured winner, table hit, else
    modeled search. Table and cache hits are validated against the
    *caller's* budget — an entry recorded under the default budget can
    overshoot a reduced one, and returning it anyway would hand the kernel
    a working set the gate just rejected (the search path below respects
    the budget, so fall through to it)."""
    hit = _autotune_lookup(_gemm_key, (m, k, n, r), autotune)
    if hit is not None:
        bm, bn, bk = (min(hit[0], m), min(hit[1], n), min(hit[2], k))
        if vmem_bytes(bm, bn, bk, r) <= budget:
            return bm, bn, bk
    hit = GEMM_BLOCK_TABLE.get((_m_bucket(m), k, n, r))
    if hit is not None:
        bm, bn, bk = (min(hit[0], m), min(hit[1], n), min(hit[2], k))
        if vmem_bytes(bm, bn, bk, r) <= budget:
            return hit
    best, best_ai = (256, 256, 512), -1.0
    for bm in GEMM_BM_CANDIDATES:
        for bn in GEMM_BN_CANDIDATES:
            for bk in GEMM_BK_CANDIDATES:
                bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
                vm = vmem_bytes(bm_, bn_, bk_, r)
                if vm > budget:
                    continue
                ai = (2 * bm_ * bn_ * bk_) / vm   # flops per VMEM byte
                if ai > best_ai:
                    best, best_ai = (bm_, bn_, bk_), ai
    return best
