"""Pallas TPU kernels (validated in interpret mode on CPU) + XLA fallbacks."""
from . import ops, ref, tuning
from .w4a8_gemm import w4a8_gemm
from .w4a8_fused import w4a8_fused, w4a8_fused_gather
from .act_quant import act_quant
from .flash_attention import flash_attention
from .paged_attention import paged_decode_attention

__all__ = ["ops", "ref", "tuning", "w4a8_gemm", "w4a8_fused",
           "w4a8_fused_gather", "act_quant", "flash_attention",
           "paged_decode_attention"]
