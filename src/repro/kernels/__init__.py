"""Pallas TPU kernels (validated in interpret mode on CPU) + XLA fallbacks."""
from . import ops, ref
from .w4a8_gemm import w4a8_gemm
from .act_quant import act_quant
from .flash_attention import flash_attention

__all__ = ["ops", "ref", "w4a8_gemm", "act_quant", "flash_attention"]
