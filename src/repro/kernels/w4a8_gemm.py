"""W4A8 matmul Pallas kernel with fused dequant + ASER low-rank epilogue.

TPU-native adaptation of CUDA W4A8 GEMMs (Marlin-style): the MXU consumes
int8×int8→int32; int4 weights are stored packed 2-per-byte along K and
unpacked to int8 on the VPU inside the kernel. Per-token activation scales
``sx`` and per-channel weight scales ``sw`` are applied in the f32 epilogue,
fused with the ASER compensation ``xlr @ la`` (xlr = smoothed activations
pre-projected onto L_B by the act-quant kernel) so the low-rank path never
round-trips HBM.

Grid: (m_tiles, n_tiles, k_tiles); K is innermost so the int32 accumulator
lives in a VMEM scratch across K steps.

Weight packing: pairwise along K — packed[i, n] holds codes[2i, n] in the
low nibble, codes[2i+1, n] in the high nibble (see repro.core.pack_int4
applied along K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def unpack_int4_block(packed):
    """[bk//2, bn] int8 → [bk, bn] int8 (pairwise interleave along K).

    VPU-side unpack shared by the tiled and fused W4A8 kernels."""
    u = packed.astype(jnp.uint8)
    lo = (u & 0xF).astype(jnp.int8)
    hi = ((u >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    w = jnp.stack([lo, hi], axis=1)               # [bk//2, 2, bn]
    return w.reshape(lo.shape[0] * 2, lo.shape[1])


def _kernel(xq_ref, sx_ref, qw_ref, sw_ref, xlr_ref, la_ref, out_ref,
            acc_ref, *, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = unpack_int4_block(qw_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...].astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == n_k - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        y = y + jnp.dot(xlr_ref[...].astype(jnp.float32),
                        la_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        out_ref[...] = y


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w4a8_gemm(xq, sx, qw, sw, xlr, la, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
              bk=DEFAULT_BK, interpret=True):
    """xq: [m,k] int8; sx: [m,1] f32; qw: [k//2,n] int8 packed; sw: [n] f32;
    xlr: [m,r] f32; la: [r,n] f32 → y [m,n] f32."""
    m, k = xq.shape
    n = qw.shape[1]
    r = xlr.shape[1]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    n_k = pl.cdiv(k, bk_)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm_, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk_ // 2, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
            pl.BlockSpec((bm_, r), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((r, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(xq, sx, qw, sw.reshape(1, n), xlr, la)
