"""Paged-gather decode attention (Pallas), the paged-KV serving hot path.

Decode reads one token's attention over a request's pages of the global
block pool. The XLA fallback gathers the whole per-row KV view
([b, blocks_per_seq * block_size, hkv, hd]) into a contiguous buffer every
layer — an HBM round-trip proportional to context length per decode token.
This kernel never materializes that view: the per-request block table rides
in as a **scalar-prefetch** operand, so each grid step's BlockSpec
``index_map`` reads the table and DMAs exactly one physical KV block from
the pool into VMEM.

Grid: (batch * kv_heads, blocks_per_seq), last axis fastest (sequential on
TPU), with the online-softmax accumulators for the current (row, kv head)
living in VMEM scratch across the block steps. Quantized (int8/int4-coded)
pools add a **dequant epilogue**: the per-page scale tiles DMA in through
the same table-indexed BlockSpec as their code blocks and multiply in VMEM,
so a quantized pool is never materialized dequantized in HBM. GQA is folded into the grid:
each program attends one kv head's query group ([group, hd]) against one
[block_size, hd] KV block. Blocks wholly past a row's frontier are skipped
(`pl.when`), and sentinel table entries (unmapped logical blocks) are
clamped in the index_map — their loads are dead because the frontier mask
already excludes them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, klen_ref, q_ref, k_ref, v_ref, *rest, bs: int, nb: int,
            hkv: int, scale: float, logit_cap: float, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    i = pl.program_id(1)
    b_idx = pl.program_id(0) // hkv

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    klen = klen_ref[b_idx]

    @pl.when(i * bs < klen)
    def _block():
        q = q_ref[...].astype(jnp.float32) * scale          # [group, hd]
        k = k_ref[...].astype(jnp.float32)                  # [bs, hd]
        v = v_ref[...].astype(jnp.float32)
        if quantized:
            # dequant epilogue: int8 codes × per-slot-per-head f32 scale,
            # fused right after the pool DMA (no dequantized HBM copy)
            k = k * ks_ref[...].reshape(bs, 1)
            v = v * vs_ref[...].reshape(bs, 1)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [group, bs]
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kv_pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(kv_pos < klen, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]             # [group, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("logit_cap", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len,
                           k_scale=None, v_scale=None, *,
                           logit_cap: float = 0.0, interpret: bool = True):
    """q: [b, 1, hq, hd]; pools: [num_blocks, bs, hkv, hd];
    block_tables: [b, nb] int32 physical ids (sentinel = num_blocks for
    unmapped entries); kv_len: [b] int32 valid prefix per row.

    ``k_scale``/``v_scale`` ([num_blocks, bs, hkv] f32, both or neither):
    quantized pools — ``k_pool``/``v_pool`` hold int8 codes and each block
    step multiplies the DMA'd code tile by its per-slot-per-head scale tile
    in VMEM (the dequant epilogue; the pool never materializes
    dequantized).

    Returns [b, 1, hq, hd].
    """
    b, s, hq, hd = q.shape
    assert s == 1, "paged kernel is the decode (s == 1) hot path"
    quantized = k_scale is not None
    assert (v_scale is not None) == quantized, \
        "k_scale/v_scale must be passed together"
    n_total, bs, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    group = hq // hkv
    scale = hd ** -0.5

    # q head h uses kv head h // group: [b, hkv, group, hd]
    qf = q.reshape(b, hkv, group, hd)
    bt = block_tables.astype(jnp.int32)
    klen = kv_len.astype(jnp.int32)

    grid = (b * hkv, nb)
    kernel = functools.partial(_kernel, bs=bs, nb=nb, hkv=hkv, scale=scale,
                               logit_cap=logit_cap, quantized=quantized)
    pool_spec = pl.BlockSpec((None, bs, None, hd),
                             lambda bh, i, bt, kl: (
                                 jnp.minimum(bt[bh // hkv, i], n_total - 1),
                                 0, bh % hkv, 0))
    # scale tiles ride the same table-indexed gather as their code blocks
    scale_spec = pl.BlockSpec((None, bs, None),
                              lambda bh, i, bt, kl: (
                                  jnp.minimum(bt[bh // hkv, i], n_total - 1),
                                  0, bh % hkv))
    in_specs = [
        pl.BlockSpec((None, None, group, hd),
                     lambda bh, i, bt, kl: (bh // hkv, bh % hkv, 0, 0)),
        # the paged gather: table entry → physical pool block
        pool_spec,
        pool_spec,
    ]
    operands = [bt, klen, qf, k_pool, v_pool]
    if quantized:
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((None, None, group, hd),
                                   lambda bh, i, bt, kl: (bh // hkv,
                                                          bh % hkv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),     # running max
                pltpu.VMEM((group, 1), jnp.float32),     # running denom
                pltpu.VMEM((group, hd), jnp.float32),    # running numerator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, 1, hq, hd)
