"""Fused W4A8 decode kernel: one VMEM pass for the whole quantized linear.

For decode / GEMV shapes (m tokens, m small) the two-kernel pipeline
(act_quant → w4a8_gemm) round-trips ``xq``/``sx``/``xlr`` through HBM
between the calls — at m ∈ {1..8} that traffic and the second dispatch
dominate the actual math. This kernel does the full chain in a single
``pallas_call``::

    x_s  = x / m_diag                     (ASER activation smoothing)
    sx   = absmax(x_s, rows) / qmax       (per-token scale)
    xq   = round(x_s / sx)                (int8 codes)
    acc  = xq · unpack_int4(qw)           (MXU int32 GEMM)
    y    = acc * sx * sw + (x_s @ L_B) @ L_A   (dequant + ASER epilogue)

Grid is over n-tiles only; K is kept whole per step (the per-token absmax
needs the full row, and at decode m the whole-K working set fits VMEM —
``repro.kernels.tuning.use_fused_decode`` gates routing on exactly that).
The smooth/quant stage is recomputed per n-tile; at decode m that is a few
KFLOP against the saved HBM round-trip.

Rank 0 (no compensation) omits the ``lb``/``la`` operands and the epilogue
dot entirely — base-model rows pay nothing for a feature they don't use.

``w4a8_fused_gather`` is the multi-tenant adapter variant: each batch row
additionally gathers one adapter's (``alb``, ``ala``) factor block out of a
device pool by table index. The per-row index vector rides in as a
**scalar-prefetch** operand (same pattern as the paged-attention block
table), so each grid step's BlockSpec ``index_map`` reads the table and
DMAs exactly one adapter's factors into VMEM — the pool is never gathered
in HBM. The grid tiles (row, n-tile); slot 0 of the pool is the all-zero
base adapter, so base rows in a mixed batch add an exactly-zero epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .act_quant import smooth_quant_block
from .tuning import fused_bn, fused_gather_bn
from .w4a8_gemm import unpack_int4_block


def _kernel(x_ref, m_ref, qw_ref, sw_ref, *rest, qmax: int, has_lr: bool):
    if has_lr:
        lb_ref, la_ref, out_ref = rest
    else:
        (out_ref,) = rest
    x, sx, codes = smooth_quant_block(x_ref[...], m_ref[...], qmax)
    xq = codes.astype(jnp.int32)
    w = unpack_int4_block(qw_ref[...])
    acc = jax.lax.dot_general(
        xq, w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * sx * sw_ref[...]
    if has_lr:
        xlr = jnp.dot(x, lb_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        y = y + jnp.dot(xlr, la_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    out_ref[...] = y


@functools.partial(jax.jit,
                   static_argnames=("bits", "bn", "bm", "interpret"))
def w4a8_fused(x, m_diag, qw, sw, lb, la, *, bits: int = 8,
               bn: int | None = None, bm: int | None = None,
               interpret: bool = True):
    """x: [m,k]; m_diag: [k]; qw: [k//2,n] int8 packed; sw: [n]; lb: [k,r];
    la: [r,n] → y [m,n] f32.

    Decode shapes (``bm`` None): m small, K whole in VMEM, grid over
    n-tiles only. Prefill shapes pass ``bm`` to tile the rows as well —
    each grid step holds a ``bm``-row slab with K still whole (the
    per-token absmax needs full rows), so chunked prefill runs the same
    single-pass chain instead of the two-kernel HBM round trip. The
    caller's router (``ops.w4a8_linear`` via ``tuning.fused_bn`` /
    ``tuning.fused_tiles``) owns the tile choice and threads it through —
    the ``bn=None`` re-derivation below is a back-compat default for
    direct API use and runs under the *default* budget only.

    r == 0 skips the low-rank epilogue entirely (operands never enter the
    kernel) — the zero-rank fast path."""
    m, k = x.shape
    n = qw.shape[1]
    r = lb.shape[1]
    has_lr = r > 0
    qmax = 2 ** (bits - 1) - 1
    if bn is None:
        bn = fused_bn(m, k, n, r)
        if bn is None:
            raise ValueError(
                f"fused decode working set over VMEM budget for shape "
                f"(m={m}, k={k}, n={n}, r={r}); route through the tiled "
                f"act_quant → w4a8_gemm pipeline instead")
    bn_ = min(bn, n)
    bm_ = m if bm is None else min(bm, m)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_))
    in_specs = [
        pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
        pl.BlockSpec((1, k), lambda i, j: (0, 0)),
        pl.BlockSpec((k // 2, bn_), lambda i, j: (0, j)),
        pl.BlockSpec((1, bn_), lambda i, j: (0, j)),
    ]
    operands = [x, m_diag.reshape(1, k), qw, sw.reshape(1, n)]
    if has_lr:
        in_specs += [
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn_), lambda i, j: (0, j)),
        ]
        operands += [lb, la]
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax, has_lr=has_lr),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*operands)


def _gather_kernel(idx_ref, x_ref, m_ref, qw_ref, sw_ref, *rest, qmax: int,
                   has_lr: bool):
    del idx_ref  # consumed by the BlockSpec index_maps, not the body
    if has_lr:
        lb_ref, la_ref, alb_ref, ala_ref, out_ref = rest
    else:
        alb_ref, ala_ref, out_ref = rest
    x, sx, codes = smooth_quant_block(x_ref[...], m_ref[...], qmax)
    xq = codes.astype(jnp.int32)
    w = unpack_int4_block(qw_ref[...])
    acc = jax.lax.dot_general(
        xq, w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * sx * sw_ref[...]
    if has_lr:
        xlr = jnp.dot(x, lb_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        y = y + jnp.dot(xlr, la_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    # gathered adapter epilogue: this row's factors, DMA'd by table index
    t = jnp.dot(x, alb_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = y + jnp.dot(t, ala_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    out_ref[...] = y


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def w4a8_fused_gather(x, m_diag, qw, sw, lb, la, alb, ala, idx, *,
                      bits: int = 8, bn: int | None = None,
                      interpret: bool = True):
    """Fused W4A8 linear with a per-row gathered adapter epilogue.

    x: [m,k]; alb: [P,k,ra]; ala: [P,ra,n]; idx: [m] int32 adapter slots
    (slot 0 = the all-zero base adapter). Each grid step (row i, n-tile j)
    DMAs ``alb[idx[i]]`` / ``ala[idx[i], :, j·bn:]`` via scalar-prefetch
    BlockSpecs; base factors (``lb``/``la``, r may be 0) ride along as in
    ``w4a8_fused``. Returns [m, n] f32."""
    m, k = x.shape
    n = qw.shape[1]
    r = lb.shape[1]
    p, _, ra = alb.shape
    has_lr = r > 0
    qmax = 2 ** (bits - 1) - 1
    if bn is None:
        bn = fused_gather_bn(k, n, r, ra)
        if bn is None:
            raise ValueError(
                f"gathered fused working set over VMEM budget for shape "
                f"(k={k}, n={n}, r={r}, ra={ra}); take the XLA "
                f"batched-gather epilogue instead")
    bn_ = min(bn, n)
    grid = (m, pl.cdiv(n, bn_))
    in_specs = [
        pl.BlockSpec((1, k), lambda i, j, idx: (i, 0)),
        pl.BlockSpec((1, k), lambda i, j, idx: (0, 0)),
        pl.BlockSpec((k // 2, bn_), lambda i, j, idx: (0, j)),
        pl.BlockSpec((1, bn_), lambda i, j, idx: (0, j)),
    ]
    operands = [x, m_diag.reshape(1, k), qw, sw.reshape(1, n)]
    if has_lr:
        in_specs += [
            pl.BlockSpec((k, r), lambda i, j, idx: (0, 0)),
            pl.BlockSpec((r, bn_), lambda i, j, idx: (0, j)),
        ]
        operands += [lb, la]
    in_specs += [
        # the adapter gather: table entry → pool block (clamped for safety;
        # the host never hands out slots ≥ P)
        pl.BlockSpec((None, k, ra),
                     lambda i, j, idx: (jnp.minimum(idx[i], p - 1), 0, 0)),
        pl.BlockSpec((None, ra, bn_),
                     lambda i, j, idx: (jnp.minimum(idx[i], p - 1), 0, j)),
    ]
    operands += [alb, ala]
    return pl.pallas_call(
        functools.partial(_gather_kernel, qmax=qmax, has_lr=has_lr),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bn_), lambda i, j, idx: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), *operands)
