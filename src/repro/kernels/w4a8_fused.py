"""Fused W4A8 decode kernel: one VMEM pass for the whole quantized linear.

For decode / GEMV shapes (m tokens, m small) the two-kernel pipeline
(act_quant → w4a8_gemm) round-trips ``xq``/``sx``/``xlr`` through HBM
between the calls — at m ∈ {1..8} that traffic and the second dispatch
dominate the actual math. This kernel does the full chain in a single
``pallas_call``::

    x_s  = x / m_diag                     (ASER activation smoothing)
    sx   = absmax(x_s, rows) / qmax       (per-token scale)
    xq   = round(x_s / sx)                (int8 codes)
    acc  = xq · unpack_int4(qw)           (MXU int32 GEMM)
    y    = acc * sx * sw + (x_s @ L_B) @ L_A   (dequant + ASER epilogue)

Grid is over n-tiles only; K is kept whole per step (the per-token absmax
needs the full row, and at decode m the whole-K working set fits VMEM —
``repro.kernels.tuning.use_fused_decode`` gates routing on exactly that).
The smooth/quant stage is recomputed per n-tile; at decode m that is a few
KFLOP against the saved HBM round-trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .act_quant import smooth_quant_block
from .tuning import fused_bn
from .w4a8_gemm import unpack_int4_block


def _kernel(x_ref, m_ref, qw_ref, sw_ref, lb_ref, la_ref, out_ref, *,
            qmax: int):
    x, sx, codes = smooth_quant_block(x_ref[...], m_ref[...], qmax)
    xq = codes.astype(jnp.int32)
    w = unpack_int4_block(qw_ref[...])
    acc = jax.lax.dot_general(
        xq, w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * sx * sw_ref[...]
    xlr = jnp.dot(x, lb_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    y = y + jnp.dot(xlr, la_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    out_ref[...] = y


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def w4a8_fused(x, m_diag, qw, sw, lb, la, *, bits: int = 8,
               bn: int | None = None, interpret: bool = True):
    """x: [m,k]; m_diag: [k]; qw: [k//2,n] int8 packed; sw: [n]; lb: [k,r];
    la: [r,n] → y [m,n] f32. Decode shapes: m small, K whole in VMEM."""
    m, k = x.shape
    n = qw.shape[1]
    r = lb.shape[1]
    qmax = 2 ** (bits - 1) - 1
    if bn is None:
        bn = fused_bn(m, k, n, r)
        if bn is None:
            raise ValueError(
                f"fused decode working set over VMEM budget for shape "
                f"(m={m}, k={k}, n={n}, r={r}); route through the tiled "
                f"act_quant → w4a8_gemm pipeline instead")
    bn_ = min(bn, n)
    grid = (pl.cdiv(n, bn_),)
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((k // 2, bn_), lambda j: (0, j)),
            pl.BlockSpec((1, bn_), lambda j: (0, j)),
            pl.BlockSpec((k, r), lambda j: (0, 0)),
            pl.BlockSpec((r, bn_), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn_), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, m_diag.reshape(1, k), qw, sw.reshape(1, n), lb, la)
