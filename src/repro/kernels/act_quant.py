"""Fused activation smoothing + per-token int8 quant + L_B projection kernel.

One VMEM pass over the activations produces everything the W4A8 GEMM needs:
    x_s  = x / m_diag            (ASER activation smoothing)
    sx   = absmax(x_s) / qmax    (per-token scale)
    xq   = round(x_s / sx)       (int8 codes)
    xlr  = x_s @ L_B             (low-rank input, rides along in VMEM)

Grid over token tiles; K is kept whole per tile (absmax needs the full row —
for K beyond VMEM the wrapper falls back to a two-pass XLA path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def smooth_quant_block(x, m_diag, qmax: int):
    """Smooth → per-token scale → symmetric quantize, shared between this
    kernel and the fused decode kernel so the epsilon / clip conventions
    cannot drift apart. Returns (x_s f32, sx f32 [rows,1], codes f32)."""
    x = x.astype(jnp.float32) / m_diag
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    sx = jnp.maximum(amax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(x / sx), -qmax - 1, qmax)
    return x, sx, codes


def _kernel(x_ref, m_ref, lb_ref, xq_ref, sx_ref, xlr_ref, *, qmax: int):
    x, sx, codes = smooth_quant_block(x_ref[...], m_ref[...], qmax)
    xq_ref[...] = codes.astype(jnp.int8)
    sx_ref[...] = sx
    xlr_ref[...] = jnp.dot(x, lb_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def act_quant(x, m_diag, lb, *, bits: int = 8, bm: int = 256,
              interpret: bool = True):
    """x: [m,k]; m_diag: [k]; lb: [k,r] → (xq int8 [m,k], sx [m,1], xlr [m,r])."""
    m, k = x.shape
    r = lb.shape[1]
    qmax = 2 ** (bits - 1) - 1
    bm_ = min(bm, m)
    grid = (pl.cdiv(m, bm_),)
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((k, r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, k), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm_, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, m_diag.reshape(1, k), lb)
