"""jit'd public wrappers over the Pallas kernels with XLA fallbacks.

``use_pallas(True/False)`` flips between the kernel path (interpret mode on
CPU, compiled on TPU) and the pure-XLA path. The XLA fallback implements the
identical math so quantized-model behavior is bitwise-comparable up to f32
reduction order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref as _ref
from .act_quant import act_quant as _act_quant_kernel
from .w4a8_gemm import w4a8_gemm as _w4a8_kernel
from .flash_attention import flash_attention as _flash_kernel

_STATE = {"use_pallas": False, "interpret": True, "a_bits": 8}


def use_pallas(flag: bool, interpret: bool = True):
    _STATE["use_pallas"] = flag
    _STATE["interpret"] = interpret


def pallas_enabled() -> bool:
    return _STATE["use_pallas"]


def set_act_bits(bits: int):
    """Global activation bit-width for the quantized serving path
    (8 = paper's W4A8; 6/4 for the W4A6/W4A4 setups; 16 = weight-only)."""
    _STATE["a_bits"] = bits


def w4a8_linear(x, qw, sw, m_diag, lb, la, *, a_bits: int | None = None):
    """Full quantized linear: smooth → quantize → int4×int8 GEMM → dequant
    → low-rank compensation. x: [m, k] → [m, n] (f32)."""
    bits = _STATE["a_bits"] if a_bits is None else a_bits
    if bits >= 16:
        # weight-only: dequantize W and run in float (no act quant)
        from repro.core.quantizers import unpack_int4
        x_s = x.astype(jnp.float32) / m_diag[None, :]
        codes = (unpack_int4(qw.T).T if qw.shape[0] * 2 == m_diag.shape[0]
                 else qw)
        w = codes.astype(jnp.float32) * sw[None, :]
        return x_s @ w + (x_s @ lb.astype(jnp.float32)) @ la.astype(jnp.float32)
    if _STATE["use_pallas"] and bits == 8 \
            and qw.shape[0] * 2 == m_diag.shape[0]:
        r = lb.shape[1]
        if r == 0 or r % 8:
            pad = 8 if r == 0 else (-r) % 8
            lb = jnp.pad(lb, ((0, 0), (0, pad)))
            la = jnp.pad(la, ((0, pad), (0, 0)))
        xq, sx, xlr = _act_quant_kernel(x, m_diag, lb,
                                        interpret=_STATE["interpret"])
        return _w4a8_kernel(xq, sx, qw, sw, xlr, la,
                            interpret=_STATE["interpret"])
    return _ref.w4a8_linear_ref(x, qw, sw, m_diag, lb, la, a_bits=bits)


def attention(q, k, v, **kw):
    if _STATE["use_pallas"]:
        return _flash_kernel(q, k, v, interpret=_STATE["interpret"], **kw)
    return _ref.flash_attention_ref(q, k, v, **kw)
