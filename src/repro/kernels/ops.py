"""jit'd public wrappers over the Pallas kernels with XLA fallbacks.

Kernel selection and activation bit-width are explicit: every entry point
takes an ``rt:`` :class:`repro.runtime.RuntimeConfig`; ``rt=None`` means the
process default (``repro.runtime.DEFAULT_RUNTIME``). Construct a
``RuntimeConfig`` and pass it down (see ``serve.Engine`` /
``models.forward``) — the pre-PR-1 process-global mutators
(``set_act_bits`` / ``use_pallas``) are gone.

The XLA fallback implements the identical math so quantized-model behavior
is bitwise-comparable up to f32 reduction order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime import DEFAULT_RUNTIME, RuntimeConfig

from . import ref as _ref
from . import tuning as _tuning
from .act_quant import act_quant as _act_quant_kernel
from .w4a8_gemm import w4a8_gemm as _w4a8_kernel
from .w4a8_fused import w4a8_fused as _w4a8_fused_kernel
from .w4a8_fused import w4a8_fused_gather as _w4a8_gather_kernel
from .flash_attention import flash_attention as _flash_kernel
from .paged_attention import paged_decode_attention as _paged_kernel

# Pallas kernels tile the low-rank factors along r; decode-path BlockSpecs
# assume r is lane-aligned to this multiple. quantize-time packing
# (repro.quant.apply) emits already-padded factors; pad_lowrank here is the
# fallback for hand-built leaves coming through the public API.
LOWRANK_MULTIPLE = 8


def pad_lowrank(lb, la, multiple: int = LOWRANK_MULTIPLE):
    """Zero-pad the rank axis of (lb [k,r], la [r,n]) up to ``multiple``.

    Rank 0 (no compensation) is padded to one full multiple of zeros so the
    kernels never see an empty block. Zero columns/rows are mathematically
    inert. No-op when already aligned."""
    r = lb.shape[-1]
    pad = multiple if r == 0 else (-r) % multiple
    if pad == 0:
        return lb, la
    lb = jnp.pad(lb, ((0, 0),) * (lb.ndim - 1) + ((0, pad),))
    la = jnp.pad(la, ((0, pad),) + ((0, 0),) * (la.ndim - 1))
    return lb, la

def default_runtime() -> RuntimeConfig:
    """The RuntimeConfig used when callers don't pass one explicitly."""
    return DEFAULT_RUNTIME


# -- public kernel entry points ---------------------------------------------

def adapter_epilogue(x_s, alb, ala, idx, lb=None, la=None,
                     uniform: bool = False):
    """Batched-gather adapter epilogue, XLA reference path.

    Each row of ``x_s`` ([m, k], already smoothed) selects one adapter's
    factors out of the device pools (``alb`` [P, k, ra], ``ala``
    [P, ra, n]) by ``idx`` ([m] int32; slot 0 = the all-zero base adapter)
    and adds its low-rank correction. Used whenever the fused gather kernel
    isn't routed (non-decode shapes, XLA path).

    Passing the base compensation factors (``lb`` [k, r], ``la`` [r, n])
    folds them into the gathered reduction so base + adapter is ONE sum
    over r + ra — the same reduction a merged-weight checkpoint
    (``AdapterRegistry.merged_params``, which concatenates the factors the
    same way) computes through the plain leaf path. Summing the two
    epilogues separately instead differs in f32 rounding, which is enough
    to flip a near-tie argmax over a long generation; the concat form
    keeps routed XLA serving token-exact against the merged reference.
    Only the rank-axis reduction is order-sensitive: the first stage keeps
    ``x_s @ lb`` as a shared GEMM (its columns are bitwise those of
    ``x_s @ concat([lb, a])``) and the concat happens on the skinny
    ``[m, r + ra]`` intermediates, not the [m, k, r] factor stack.

    ``uniform=True`` asserts every row routes to ``idx[0]`` (statically
    known for single-sequence calls — prefill, batch-1 generate): the
    gather collapses to one slot and both stages run as plain shared
    GEMMs, the exact shapes the merged-weight path computes."""
    x_s = x_s.astype(jnp.float32)
    if uniform:
        a1 = alb[idx[0]].astype(jnp.float32)              # [k, ra]
        b1 = ala[idx[0]].astype(jnp.float32)              # [ra, n]
        t = x_s @ a1
        if lb is not None and lb.shape[-1]:
            t = jnp.concatenate([x_s @ lb.astype(jnp.float32), t], -1)
            b1 = jnp.concatenate([la.astype(jnp.float32), b1], -2)
        return t @ b1
    a = jnp.take(alb, idx, axis=0).astype(jnp.float32)    # [m, k, ra]
    b = jnp.take(ala, idx, axis=0).astype(jnp.float32)    # [m, ra, n]
    t = jnp.einsum("mk,mkr->mr", x_s, a)                  # [m, ra]
    if lb is not None and lb.shape[-1]:
        m = x_s.shape[0]
        t = jnp.concatenate([x_s @ lb.astype(jnp.float32), t], axis=-1)
        b = jnp.concatenate(
            [jnp.broadcast_to(la.astype(jnp.float32)[None], (m,) + la.shape),
             b], axis=-2)                                 # [m, r + ra, n]
    return jnp.einsum("mr,mrn->mn", t, b)


def w4a8_linear(x, qw, sw, m_diag, lb, la, *,
                rt: RuntimeConfig | None = None, a_bits: int | None = None,
                adapter=None, adapter_uniform: bool = False,
                waug=None, blb=None):
    """Full quantized linear: smooth → quantize → int4×int8 GEMM → dequant
    → low-rank compensation. x: [m, k] → [m, n] (f32).

    ``a_bits`` overrides ``rt.a_bits`` (kept for per-call sweeps).
    ``adapter=(alb, ala, idx)`` adds a per-row gathered LoRA epilogue on
    top of the base compensation: the fused gather kernel at decode shapes
    on the Pallas path, the XLA batched gather otherwise. Rank-0 base
    factors (``lb.shape[-1] == 0``) skip the base epilogue entirely.
    ``adapter_uniform=True`` promises every row carries ``idx[0]`` (set by
    single-sequence callers) and routes the shared-GEMM epilogue.

    ``waug``/``blb`` are the prepared-plan arrays the autotuner's engine
    hook attaches to leaves (``repro.kernels.autotune.prepare_leaf``):
    when present — and the call is the plain per-token W4A8 shape they
    encode (no adapter, no reference pin) — the whole chain runs as ONE
    augmented GEMM on f32 code matrices. Same math, f32 reduction order
    only; the win is that the weight reaches the dot as a whole
    loop-invariant buffer instead of a per-step slice of a scanned stack
    (see the autotune module docstring)."""
    rt = DEFAULT_RUNTIME if rt is None else rt
    bits = rt.a_bits if a_bits is None else a_bits
    if (waug is not None and adapter is None and bits == 8
            and rt.act_granularity == "per_token"
            and not rt.force_reference):
        from .autotune import _aug_linear
        return _aug_linear(x, waug, blb, m_diag)
    if bits >= 16:
        # weight-only: dequantize W and run in float (no act quant)
        from repro.core.quantizers import unpack_int4
        x_s = x.astype(jnp.float32) / m_diag[None, :]
        codes = (unpack_int4(qw.T).T if qw.shape[0] * 2 == m_diag.shape[0]
                 else qw)
        w = codes.astype(jnp.float32) * sw[None, :]
        y = x_s @ w
        if adapter is not None:
            # base + adapter factors concatenated into one rank reduction —
            # bit-matches the merged-weight reference (see adapter_epilogue)
            y = y + adapter_epilogue(x_s, *adapter, lb=lb, la=la,
                                     uniform=adapter_uniform)
        elif lb.shape[-1]:
            y = y + (x_s @ lb.astype(jnp.float32)) @ la.astype(jnp.float32)
        return y
    if rt.use_pallas and not rt.force_reference and bits == 8 \
            and rt.act_granularity == "per_token" \
            and qw.shape[0] * 2 == m_diag.shape[0]:
        m, kd = x.shape
        n = qw.shape[1]
        if lb.shape[1]:
            lb, la = pad_lowrank(lb, la)  # no-op for pack-time-padded leaves
        r = lb.shape[1]                   # 0 = zero-rank fast path
        if adapter is not None:
            alb, ala, idx = adapter
            ra = alb.shape[-1]
            if rt.fused_decode and _tuning.use_fused_gather(m, kd, n, r, ra):
                # decode fast path: base linear + gathered adapter epilogue
                # in one pallas_call (scalar-prefetch factor DMA)
                return _w4a8_gather_kernel(x, m_diag, qw, sw, lb, la,
                                           alb, ala, idx,
                                           interpret=rt.interpret)
        # The router owns the tile choice: fused_bn is computed ONCE here,
        # under the caller's autotune mode, and threaded through to the
        # kernel — the kernel's own bn=None re-derivation runs under the
        # default budget and would silently discard a measured winner.
        fused_bn = (_tuning.fused_bn(m, kd, n, r, autotune=rt.autotune)
                    if rt.fused_decode and m <= _tuning.DECODE_M_MAX
                    else None)
        fused_mt = None
        if (fused_bn is None and rt.fused_decode and rt.autotune != "off"
                and m > _tuning.DECODE_M_MAX):
            # tiled-m fused prefill: autotune-gated (the modeled tables
            # keep prefill on the two-kernel pipeline, so "off" stays
            # bit-for-bit today's routing)
            fused_mt = _tuning.fused_tiles(m, kd, n, r,
                                           autotune=rt.autotune)
        if fused_bn is not None:
            # decode/GEMV fast path: one pallas_call, no xq/sx/xlr HBM
            # round-trip between kernels
            y = _w4a8_fused_kernel(x, m_diag, qw, sw, lb, la, bn=fused_bn,
                                   interpret=rt.interpret)
        elif fused_mt is not None:
            bm_f, bn_f = fused_mt
            y = _w4a8_fused_kernel(x, m_diag, qw, sw, lb, la, bn=bn_f,
                                   bm=bm_f, interpret=rt.interpret)
        else:
            if r == 0:
                # the tiled pipeline threads xlr between its two kernels;
                # keep the padded zero block there (decode shapes — the
                # ones that matter — took the fast path above)
                lb, la = pad_lowrank(lb, la)
                r = lb.shape[1]
            bm, bn, bk = _tuning.select_gemm_blocks(m, kd, n, r,
                                                    autotune=rt.autotune)
            xq, sx, xlr = _act_quant_kernel(x, m_diag, lb,
                                            interpret=rt.interpret)
            y = _w4a8_kernel(xq, sx, qw, sw, xlr, la, bm=bm, bn=bn, bk=bk,
                             interpret=rt.interpret)
        if adapter is not None:
            y = y + adapter_epilogue(x.astype(jnp.float32) / m_diag[None, :],
                                     alb, ala, idx, uniform=adapter_uniform)
        return y
    if adapter is not None:
        # suppress the in-ref base epilogue (rank-0 factors) and fold it
        # into the gathered reduction instead — one concatenated sum over
        # r + ra, bit-matching the merged-weight reference
        y = _ref.w4a8_linear_ref(x, qw, sw, m_diag, lb[..., :0], la[:0],
                                 a_bits=bits, granularity=rt.act_granularity)
        return y + adapter_epilogue(x.astype(jnp.float32) / m_diag[None, :],
                                    *adapter, lb=lb, la=la,
                                    uniform=adapter_uniform)
    return _ref.w4a8_linear_ref(x, qw, sw, m_diag, lb, la, a_bits=bits,
                                granularity=rt.act_granularity)


def attention(q, k, v, *, rt: RuntimeConfig | None = None, **kw):
    rt = DEFAULT_RUNTIME if rt is None else rt
    if rt.use_pallas and not rt.force_reference:
        return _flash_kernel(q, k, v, interpret=rt.interpret, **kw)
    return _ref.flash_attention_ref(q, k, v, **kw)


def paged_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                    k_scale=None, v_scale=None, logit_cap: float = 0.0,
                    rt: RuntimeConfig | None = None):
    """Paged-KV decode attention over a global block pool.

    q: [b, 1, hq, hd]; pools: [num_blocks, block_size, hkv, hd];
    block_tables: [b, blocks_per_seq] int32 (sentinel = num_blocks);
    kv_len: [b] int32 valid prefix per row. ``k_scale``/``v_scale``
    ([num_blocks, block_size, hkv] f32): quantized pools — the pools hold
    int8 codes and the kernel runs its fused dequant epilogue per block.

    Returns [b, 1, hq, hd] from the Pallas paged-gather kernel, or ``None``
    when the runtime / tuning model routes this shape to the XLA gather
    fallback (the caller — ``models.attention._paged_attention`` — owns
    that path; the ``None`` contract matches the sharded-decode helper).
    """
    rt = DEFAULT_RUNTIME if rt is None else rt
    if not rt.use_pallas or rt.force_reference:
        return None
    b, _, hq, hd = q.shape
    bs, hkv = k_pool.shape[1], k_pool.shape[2]
    quantized = k_scale is not None
    if hq % hkv != 0:
        return None
    if not _tuning.use_paged_kernel(b, block_tables.shape[1], bs,
                                    hq // hkv, hd, quantized=quantized,
                                    autotune=rt.autotune):
        return None
    return _paged_kernel(q, k_pool, v_pool, block_tables, kv_len,
                         k_scale, v_scale,
                         logit_cap=logit_cap, interpret=rt.interpret)
