"""AdamW with fp32 master state, global-norm clipping and cosine schedule.

No external optimizer libs offline; implemented directly on pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 20
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"   # bf16 halves optimizer HBM (340B-scale)


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: any
    nu: any


def init_opt_state(params, dtype=jnp.float32) -> OptState:
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas
    lr = schedule(cfg, step.astype(jnp.float32))

    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_n = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_n = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        m_hat = m_n / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_n / (1 - b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:     # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_n.astype(sdt), v_n.astype(sdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in new])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
