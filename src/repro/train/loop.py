"""Training step + loop: CE loss, gradient accumulation, aux-loss weighting.

``make_train_step`` builds the jit-able step used both by the CPU examples
and the multi-pod dry-run (pjit with explicit shardings from repro.sharding).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.metrics import cross_entropy
from repro.models import ModelConfig, forward
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    aux_weight: float = 0.01       # MoE load-balance loss weight
    grad_accum: int = 1            # microbatches per step
    loss_dtype: str = "float32"


def loss_fn(params, cfg: ModelConfig, batch, *, aux_weight: float,
            encoder_out=None, mrope_positions=None):
    tokens = batch["tokens"]
    logits, _, aux = forward(params, cfg, tokens[:, :-1], train=True,
                             encoder_out=encoder_out,
                             mrope_positions=mrope_positions)
    ce = cross_entropy(logits, tokens[:, 1:],
                       batch.get("mask", None))
    return ce + aux_weight * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns step(params, opt_state, batch) → (params, opt_state, metrics).

    With grad_accum > 1 the batch's leading axis is split into microbatches
    scanned sequentially (activation memory / straggler smoothing), gradients
    averaged before the optimizer update.
    """
    def grads_of(params, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch,
                                   aux_weight=tcfg.aux_weight)
        return loss, ce, aux, grads

    def step(params, opt_state: OptState, batch):
        if tcfg.grad_accum > 1:
            na = tcfg.grad_accum
            micro = jax.tree.map(
                lambda x: x.reshape((na, x.shape[0] // na) + x.shape[1:]),
                batch)

            def acc_fn(carry, mb):
                g_sum, l_sum = carry
                loss, ce, aux, g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, g_sum, g), l_sum + ce), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (g_sum, ce_sum), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / na, g_sum)
            ce = ce_sum / na
            aux = jnp.zeros((), jnp.float32)
        else:
            _, ce, aux, grads = grads_of(params, batch)
        params, opt_state, m = adamw_update(tcfg.opt, params, grads, opt_state)
        m = dict(m, loss=ce, aux=aux)
        return params, opt_state, m

    return step


def train_step_for_dryrun(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """(params, opt_state, batch) signature used by launch/dryrun.py."""
    return make_train_step(cfg, tcfg)
