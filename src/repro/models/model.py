"""LM wrapper: embeddings → (prefix + scanned groups [+ shared block]) → head.

Decoder-only and encoder-decoder (whisper) variants share this module. The
forward has three modes:
  * ``forward(params, tokens)``                  — train / logits over full seq
  * ``forward(..., caches=...)``                 — decode step with caches
  * ``encode(params, frames)``                   — enc-dec encoder pass
Calibration capture (for PTQ) lives in repro.quant.calibrate and reuses
these same functions with probes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .attention import (KVCache, PagedKVCache, attention, attn_params,
                        init_cache, init_paged_cache)
from .config import ModelConfig
from .layers import apply_norm, apply_mlp, dense, linear_params, mlp_params, norm_params, softcap
from .transformer import (BlockSpec, block_forward, block_params, group_blocks,
                          group_params, init_block_cache, shared_block_forward,
                          shared_block_params)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


from .layers import BATCH, constrain as _constrain_impl


def _constrain(x, *spec):
    return _constrain_impl(x, *spec)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, d), jnp.float32)
                  * d ** -0.5).astype(dt),
        "final_norm": norm_params(cfg.norm, d, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = linear_params(keys[1], d, cfg.vocab_size, dt)

    specs = group_blocks(cfg)

    # leading dense-FFN layers (MoE archs)
    if cfg.n_dense_layers:
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        p["prefix"] = [block_params(jax.random.fold_in(keys[2], i), dense_cfg,
                                    BlockSpec("attn"), dt)
                       for i in range(cfg.n_dense_layers)]

    n_groups = _n_scanned_groups(cfg)
    gkeys = jax.random.split(keys[3], n_groups)
    p["groups"] = jax.vmap(lambda k: group_params(k, cfg, dt))(gkeys)

    if cfg.family == "hybrid":
        p["shared"] = shared_block_params(keys[4], cfg, dt)

    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, n_layers=cfg.n_encoder_layers,
                                      local_global_period=0, sliding_window=0)
        ekeys = jax.random.split(keys[5], cfg.n_encoder_layers)
        p["encoder"] = {
            "groups": jax.vmap(lambda k: group_params(k, enc_cfg, dt))(ekeys),
            "final_norm": norm_params(cfg.norm, d, dt),
            "pos_embed": (jax.random.normal(keys[6], (cfg.encoder_seq, d),
                                            jnp.float32) * 0.02).astype(dt),
        }
        ckeys = jax.random.split(keys[7], _n_scanned_groups(cfg))
        p["cross"] = jax.vmap(
            lambda k: {"norm": norm_params(cfg.norm, d, dt),
                       "attn": attn_params(k, cfg, dt)})(ckeys)
    return p


def _n_scanned_groups(cfg: ModelConfig) -> int:
    n = cfg.n_layers - cfg.n_dense_layers
    g = cfg.group_size
    assert n % g == 0, f"{cfg.name}: {n} layers not divisible by group {g}"
    return n // g


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                kv_dtype: str = "bf16"):
    """Cache pytree matching the forward structure.

    ``kv_dtype``: "bf16" (native, the model dtype) or "int8"/"int4" —
    quantized KV lanes with per-token-per-head scales (see
    :class:`repro.models.attention.KVCache`). Quantized caches are
    attention-family only: SSM/hybrid running state and enc-dec cross
    caches are not quantizable, and ring buffers would re-quantize on
    wraparound.
    """
    if kv_dtype != "bf16":
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                f"quantized KV cache (kv_dtype={kv_dtype!r}) not supported "
                f"for family {cfg.family!r} (SSM/hybrid state and enc-dec "
                f"cross caches are not int8-pageable); use kv_dtype='bf16'")
        if cfg.sliding_window > 0 or cfg.local_global_period > 0:
            raise NotImplementedError(
                f"quantized KV cache (kv_dtype={kv_dtype!r}) not supported "
                f"with sliding-window (ring-buffer) layers; use "
                f"kv_dtype='bf16'")
    dt = _dtype(cfg)
    specs = group_blocks(cfg)
    caches: dict = {}
    if cfg.n_dense_layers:
        caches["prefix"] = [init_block_cache(cfg, BlockSpec("attn"), batch,
                                             max_len, dt, kv_dtype=kv_dtype)
                            for _ in range(cfg.n_dense_layers)]
    n_groups = _n_scanned_groups(cfg)

    def one_group(_):
        out = [init_block_cache(cfg, s, batch, max_len, dt,
                                kv_dtype=kv_dtype) for s in specs]
        if cfg.family == "hybrid":
            win = cfg.sliding_window
            out.append(init_cache(cfg, batch, max_len, window=win, dtype=dt))
        return out

    caches["groups"] = jax.vmap(one_group)(jnp.arange(n_groups))
    if cfg.family == "encdec":
        # cross-attention KV computed at encode time, stored per group
        def one_cross(_):
            return init_cache(cfg, batch, cfg.encoder_seq, dtype=dt)
        caches["cross"] = jax.vmap(one_cross)(jnp.arange(n_groups))
    return caches


def init_paged_caches(cfg: ModelConfig, num_blocks: int, block_size: int,
                      kv_dtype: str = "bf16"):
    """Block-paged cache pytree: per-layer physical pools, no batch axis.

    Structurally mirrors :func:`init_caches` (prefix list + vmapped scanned
    groups) but every KV leaf is a :class:`PagedKVCache` pool of
    ``num_blocks × block_size`` token slots shared by all in-flight
    requests; per-request block tables are passed to ``forward`` separately.
    Only attention families qualify (SSM/hybrid state and ring buffers are
    not pageable), matching the ragged-serving gate in ``serve.Engine``.
    """
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(
            f"paged KV cache not supported for family {cfg.family!r}")
    if cfg.sliding_window > 0 or cfg.local_global_period > 0:
        raise NotImplementedError(
            "paged KV cache not supported with sliding-window layers")
    dt = _dtype(cfg)
    specs = group_blocks(cfg)
    caches: dict = {}
    if cfg.n_dense_layers:
        caches["prefix"] = [init_paged_cache(cfg, num_blocks, block_size, dt,
                                             kv_dtype=kv_dtype)
                            for _ in range(cfg.n_dense_layers)]

    def one_group(_):
        return [init_paged_cache(cfg, num_blocks, block_size, dt,
                                 kv_dtype=kv_dtype)
                for _ in specs]

    caches["groups"] = jax.vmap(one_group)(jnp.arange(_n_scanned_groups(cfg)))
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

class LayerList(list):
    """Marker for the prepared (unstacked) group layout.

    A ``LayerList`` holds one group-params pytree per scanned group instead
    of a single stacked pytree. ``_scan_groups`` iterates it with a Python
    loop at trace time so each group's weights reach their dots as whole
    loop-invariant buffers — inside a decode ``lax.scan`` that is the
    difference between the backend's fast GEMM path and a naive
    slice-fused loop, because XLA never hoists per-iteration slices of a
    stacked operand out of a while body (see docs/serving_perf.md).
    Produced by ``repro.kernels.autotune.prepare_params``.
    """


jax.tree_util.register_pytree_node(
    LayerList,
    lambda xs: (list(xs), None),
    lambda _, children: LayerList(children))


def _scan_groups(params, cfg: ModelConfig, x, x0, *, positions,
                 mrope_positions, caches, cross_ctx, train: bool,
                 ragged: bool = False, block_tables=None, adapter_idx=None,
                 with_tape: bool = False, rt=None):
    """lax.scan over the stacked groups."""
    specs = group_blocks(cfg)
    shared_p = params.get("shared")
    cross_p = params.get("cross")

    def group_fn(carry, scanned):
        h, aux = carry
        h = _constrain(h, BATCH,
                       "model" if cfg.seq_shard_residual else None, None)
        gp = scanned["p"]
        gc = scanned.get("c")
        cp = scanned.get("cross_p")
        cc = scanned.get("cross_c")
        tape_g = {} if with_tape else None
        new_caches = []
        for i, spec in enumerate(specs):
            c_i = gc[i] if gc is not None else None
            btape = None
            if tape_g is not None:
                tape_g[f"b{i}"] = {}
                btape = tape_g[f"b{i}"]
            h, nc, a = block_forward(gp[i], cfg, spec, h, positions=positions,
                                     mrope_positions=mrope_positions, cache=c_i,
                                     ragged=ragged, block_tables=block_tables,
                                     adapter_idx=adapter_idx,
                                     tape=btape, rt=rt)
            aux = aux + a
            new_caches.append(nc if nc is not None else c_i)
            if spec.shared_after and shared_p is not None:
                sc = gc[len(specs)] if gc is not None else None
                stape = None
                if tape_g is not None:
                    tape_g["shared"] = {}
                    stape = tape_g["shared"]
                h, nsc = shared_block_forward(
                    shared_p, cfg, h, x0, positions=positions, cache=sc,
                    window=cfg.sliding_window, ragged=ragged,
                    block_tables=block_tables, tape=stape, rt=rt)
                if gc is not None:
                    new_caches.append(nsc if nsc is not None else sc)
        if cp is not None:
            # whisper decoder cross-attention (after self block, pre-norm)
            hn = apply_norm(cfg.norm, cp["norm"], h)
            if cc is not None:
                kv = (cc.k, cc.v)
            else:
                b, es = cross_ctx.shape[0], cross_ctx.shape[1]
                k = dense(cp["attn"]["wk"], cross_ctx, rt=rt).reshape(
                    b, es, cfg.n_kv_heads, cfg.head_dim)
                v = dense(cp["attn"]["wv"], cross_ctx, rt=rt).reshape(
                    b, es, cfg.n_kv_heads, cfg.head_dim)
                kv = (k, v)
            a, _ = attention(cp["attn"], cfg, hn, positions=positions,
                             cross_kv=kv, rt=rt)
            h = h + a
        out = {"c": new_caches} if gc is not None else {}
        if tape_g is not None:
            out["tape"] = tape_g
        return (h, aux), out

    groups = params["groups"]
    if isinstance(groups, LayerList):
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for gi, gp in enumerate(groups):
            scanned_i = {"p": gp}
            if caches is not None:
                scanned_i["c"] = jax.tree.map(lambda a, gi=gi: a[gi],
                                              caches["groups"])
            if cross_p is not None:
                scanned_i["cross_p"] = jax.tree.map(lambda a, gi=gi: a[gi],
                                                    cross_p)
                if caches is not None and "cross" in caches:
                    scanned_i["cross_c"] = jax.tree.map(
                        lambda a, gi=gi: a[gi], caches["cross"])
            carry, out_i = group_fn(carry, scanned_i)
            outs.append(out_i)
        (x, aux) = carry
        scanned_out = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return (x, aux, scanned_out.get("c"), scanned_out.get("tape"))

    scanned_in = {"p": groups}
    if caches is not None:
        scanned_in["c"] = caches["groups"]
    if cross_p is not None:
        scanned_in["cross_p"] = cross_p
        if caches is not None and "cross" in caches:
            scanned_in["cross_c"] = caches["cross"]

    fn = group_fn
    if train and cfg.remat:
        fn = jax.checkpoint(group_fn, prevent_cse=False)
    (x, aux), scanned_out = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                         scanned_in,
                                         unroll=(_n_scanned_groups(cfg)
                                                 if cfg.scan_unroll else 1))
    new_caches = scanned_out.get("c")
    return x, aux, new_caches, scanned_out.get("tape")


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            positions: jnp.ndarray | None = None,
            mrope_positions: jnp.ndarray | None = None,
            caches=None, encoder_out: jnp.ndarray | None = None,
            train: bool = False, ragged: bool = False,
            block_tables: jnp.ndarray | None = None,
            adapter_idx: jnp.ndarray | None = None, tape=None, rt=None):
    """tokens: [b, s] int32 → logits [b, s, vocab].

    Returns (logits, new_caches, aux_loss). If ``tape`` is a dict it is
    filled with per-linear calibration stats (see repro.quant.calibrate).
    ``rt``: optional :class:`repro.runtime.RuntimeConfig` steering the
    quantized-leaf serving path (act bits, pallas vs XLA). It is plain
    Python config consumed at trace time — never a traced value.
    ``ragged=True`` (decode with caches): ``positions`` carries per-row
    global positions and KV writes/masks are per row — see
    :func:`repro.models.attention.attention`.
    ``block_tables`` ([b, blocks_per_seq] int32): required when ``caches``
    holds :class:`PagedKVCache` pools — maps each row's logical blocks to
    physical pool blocks; the same table is used by every layer.
    ``adapter_idx`` ([b] int32): per-row adapter-pool slots for params that
    carry installed adapter pools (``serve.adapters.install_pools``); every
    pooled quantized linear gathers that row's LoRA factors (slot 0 = the
    all-zero base adapter). Like ``block_tables`` it is closed over by the
    group scan, not scanned.
    """
    if ragged and positions is None:
        raise ValueError("ragged forward needs explicit per-row positions")
    b, s = tokens.shape
    if positions is None:
        self_caches = ({k: v for k, v in caches.items() if k != "cross"}
                       if caches is not None else None)
        start = caches_length(self_caches) if caches is not None else 0
        positions = start + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    x = params["embed"][tokens].astype(_dtype(cfg))
    x = _constrain(x, BATCH, None, None)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # whisper/gemma scale
    x0 = x

    new_prefix = None
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_dense_layers:
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        new_prefix = []
        if tape is not None:
            tape["prefix"] = []
        for i, bp in enumerate(params["prefix"]):
            c_i = caches["prefix"][i] if caches is not None else None
            btape = {} if tape is not None else None
            x, nc, a = block_forward(bp, dense_cfg, BlockSpec("attn"), x,
                                     positions=positions,
                                     mrope_positions=mrope_positions, cache=c_i,
                                     ragged=ragged, block_tables=block_tables,
                                     adapter_idx=adapter_idx,
                                     tape=btape, rt=rt)
            if tape is not None:
                tape["prefix"].append(btape)
            aux += a
            new_prefix.append(nc)

    cross_ctx = encoder_out if cfg.family == "encdec" else None

    x, aux_s, new_group_caches, group_tape = _scan_groups(
        params, cfg, x, x0, positions=positions,
        mrope_positions=mrope_positions, caches=caches,
        cross_ctx=cross_ctx, train=train, ragged=ragged,
        block_tables=block_tables, adapter_idx=adapter_idx,
        with_tape=tape is not None, rt=rt)
    aux = aux + aux_s
    if tape is not None:
        tape["groups"] = group_tape

    x = apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = dense(params["head"], x, rt=rt)
    # keep logits vocab-sharded on the model axis: the f32 softmax/CE path
    # otherwise materializes [tokens, vocab] per device (75GB/dev at 4k×256)
    logits = _constrain(logits, ("pod", "data"), None, "model")
    logits = softcap(logits, cfg.final_softcap)

    new_caches = None
    if caches is not None:
        new_caches = dict(caches)
        new_caches["groups"] = new_group_caches
        if new_prefix is not None:
            new_caches["prefix"] = new_prefix
    return logits, new_caches, aux


def caches_length(caches):
    """Current decode position from any KV cache in the tree.

    Paged pools are skipped (pool-wide ``length`` is not a per-request
    position); paged callers always pass explicit positions instead.
    """
    nodes = jax.tree.leaves(caches, is_leaf=lambda x: isinstance(x, KVCache))
    for c in nodes:
        if isinstance(c, KVCache):
            # scanned caches have a leading group axis on length
            return c.length.reshape(-1)[0]
    return jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jnp.ndarray, tape=None, rt=None):
    """frames: [b, enc_seq, d] precomputed conv-frontend embeddings (stub).

    ``tape``: optional dict filled with per-layer calibration stats under
    ["encoder"]["groups"] (same convention as forward()).
    """
    enc = params["encoder"]
    x = frames.astype(_dtype(cfg)) + enc["pos_embed"][None].astype(_dtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_cfg = dataclasses.replace(cfg, n_layers=cfg.n_encoder_layers,
                                  local_global_period=0, sliding_window=0)
    spec = BlockSpec("attn")

    with_tape = tape is not None

    def group_fn(h, gp):
        # bidirectional: causal=False via cross_kv-style call on itself
        t_b = {"attn": {}, "mlp": {}} if with_tape else None
        hn = apply_norm(enc_cfg.norm, gp[0]["attn_norm"], h)
        k = dense(gp[0]["attn"]["wk"], hn, rt=rt).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        v = dense(gp[0]["attn"]["wv"], hn, rt=rt).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        a, _ = attention(gp[0]["attn"], enc_cfg, hn, positions=positions,
                         cross_kv=(k, v),
                         tape=t_b["attn"] if with_tape else None, rt=rt)
        h = h + a
        m = apply_mlp(enc_cfg.mlp, gp[0]["mlp"],
                      apply_norm(enc_cfg.norm, gp[0]["mlp_norm"], h),
                      t_b["mlp"] if with_tape else None, rt=rt)
        return h + m, (t_b if with_tape else {})

    x, t_stack = jax.lax.scan(group_fn, x, enc["groups"])
    if with_tape:
        tape["encoder"] = {"groups": {"b0": t_stack}}
    return apply_norm(cfg.norm, enc["final_norm"], x)


def prepare_cross_caches(params, cfg: ModelConfig, encoder_out: jnp.ndarray,
                         caches, rt=None):
    """Precompute per-decoder-group cross KV from encoder output."""
    b, s, _ = encoder_out.shape

    def one(cp, cc):
        k = dense(cp["attn"]["wk"], encoder_out, rt=rt).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim).astype(cc.k.dtype)
        v = dense(cp["attn"]["wv"], encoder_out, rt=rt).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim).astype(cc.v.dtype)
        return KVCache(k, v, jnp.asarray(s, jnp.int32), cc.pos)

    caches = dict(caches)
    caches["cross"] = jax.vmap(one)(params["cross"], caches["cross"])
    return caches
