"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Top-k softmax routing (renormalized over the selected experts), deterministic
static shapes via per-expert capacity, scatter/gather dispatch (no [T, E, cap]
one-hot einsum — that pattern inflates HLO FLOPs quadratically and would
poison the roofline's useful-FLOPs ratio). Experts are stacked on the leading
axis so they shard cleanly over the "model" mesh axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, mlp_params, apply_mlp


def moe_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    e, d, dff = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 2 + cfg.n_shared_experts)
    std = d ** -0.5
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e), jnp.float32) * std
                         ).astype(jnp.float32)},
        # stacked expert weights [E, d, dff] / [E, dff, d] (swiglu)
        "experts": {
            "gate": (jax.random.normal(ks[1], (e, d, dff), jnp.float32) * std).astype(dtype),
            "up": (jax.random.normal(jax.random.fold_in(ks[1], 1), (e, d, dff),
                                     jnp.float32) * std).astype(dtype),
            "down": (jax.random.normal(jax.random.fold_in(ks[1], 2), (e, dff, d),
                                       jnp.float32) * dff ** -0.5).astype(dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(ks[2], "swiglu", d,
                                 cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def _positions_in_expert(flat_e: jnp.ndarray, e: int,
                         chunk: int = 4096) -> jnp.ndarray:
    """Exclusive rank of each entry within its expert, computed chunkwise."""
    n = flat_e.shape[0]
    if n <= chunk:
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(oh, axis=0) - oh
        return jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    pad = (-n) % chunk
    fe = jnp.pad(flat_e, (0, pad), constant_values=e)  # e is out-of-range → 0 row
    fec = fe.reshape(-1, chunk)

    def step(counts, idx_chunk):
        oh = jax.nn.one_hot(idx_chunk, e, dtype=jnp.int32)
        within = jnp.cumsum(oh, axis=0) - oh + counts[None, :]
        p = jnp.take_along_axis(within, jnp.clip(idx_chunk, 0, e - 1)[:, None],
                                axis=1)[:, 0]
        return counts + jnp.sum(oh, axis=0), p

    _, pos = jax.lax.scan(step, jnp.zeros((e,), jnp.int32), fec)
    return pos.reshape(-1)[:n]


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    # round up to a lane-friendly multiple
    return max(8, -(-cap // 8) * 8)


def _q_expert_mm(buf: jnp.ndarray, q: dict, rt=None) -> jnp.ndarray:
    """Per-expert W4A8 matmul: buf [e, cap, d] × quantized stack → [e, cap, f]."""
    from repro.kernels import ops as kops
    dt = buf.dtype
    y = jax.vmap(lambda xb, qw, sw, m, lb, la:
                 kops.w4a8_linear(xb, qw, sw, m, lb, la, rt=rt))(
        buf, q["qw"], q["sw"], q["m"], q["lb"], q["la"])
    return y.astype(dt)


def moe_block(p, cfg: ModelConfig, x: jnp.ndarray, tape=None, rt=None):
    """x: [b, s, d] → [b, s, d]. Returns (y, aux) with load-balance aux loss.

    Two dispatch paths:
      * shard_map EP (production): experts stay sharded on the "model" mesh
        axis; activations (replicated over "model" under TP) are dispatched
        *locally* to the resident experts and partial outputs are psum'd —
        the only collective is the same [tokens, d] all-reduce a dense TP
        MLP already pays. Chosen when a mesh with a "model" axis is active
        and the expert count divides it.
      * global scatter (portable): single-device / CPU tests.
    The scatter-into-sharded-buffer path is never used: XLA's SPMD partition
    of token→expert scatter degenerates to all-gathering the dispatch buffer
    (measured 236 s of collectives per step on kimi-k2 train_4k — see
    EXPERIMENTS.md §Perf iteration 1).
    """
    from .layers import _active_mesh
    mesh = _active_mesh()
    if (mesh is not None and "model" in mesh.axis_names and tape is None
            and cfg.n_experts % dict(zip(mesh.axis_names, mesh.devices.shape))["model"] == 0):
        return _moe_block_shard_map(p, cfg, x, mesh, rt=rt)
    return _moe_block_global(p, cfg, x, tape, rt=rt)


def _moe_block_global(p, cfg: ModelConfig, x: jnp.ndarray, tape=None, rt=None):
    """Portable scatter-based dispatch (single device, calibration)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])       # [t, e]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # [t, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert queue — chunked exclusive
    # cumsum keeps the one-hot intermediate at [chunk, E] instead of [t*k, E]
    flat_e = gate_idx.reshape(-1)                               # [t*k]
    pos = _positions_in_expert(flat_e, e)
    keep = pos < cap                                            # dropped beyond capacity

    # scatter tokens into [e, cap, d]
    dst = flat_e * cap + jnp.where(keep, pos, cap - 1)          # clamp; masked below
    upd = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((e * cap, d), xt.dtype).at[dst].add(upd)
    buf = buf.reshape(e, cap, d)

    # per-expert SwiGLU on the stacked buffer
    if tape is not None:
        from .layers import LinStats
        cnt = jnp.zeros((e,), jnp.float32).at[flat_e].add(keep.astype(jnp.float32))
        bf = buf.astype(jnp.float32)
        tape["experts"] = {
            "gate": LinStats(jnp.einsum("ecd,ecf->edf", bf, bf),
                             jnp.sum(jnp.abs(bf), axis=1),
                             jnp.max(jnp.abs(bf), axis=1), cnt),
        }
    ge = p["experts"]["gate"]
    if isinstance(ge, dict) and "qw" in ge:        # W4A8-quantized experts
        h_gate = _q_expert_mm(buf, ge, rt)
        h_up = _q_expert_mm(buf, p["experts"]["up"], rt)
    else:
        h_gate = jnp.einsum("ecd,edf->ecf", buf, ge.astype(buf.dtype))
        h_up = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["up"].astype(buf.dtype))
    h = jax.nn.silu(h_gate) * h_up
    if tape is not None:
        from .layers import LinStats
        hf = h.astype(jnp.float32)
        tape["experts"]["up"] = tape["experts"]["gate"]
        tape["experts"]["down"] = LinStats(
            jnp.einsum("ecf,ecg->efg", hf, hf), jnp.sum(jnp.abs(hf), axis=1),
            jnp.max(jnp.abs(hf), axis=1), tape["experts"]["gate"].count)
    de = p["experts"]["down"]
    if isinstance(de, dict) and "qw" in de:
        y_e = _q_expert_mm(h, de, rt)
    else:
        y_e = jnp.einsum("ecf,efd->ecd", h, de.astype(h.dtype))

    # gather back with gate weights
    y_flat = y_e.reshape(e * cap, d)
    gathered = y_flat[dst] * (gate_vals.reshape(-1) * keep).astype(y_flat.dtype)[:, None]
    y = jnp.sum(gathered.reshape(t, k, d), axis=1)

    if cfg.n_shared_experts:
        shared_tape = {} if tape is not None else None
        y = y + apply_mlp("swiglu", p["shared"], xt, shared_tape, rt=rt)
        if tape is not None:
            tape["shared"] = shared_tape

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (production path)
# ---------------------------------------------------------------------------

def _moe_block_shard_map(p, cfg: ModelConfig, x: jnp.ndarray, mesh, rt=None):
    """EP dispatch under TP-replicated activations.

    Each "model"-axis rank holds e_loc = E / tp experts. Activations x are
    replicated over "model" (standard TP), so each rank scatters the tokens
    routed to ITS experts into a local [e_loc, cap, d] buffer, runs the
    expert FFN locally, combines locally, and psums partial outputs over
    "model". Batch stays sharded over (pod, data) — those axes pass through.
    """
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    names = mesh.axis_names
    tp = dict(zip(names, mesh.devices.shape))["model"]
    e_loc = e // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    bspec = batch_axes if batch_axes else None

    # router on replicated activations (outside shard_map: plain jit code)
    xt = x.reshape(b * s, d)
    logits = (xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    quant = isinstance(p["experts"]["gate"], dict)
    # per-expert leaf specs: expert axis sharded on "model"
    if quant:
        espec = {"gate": _qspec(), "up": _qspec(), "down": _qspec()}
    else:
        espec = {"gate": P("model", None, None), "up": P("model", None, None),
                 "down": P("model", None, None)}

    sizes = dict(zip(names, mesh.devices.shape))
    data_sh = 1
    for a in batch_axes:
        data_sh *= sizes[a]
    t_local = (b * s) // data_sh          # tokens seen by each model-rank
    cap = _capacity(cfg, t_local)

    @partial(shard_map, mesh=mesh,
             in_specs=(espec,
                       P(bspec, None),       # xt [t, d] (batch-sharded)
                       P(bspec, None),       # gate_vals
                       P(bspec, None)),      # gate_idx
             out_specs=P(bspec, None),
             check_rep=False)
    def ep(experts, xt_l, gv_l, gi_l):
        rank = jax.lax.axis_index("model")
        t_l = xt_l.shape[0]
        lo = rank * e_loc
        flat_e = gi_l.reshape(-1)
        local = (flat_e >= lo) & (flat_e < lo + e_loc)
        le = jnp.where(local, flat_e - lo, e_loc)       # e_loc = out of range
        pos = _positions_in_expert(le, e_loc)
        keep = local & (pos < cap)
        dst = jnp.where(keep, le * cap + pos, e_loc * cap)
        upd = jnp.repeat(xt_l, k, axis=0) * keep[:, None].astype(xt_l.dtype)
        buf = jnp.zeros((e_loc * cap + 1, xt_l.shape[1]), xt_l.dtype
                        ).at[dst].add(upd)[:-1].reshape(e_loc, cap, -1)

        if quant:
            h = jax.nn.silu(_q_expert_mm(buf, experts["gate"], rt)) \
                * _q_expert_mm(buf, experts["up"], rt)
            y_e = _q_expert_mm(h.astype(buf.dtype), experts["down"], rt)
        else:
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                       experts["gate"].astype(buf.dtype))) \
                * jnp.einsum("ecd,edf->ecf", buf, experts["up"].astype(buf.dtype))
            y_e = jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(h.dtype))

        y_flat = jnp.concatenate(
            [y_e.reshape(e_loc * cap, -1),
             jnp.zeros((1, y_e.shape[-1]), y_e.dtype)], axis=0)
        gathered = y_flat[dst] * (gv_l.reshape(-1)
                                  * keep.astype(jnp.float32)
                                  ).astype(y_flat.dtype)[:, None]
        y_partial = jnp.sum(gathered.reshape(t_l, k, -1), axis=1)
        return jax.lax.psum(y_partial, "model")

    y = ep(p["experts"], xt, gate_vals.astype(jnp.float32), gate_idx)

    if cfg.n_shared_experts:
        y = y + apply_mlp("swiglu", p["shared"], xt, rt=rt)
    return y.reshape(b, s, d), aux


def _qspec():
    from jax.sharding import PartitionSpec as P
    return {"qw": P("model", None, None), "sw": P("model", None),
            "m": P("model", None), "lb": P("model", None, None),
            "la": P("model", None, None)}
