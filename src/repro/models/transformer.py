"""Block assembly: repeating groups, scan-over-layers, block forward.

A model is: [prefix blocks (python-level, e.g. leading dense-FFN layers of
MoE archs)] + [n_groups × scanned group] (+ one shared attention block for
zamba2-style hybrids, whose params live outside the scan).

Block kinds:
  attn   — GQA attention (+ optional sliding window) + MLP (dense or MoE)
  mamba  — Mamba-2 mixer (no MLP; mamba2/zamba2 style)
  shared — hybrid shared attention block invocation (params shared across
           groups, per-invocation KV cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from .attention import KVCache, attention, attn_params, init_cache
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, dense, linear_params, mlp_params, norm_params
from .mamba2 import SSMCache, init_ssm_cache, mamba2_block, mamba2_params
from .moe import moe_block, moe_params


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str            # attn | mamba
    window: int = 0      # sliding window (0 = full)
    moe: bool = False
    shared_after: bool = False   # invoke the shared block after this one


def group_blocks(cfg: ModelConfig) -> List[BlockSpec]:
    """Block specs for one repeating group."""
    if cfg.family == "ssm":
        return [BlockSpec("mamba")]
    if cfg.family == "hybrid":
        blocks = [BlockSpec("mamba") for _ in range(cfg.group_size)]
        return blocks[:-1] + [dataclasses.replace(blocks[-1], shared_after=True)]
    if cfg.local_global_period > 0:
        # gemma2: alternate sliding-window and full attention
        out = []
        for i in range(cfg.local_global_period):
            win = cfg.sliding_window if i % 2 == 0 else 0
            out.append(BlockSpec("attn", window=win, moe=bool(cfg.n_experts)))
        return out
    return [BlockSpec("attn", window=cfg.sliding_window, moe=bool(cfg.n_experts))]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def block_params(key, cfg: ModelConfig, spec: BlockSpec, dtype):
    ks = jax.random.split(key, 4)
    if spec.kind == "mamba":
        p = {"norm": norm_params(cfg.norm, cfg.d_model, dtype),
             "mixer": mamba2_params(ks[0], cfg, dtype)}
        return p
    p = {"attn_norm": norm_params(cfg.norm, cfg.d_model, dtype),
         "attn": attn_params(ks[0], cfg, dtype),
         "mlp_norm": norm_params(cfg.norm, cfg.d_model, dtype)}
    if spec.moe:
        p["moe"] = moe_params(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_params(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_block_norm:
        p["post_attn_norm"] = norm_params(cfg.norm, cfg.d_model, dtype)
        p["post_mlp_norm"] = norm_params(cfg.norm, cfg.d_model, dtype)
    return p


def shared_block_params(key, cfg: ModelConfig, dtype):
    """Zamba2-style shared block: input is concat([h, h_embed]) (2d → d)."""
    ks = jax.random.split(key, 4)
    scfg = dataclasses.replace(cfg, qkv_bias=False)
    return {
        "in_norm": norm_params(cfg.norm, 2 * cfg.d_model, dtype),
        "in_proj": linear_params(ks[0], 2 * cfg.d_model, cfg.d_model, dtype),
        "attn": attn_params(ks[1], scfg, dtype),
        "mlp_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_params(ks[2], cfg.mlp, cfg.d_model, cfg.d_ff, dtype),
        "out_proj": linear_params(ks[3], cfg.d_model, cfg.d_model, dtype),
    }


def group_params(key, cfg: ModelConfig, dtype):
    specs = group_blocks(cfg)
    ks = jax.random.split(key, len(specs))
    return [block_params(k, cfg, s, dtype) for k, s in zip(ks, specs)]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def block_forward(p, cfg: ModelConfig, spec: BlockSpec, x: jnp.ndarray, *,
                  positions, mrope_positions=None, cache=None, ragged=False,
                  block_tables=None, adapter_idx=None, tape=None, rt=None):
    """One block. Returns (y, new_cache, aux).

    ``adapter_idx`` ([b] int32): per-sequence adapter-pool slots; tags the
    block's pooled quantized leaves so each row's LoRA epilogue gathers its
    own factors (slot 0 = base, exactly zero)."""
    if adapter_idx is not None:
        from .layers import route_adapters
        p = route_adapters(p, adapter_idx)
    if spec.kind == "mamba":
        if ragged:
            raise NotImplementedError("ragged decode: SSM blocks carry a "
                                      "running state that pad tokens would "
                                      "corrupt")
        h = apply_norm(cfg.norm, p["norm"], x)
        mtape = _sub(tape, "mixer")
        y, new_cache = mamba2_block(p["mixer"], cfg, h, cache, tape=mtape,
                                    rt=rt)
        return x + y, new_cache, jnp.zeros((), jnp.float32)

    h = apply_norm(cfg.norm, p["attn_norm"], x)
    a, new_cache = attention(p["attn"], cfg, h, positions=positions,
                             layer_window=spec.window,
                             mrope_positions=mrope_positions, cache=cache,
                             ragged=ragged, block_tables=block_tables,
                             tape=_sub(tape, "attn"), rt=rt)
    if cfg.post_block_norm:
        a = apply_norm(cfg.norm, p["post_attn_norm"], a)
    x = x + a
    h = apply_norm(cfg.norm, p["mlp_norm"], x)
    aux = jnp.zeros((), jnp.float32)
    if spec.moe:
        m, aux = moe_block(p["moe"], cfg, h, tape=_sub(tape, "moe"), rt=rt)
    else:
        m = apply_mlp(cfg.mlp, p["mlp"], h, tape=_sub(tape, "mlp"), rt=rt)
    if cfg.post_block_norm:
        m = apply_norm(cfg.norm, p["post_mlp_norm"], m)
    return x + m, new_cache, aux


def _sub(tape, name: str):
    """Child tape dict (None-propagating)."""
    if tape is None:
        return None
    tape[name] = {}
    return tape[name]


def shared_block_forward(p, cfg: ModelConfig, x, x0, *, positions,
                         cache=None, window: int = 0, ragged=False,
                         block_tables=None, tape=None, rt=None):
    """Shared attention block on concat([x, x0]) (zamba2)."""
    from .layers import record
    h = apply_norm(cfg.norm, p["in_norm"], jnp.concatenate([x, x0], axis=-1))
    record(tape, "in_proj", h)
    h = dense(p["in_proj"], h, rt=rt)
    a, new_cache = attention(p["attn"], cfg, h, positions=positions,
                             layer_window=window, cache=cache, ragged=ragged,
                             block_tables=block_tables,
                             tape=_sub(tape, "attn"), rt=rt)
    h = h + a
    m = apply_mlp(cfg.mlp, p["mlp"], apply_norm(cfg.norm, p["mlp_norm"], h),
                  tape=_sub(tape, "mlp"), rt=rt)
    h = h + m
    record(tape, "out_proj", h)
    return x + dense(p["out_proj"], h, rt=rt), new_cache


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_len: int, dtype=jnp.bfloat16,
                     kv_dtype: str = "bf16"):
    if spec.kind == "mamba":
        return init_ssm_cache(cfg, batch, dtype)
    return init_cache(cfg, batch, max_len, window=spec.window, dtype=dtype,
                      kv_dtype=kv_dtype)
