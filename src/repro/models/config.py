"""Model configuration for the whole architecture zoo.

One dataclass covers dense / MoE / SSM / hybrid / enc-dec families; per-arch
files in ``repro.configs`` instantiate it with the exact assigned shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # block flavor
    norm: str = "rmsnorm"          # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"            # swiglu | geglu | gelu | sq_relu
    post_block_norm: bool = False  # gemma2 sandwich norms
    scale_embed: bool = False      # multiply embeddings by sqrt(d) (gemma/whisper)
    tie_embeddings: bool = True

    # attention flavor
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # stablelm partial rotary
    sliding_window: int = 0        # 0 => full attention
    local_global_period: int = 0   # gemma2: alternate local/global every k layers
    attn_softcap: float = 0.0      # gemma2 logit softcapping inside attention
    final_softcap: float = 0.0     # gemma2 final-logit softcap
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) splits
    qkv_bias: bool = False         # qwen2 uses qkv bias

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    n_dense_layers: int = 0        # leading dense FFN layers (kimi/moonshot style)

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64
    ssm_n_groups: int = 1

    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_period: int = 0

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500        # precomputed frame embeddings (conv stub)

    # numerics / performance knobs
    dtype: str = "bfloat16"
    remat: bool = True             # checkpoint each block in train_step
    attn_chunk_q: int = 512        # chunked-attention block sizes (prefill)
    attn_chunk_kv: int = 1024
    scan_layers: bool = True       # lax.scan over the repeating group stack
    scan_unroll: bool = False      # fully unroll the group scan (dry-run cost
                                   # analysis: XLA counts while bodies once)
    seq_shard_residual: bool = False  # Megatron-SP style: shard the saved
                                   # residual stream over the model axis on
                                   # the sequence dim (remat memory /16)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def group_size(self) -> int:
        """Layers per repeating scan group."""
        if self.family == "hybrid" and self.shared_attn_period > 0:
            return self.shared_attn_period
        if self.local_global_period > 0:
            return self.local_global_period
        return 1

    @property
    def n_groups(self) -> int:
        return max(self.n_layers // self.group_size, 1)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 * self.group_size),
            d_model=128,
            n_heads=max(min(self.n_heads, 4), 1),
            n_kv_heads=max(min(self.n_kv_heads, 2), 1),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            dtype="float32",
        )
        if self.n_experts:
            small.update(n_experts=min(self.n_experts, 8),
                         top_k=min(self.top_k, 2), moe_d_ff=64,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         n_dense_layers=min(self.n_dense_layers, 1))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.n_encoder_layers:
            small.update(n_encoder_layers=2, encoder_seq=64)
        if self.sliding_window:
            small.update(sliding_window=64)
        if self.mrope_sections:
            small.update(mrope_sections=(8, 4, 4))
        small.update(overrides)
        return dataclasses.replace(self, **small)
