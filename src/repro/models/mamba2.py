"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) block.

Chunked SSD for train/prefill (block decomposition: quadratic intra-chunk +
linear inter-chunk state recurrence) and an O(1)-per-token recurrent decode
step. Selective linears (in_proj/out_proj) are quantizable ``dense`` leaves.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, linear_params


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # [b, d_conv-1, conv_dim] — rolling conv inputs
    state: jnp.ndarray   # [b, n_heads, head_dim, d_state]


def mamba2_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, d_in = cfg.d_model, cfg.ssm_d_inner
    nh, hd, ds, ng = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    conv_dim = d_in + 2 * ng * ds
    ks = jax.random.split(key, 5)
    return {
        # in_proj → [z, x, B, C, dt]
        "in_proj": linear_params(ks[0], d, 2 * d_in + 2 * ng * ds + nh, dtype),
        "out_proj": linear_params(ks[1], d_in, d, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (cfg.ssm_conv * conv_dim) ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": (jax.random.uniform(ks[3], (nh,), jnp.float32,
                                       minval=-4.6, maxval=-2.0)),
        "norm_scale": jnp.ones((d_in,), dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in, ng, ds, nh = cfg.ssm_d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ng * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: jnp.ndarray | None = None):
    """Depthwise causal conv1d. xbc: [b, l, c]; w: [k, c]. Returns (y, tail)."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)
    # y[t] = sum_i w[i] * xp[t + i]
    y = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    tail = xp[:, xp.shape[1] - (k - 1):, :]
    return jax.nn.silu(y + b[None, None, :]), tail


def _gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b_mat: jnp.ndarray, c_mat: jnp.ndarray, d_skip: jnp.ndarray,
                chunk: int, init_state: jnp.ndarray | None = None):
    """Chunked SSD scan.

    x: [b, l, nh, hd]; dt: [b, l, nh] (post-softplus); b_mat/c_mat:
    [b, l, ng, ds]; a_log: [nh]. Returns (y [b, l, nh, hd], final_state).
    """
    bsz, l, nh, hd = x.shape
    ng, ds = b_mat.shape[2], b_mat.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = nh // ng

    a = -jnp.exp(a_log.astype(jnp.float32))                  # [nh], negative
    dt = dt.astype(jnp.float32)
    dA = dt * a[None, None, :]                                # [b, l, nh] (log decay)

    xc = x.reshape(bsz, nc, chunk, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, nh)
    dAc = dA.reshape(bsz, nc, chunk, nh)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, ng, ds), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, ng, ds), rep, axis=3).astype(jnp.float32)

    seg = jnp.cumsum(dAc, axis=2)                             # [b, nc, T, nh]

    # Intra-chunk (diagonal block): y_intra[t] = sum_{s<=t} C_t·B_s exp(seg_t-seg_s) dt_s x_s
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]     # [b,nc,T,S,nh]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, -jnp.inf)
    cb = jnp.einsum("bnthd,bnshd->bntsh", cc, bc)             # [b,nc,T,S,nh]
    gate = jnp.exp(decay)
    y_intra = jnp.einsum("bntsh,bnsh,bnshp->bnthp", cb * gate, dtc, xc)

    # Chunk-final states: S_n = sum_s exp(seg_T - seg_s) dt_s B_s x_s^T
    last = seg[:, :, -1:, :]                                   # [b,nc,1,nh]
    w_state = jnp.exp(last - seg) * dtc                        # [b,nc,T,nh]
    chunk_states = jnp.einsum("bnshd,bnsh,bnshp->bnhpd", bc, w_state, xc)

    # Inter-chunk recurrence over nc (sequential scan; nc is small)
    chunk_decay = jnp.exp(last[:, :, 0, :])                    # [b,nc,nh]

    def scan_fn(state, inp):
        s_new, dec = inp                                       # [b,nh,hd,ds], [b,nh]
        state_out = state * dec[:, :, None, None] + s_new
        return state_out, state                                # emit state BEFORE chunk

    init = (jnp.zeros((bsz, nh, hd, ds), jnp.float32)
            if init_state is None else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [b,nc,nh,hd,ds]

    # Inter-chunk contribution: C_t exp(seg_t) · prev_state
    y_inter = jnp.einsum("bnthd,bnth,bnhpd->bnthp",
                         cc, jnp.exp(seg), prev_states)
    y = (y_intra + y_inter).reshape(bsz, l, nh, hd)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y, final_state


def mamba2_block(p, cfg: ModelConfig, x: jnp.ndarray,
                 cache: SSMCache | None = None, tape=None, rt=None):
    """Full Mamba-2 mixer. x: [b, l, d]. Returns (y, new_cache)."""
    bsz, l, _ = x.shape
    nh, hd, ds, ng = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    d_in = cfg.ssm_d_inner

    from .layers import record
    record(tape, "in_proj", x)
    zxbcdt = dense(p["in_proj"], x, rt=rt)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])

    prev_conv = cache.conv if cache is not None else None
    xbc_conv, conv_tail = _causal_conv(xbc, p["conv_w"].astype(jnp.float32),
                                       p["conv_b"].astype(jnp.float32), prev_conv)
    xs, b_mat, c_mat = jnp.split(xbc_conv, [d_in, d_in + ng * ds], axis=-1)
    xs = xs.reshape(bsz, l, nh, hd)
    b_mat = b_mat.reshape(bsz, l, ng, ds)
    c_mat = c_mat.reshape(bsz, l, ng, ds)

    if cache is None or l > 1:
        # pad to chunk multiple
        chunk = min(cfg.ssm_chunk, max(l, 1))
        pad = (-l) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        init_state = cache.state if cache is not None else None
        y, final_state = ssd_chunked(xs, dt, p["A_log"], b_mat, c_mat,
                                     p["D"], chunk, init_state)
        y = y[:, :l]
    else:
        # single-token recurrent decode
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * a[None, :])                    # [b, nh]
        bx = jnp.einsum("bhp,bgd,bh->bhpd",
                        xs[:, 0].astype(jnp.float32),
                        b_mat[:, 0].astype(jnp.float32),
                        dt[:, 0]) if ng == 1 else None
        if bx is None:
            rep = nh // ng
            b_rep = jnp.repeat(b_mat[:, 0], rep, axis=1)
            bx = jnp.einsum("bhp,bhd,bh->bhpd", xs[:, 0].astype(jnp.float32),
                            b_rep.astype(jnp.float32), dt[:, 0])
        state = cache.state.astype(jnp.float32) * dA[:, :, None, None] + bx
        rep = nh // ng
        c_rep = jnp.repeat(c_mat[:, 0], rep, axis=1) if ng > 1 else \
            jnp.broadcast_to(c_mat[:, 0], (bsz, nh, ds))
        y = jnp.einsum("bhpd,bhd->bhp", state, c_rep.astype(jnp.float32))
        y = y + p["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
        y = y[:, None]                                          # [b, 1, nh, hd]
        final_state = state

    y = _gated_rmsnorm(y.reshape(bsz, l, d_in).astype(x.dtype), z, p["norm_scale"])
    record(tape, "out_proj", y)
    out = dense(p["out_proj"], y, rt=rt)
    new_cache = SSMCache(conv_tail.astype(x.dtype), final_state)
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return SSMCache(
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32))
