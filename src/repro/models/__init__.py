"""Architecture zoo: dense / MoE / SSM / hybrid / enc-dec LMs in pure JAX."""
from .config import ModelConfig
from .model import (init_params, forward, encode, init_caches,
                    init_paged_caches, param_count, prepare_cross_caches,
                    caches_length)
from .attention import (KVCache, PagedKVCache, init_cache, init_paged_cache,
                        chunked_attention, quantize_kv, dequantize_kv,
                        kv_qmax)
from .mamba2 import SSMCache, init_ssm_cache
from .transformer import BlockSpec, group_blocks

__all__ = ["ModelConfig", "init_params", "forward", "encode", "init_caches",
           "init_paged_caches", "param_count", "prepare_cross_caches",
           "caches_length", "KVCache", "PagedKVCache", "init_cache",
           "init_paged_cache", "chunked_attention", "quantize_kv",
           "dequantize_kv", "kv_qmax", "SSMCache",
           "init_ssm_cache", "BlockSpec", "group_blocks"]
