"""Shared layer primitives: norms, RoPE / M-RoPE, linear dispatch, MLPs.

Every linear weight is a leaf dict so the quantization pass can swap a plain
``{"w": [in, out]}`` for a quantized ``{"qw", "sw", "la", "lb", "m"}`` leaf
without touching model code. ``dense()`` dispatches on the leaf contents.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import unpack_int4


# ---------------------------------------------------------------------------
# Sharding annotation (no-op without an active mesh)
# ---------------------------------------------------------------------------

def _active_mesh():
    """Physical mesh from the trace-time context (``with mesh:``), if any."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def constrain(x, *spec):
    """with_sharding_constraint with axis cleaning: unknown mesh axes and
    non-divisible dims are dropped, so model code can annotate logical
    layouts unconditionally (pure no-op on CPU tests without a mesh)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
            if hasattr(mesh, "devices") else dict(mesh.shape)
        names = set(mesh.axis_names)

        def clean_axis(ax, dim):
            if ax is None:
                return None
            axs = ax if isinstance(ax, tuple) else (ax,)
            axs = tuple(a for a in axs if a in names)
            total = 1
            for a in axs:
                total *= sizes[a]
            if not axs or total == 0 or dim % total != 0:
                return None
            return axs if len(axs) > 1 else axs[0]

        spec = tuple(spec)[:x.ndim]
        spec = spec + (None,) * (x.ndim - len(spec))
        clean = tuple(clean_axis(ax, d) for ax, d in zip(spec, x.shape))
        try:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*clean)))
        except Exception:
            return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:
        return x


BATCH = ("pod", "data")


# ---------------------------------------------------------------------------
# Calibration statistics (PTQ)
# ---------------------------------------------------------------------------

from typing import NamedTuple


class LinStats(NamedTuple):
    """Per-linear calibration stats: Gram = Σ xᵀx, absmean numerator, count."""
    gram: jnp.ndarray      # [d_in, d_in] f32
    abssum: jnp.ndarray    # [d_in] f32 (Σ|x|; divide by count for X̄)
    absmax: jnp.ndarray    # [d_in] f32 (max |x|, for SmoothQuant)
    count: jnp.ndarray     # [] f32 tokens


def _stats_of(x: jnp.ndarray) -> LinStats:
    d = x.shape[-1]
    xf = x.reshape(-1, d).astype(jnp.float32)
    ax = jnp.abs(xf)
    return LinStats(xf.T @ xf, jnp.sum(ax, axis=0), jnp.max(ax, axis=0),
                    jnp.asarray(xf.shape[0], jnp.float32))


def record(tape, name: str, x: jnp.ndarray):
    """Record the input distribution of linear ``name`` into ``tape``."""
    if tape is None:
        return
    tape[name] = _stats_of(x)


def record_stats(tape, name: str, st: LinStats):
    if tape is None:
        return
    tape[name] = st


def dense_c(p, name: str, x: jnp.ndarray, tape=None, rt=None) -> jnp.ndarray:
    """dense() + optional calibration capture of the layer input."""
    record(tape, name, x)
    return dense(p[name], x, rt=rt)


# ---------------------------------------------------------------------------
# Linear layers (fp + quantized dispatch)
# ---------------------------------------------------------------------------

def linear_params(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
                  bias: bool = False, scale: float | None = None):
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: jnp.ndarray, rt=None) -> jnp.ndarray:
    """Apply a (possibly quantized) linear layer. x: [..., d_in].

    ``rt``: optional :class:`repro.runtime.RuntimeConfig` steering the
    quantized path (act bits, pallas vs XLA); None → the process default."""
    if "qw" in p:
        return _quantized_dense(p, x, rt)
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def route_adapters(p, idx):
    """Tag every adapter-pooled quantized leaf under ``p`` with the batch's
    per-row adapter slots.

    ``idx`` is a [b] int32 vector of adapter-pool slots (slot 0 = base).
    Returns a shallow-copied tree where each leaf dict holding an ``alb``
    factor pool also carries ``aidx``; ``_quantized_dense`` picks it up and
    routes the gathered epilogue. Leaves without pools (fp, experts) pass
    through untouched."""
    if not isinstance(p, dict):
        return p
    if "alb" in p:
        q = dict(p)
        q["aidx"] = idx
        return q
    return {k: route_adapters(v, idx) for k, v in p.items()}


def _quantized_dense(p, x: jnp.ndarray, rt=None) -> jnp.ndarray:
    """W4A8 serving path with ASER low-rank compensation.

    Layout: qw int8 [d_in//2, d_out] (int4 pairs packed along d_in),
    sw [d_out] per-out-channel weight scale, m [d_in] smoothing diagonal,
    la [r, d_out], lb [d_in, r]. Per-token int8 activation quantization.
    Uses the Pallas kernel path when enabled, else the pure-XLA reference.

    Leaves carrying adapter pools (``alb`` [P, d_in, ra], ``ala``
    [P, ra, d_out]) and a routed batch (``aidx`` [b], injected by
    :func:`route_adapters`) add each row's gathered LoRA epilogue.
    """
    from repro.kernels import ops as kops
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    adapter, uniform = None, False
    if "alb" in p and "aidx" in p:
        # expand per-sequence slots to per-token rows of the flattened x2;
        # a single-sequence call (prefill) routes every row to one slot,
        # which the epilogue exploits as a shared-GEMM fast path
        idx = p["aidx"]
        uniform = idx.shape[0] == 1
        shape = orig_shape[:-1]
        rows = jnp.broadcast_to(idx.reshape(idx.shape + (1,) * (len(shape) - 1)),
                                shape).reshape(-1)
        adapter = (p["alb"], p["ala"], rows)
    y2 = kops.w4a8_linear(x2, p["qw"], p["sw"], p["m"], p["lb"], p["la"],
                          rt=rt, adapter=adapter, adapter_uniform=uniform,
                          waug=p.get("waug"), blb=p.get("blb"))
    y2 = y2.astype(x.dtype)
    if "b" in p:
        y2 = y2 + p["b"].astype(y2.dtype)
    return y2.reshape(*orig_shape[:-1], y2.shape[-1])


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_params(kind: str, dim: int, dtype=jnp.bfloat16):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if kind == "nonparam_ln":   # OLMo: LN without affine params
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Statistics in f32, elementwise math in the input dtype.

    Deliberately avoids materializing a full f32 copy of the residual
    stream: the f32 upcast lives only inside the (fused) reductions, which
    halves the dominant remat-saved buffer at 18k-wide models.
    """
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.maximum(jnp.mean(xf * xf, axis=-1, keepdims=True) - mu * mu, 0.0)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = (x - mu.astype(x.dtype)) * inv
    if kind == "layernorm":
        out = out * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# RoPE (standard, partial, M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rot_dim: int | None = None):
    rot = rot_dim if rot_dim is not None else head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # [rot//2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: [b, s, h, hd]; positions: [b, s] int32."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_freqs(hd, theta, rot)
    ang = positions.astype(jnp.float32)[..., None] * inv[None, None, :]  # [b,s,rot//2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions3: [3, b, s] (t, h, w) coords.

    The rotary half-dim is split into ``sections`` (summing to hd//2); each
    section uses its own positional stream.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)           # [hd//2]
    assert sum(sections) == hd // 2, (sections, hd)
    # section id per frequency slot
    sec_id = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(sections)])
    pos = positions3.astype(jnp.float32)  # [3, b, s]
    pos_per_slot = pos[sec_id]            # [hd//2, b, s]
    ang = jnp.moveaxis(pos_per_slot, 0, -1) * inv[None, None, :]  # [b, s, hd//2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_params(key, kind: str, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"gate": linear_params(ks[0], d_model, d_ff, dtype),
                "up": linear_params(ks[1], d_model, d_ff, dtype),
                "down": linear_params(ks[2], d_ff, d_model, dtype)}
    return {"up": linear_params(ks[0], d_model, d_ff, dtype),
            "down": linear_params(ks[1], d_ff, d_model, dtype)}


def apply_mlp(kind: str, p, x: jnp.ndarray, tape=None, rt=None) -> jnp.ndarray:
    def _c(h):
        return constrain(h, *((BATCH,) + (None,) * (h.ndim - 2) + ("model",)))
    if kind == "swiglu":
        h = _c(jax.nn.silu(dense_c(p, "gate", x, tape, rt))
               * dense(p["up"], x, rt=rt))
        if tape is not None:
            tape["up"] = tape["gate"]  # same input distribution
        return dense_c(p, "down", h, tape, rt)
    if kind == "geglu":
        h = _c(jax.nn.gelu(dense_c(p, "gate", x, tape, rt))
               * dense(p["up"], x, rt=rt))
        if tape is not None:
            tape["up"] = tape["gate"]
        return dense_c(p, "down", h, tape, rt)
    if kind == "gelu":
        return dense_c(p, "down",
                       _c(jax.nn.gelu(dense_c(p, "up", x, tape, rt))),
                       tape, rt)
    if kind == "sq_relu":   # Nemotron squared-ReLU
        h = jax.nn.relu(dense_c(p, "up", x, tape, rt))
        return dense_c(p, "down", _c(h * h), tape, rt)
    raise ValueError(kind)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
