"""GQA attention: chunked (flash-style) prefill/train + KV-cache decode.

Supports full-causal, sliding-window (ring-buffer cache), logit softcapping
(gemma2), partial rotary (stablelm), and M-RoPE (qwen2-vl). Pure-jnp chunked
implementation (memory-bounded lax.scan online softmax) is the portable path;
the Pallas flash kernel in ``repro.kernels`` is the TPU hot path for the same
math and is validated against this implementation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (BATCH, apply_mrope, apply_rope, constrain, dense,
                     linear_params, softcap)


class KVCache(NamedTuple):
    """Per-slot contiguous KV lanes, optionally int8/int4-quantized.

    Quantized caches (``k_scale is not None``) store abs-max per-token
    per-kv-head codes in int8 ``k``/``v`` next to f32 scale lanes; reads
    dequantize (``codes * scale``) before attention. ``qmax`` is the code
    grid half-range (127 for int8, 7 for int4 — int4 codes ride in int8
    storage) and makes the cache self-describing: the write site needs no
    out-of-band bit-width.
    """
    k: jnp.ndarray        # [b, cache_len, n_kv, hd] (int8 codes if quantized)
    v: jnp.ndarray        # [b, cache_len, n_kv, hd]
    length: jnp.ndarray   # [] int32 — tokens written so far (global position)
    pos: jnp.ndarray      # [cache_len] int32 — global position held by each slot
                          # (ring buffers overwrite; init = large negative)
    k_scale: Optional[jnp.ndarray] = None   # [b, cache_len, n_kv] f32
    v_scale: Optional[jnp.ndarray] = None   # [b, cache_len, n_kv] f32
    qmax: Optional[jnp.ndarray] = None      # [] f32 — 127 (int8) | 7 (int4)


class PagedKVCache(NamedTuple):
    """Block-paged KV storage: one global physical pool, no batch axis.

    Requests own *pages* of the pool rather than a contiguous per-slot lane:
    a per-request block table (``[b, blocks_per_seq]`` int32, threaded
    through ``forward(..., block_tables=...)``) maps logical block
    ``pos // block_size`` to a physical block id. Unmapped table entries
    hold the out-of-range sentinel ``num_blocks`` so their writes drop and
    their (masked) reads clamp harmlessly.

    Quantized pools (``k_scale is not None``) store int8 codes plus
    per-page scale tiles ``[num_blocks, block_size, n_kv]`` — one f32 scale
    per token slot per kv head, scattered/gathered through the same block
    table as the codes, so a page's scales always travel with the page
    (COW block copies, eviction, and preemption need no extra bookkeeping).
    """
    k: jnp.ndarray        # [num_blocks, block_size, n_kv, hd] (int8 codes
                          # if quantized)
    v: jnp.ndarray        # [num_blocks, block_size, n_kv, hd]
    length: jnp.ndarray   # [] int32 — total tokens written (diagnostic only;
                          # positions are always explicit in paged mode)
    k_scale: Optional[jnp.ndarray] = None   # [num_blocks, block_size, n_kv]
    v_scale: Optional[jnp.ndarray] = None   # [num_blocks, block_size, n_kv]
    qmax: Optional[jnp.ndarray] = None      # [] f32 — 127 (int8) | 7 (int4)


# storage dtypes: see repro.runtime.KV_CACHE_DTYPES (single source of truth)
_KV_QMAX = {"int8": 127.0, "int4": 7.0}


def kv_qmax(kv_dtype: str) -> float:
    """Code grid half-range for a quantized KV dtype."""
    return _KV_QMAX[kv_dtype]


def quantize_kv(x: jnp.ndarray, qmax) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Abs-max per-token-per-head symmetric quantization of K/V rows.

    x: [..., heads, hd] → (codes int8 [..., heads, hd],
    scale f32 [..., heads]). ``qmax`` may be a traced scalar (it lives in
    the cache) — the int8 clip below stays static because int4 codes are
    already within ±7 by construction of the scale.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(xf / safe[..., None]), -127, 127)
    return codes.astype(jnp.int8), scale


def dequantize_kv(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """codes [..., heads, hd] int8, scale [..., heads] f32 → f32 values."""
    return codes.astype(jnp.float32) * scale[..., None]


def attn_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_params(ks[0], cfg.d_model, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "wk": linear_params(ks[1], cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wv": linear_params(ks[2], cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wo": linear_params(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool = True, window: int = 0,
                      q_offset: int | jnp.ndarray = 0,
                      logit_cap: float = 0.0,
                      chunk_q: int = 512, chunk_kv: int = 1024,
                      kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """Memory-bounded attention via online softmax over KV chunks.

    q: [b, sq, hq, hd]; k/v: [b, skv, hkv, hd] (hq % hkv == 0).
    ``q_offset``: global position of q[0] (decode: cache length). Scalar, or
    per-row ``[b]`` for ragged batches (each row at its own position).
    ``kv_len``: valid prefix length of k/v (decode with preallocated cache);
    scalar or per-row ``[b]``.
    """
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    n_rep = hq // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = hd ** -0.5

    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    # pad to multiples
    pad_q = (-sq) % cq
    pad_kv = (-skv) % ckv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq, nkv = (sq + pad_q) // cq, (skv + pad_kv) // ckv

    qb = q.reshape(b, nq, cq, hq, hd).transpose(1, 0, 3, 2, 4)   # [nq, b, h, cq, hd]
    kb = k.reshape(b, nkv, ckv, hq, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nkv, ckv, hq, hd).transpose(1, 0, 3, 2, 4)
    qb = constrain(qb, None, BATCH, "model", None, None)
    kb = constrain(kb, None, BATCH, "model", None, None)
    vb = constrain(vb, None, BATCH, "model", None, None)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)
    valid_kv = jnp.asarray(skv if kv_len is None else kv_len, jnp.int32)
    # per-row offsets/lengths ([b]) ⇒ masks gain a batch dim
    per_row = q_pos_base.ndim > 0 or valid_kv.ndim > 0
    if per_row:
        q_pos_base = jnp.broadcast_to(q_pos_base, (b,))
        valid_kv = jnp.broadcast_to(valid_kv, (b,))

    def q_block(qi, q_i):
        q_rel = qi * cq + jnp.arange(cq, dtype=jnp.int32)
        q_pos = (q_pos_base[:, None] + q_rel[None] if per_row
                 else q_pos_base + q_rel)            # [b, cq] | [cq]

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            kv_pos = ki * ckv + jnp.arange(ckv, dtype=jnp.int32)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            if logit_cap > 0:
                s = logit_cap * jnp.tanh(s / logit_cap)
            if per_row:
                mask = kv_pos[None, None, :] < valid_kv[:, None, None]
                if causal:
                    mask = mask & (kv_pos[None, None, :] <= q_pos[:, :, None])
                if window > 0:
                    mask = mask & (kv_pos[None, None, :]
                                   > q_pos[:, :, None] - window)
                s = jnp.where(mask[:, None], s, -1e30)   # [b,1,cq,ckv]
            else:
                mask = kv_pos[None, :] < valid_kv
                if causal:
                    mask = mask & (kv_pos[None, :] <= q_pos[:, None])
                if window > 0:
                    mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
                s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, cq), jnp.float32)
        a0 = jnp.zeros((b, hq, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkv, dtype=jnp.int32), kb, vb))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq, dtype=jnp.int32), qb))  # [nq, b, h, cq, hd]
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq + pad_q, hq, hd)
    return out[:, :sq].astype(v.dtype)


def attention(p, cfg: ModelConfig, x: jnp.ndarray, *,
              positions: jnp.ndarray,
              layer_window: int = 0,
              cache: KVCache | None = None,
              mrope_positions: jnp.ndarray | None = None,
              cross_kv: tuple[jnp.ndarray, jnp.ndarray] | None = None,
              ragged: bool = False, block_tables: jnp.ndarray | None = None,
              tape=None, rt=None):
    """Self (or cross) attention. x: [b, s, d].

    Returns (out, new_cache). Train/prefill: cache=None builds nothing unless
    a preallocated cache is given. Decode: s is small (usually 1) and cache
    holds past KV (ring buffer when layer_window > 0).

    ``ragged=True`` (decode with cache): each batch row sits at its own
    position — ``positions`` [b, s] gives the per-row global positions, KV is
    scattered into the cache at those row positions (not at a shared
    ``cache.length`` offset), and the causal mask is built per row, so a row
    never attends past its own frontier into another row's padding.

    ``cache`` may be a :class:`PagedKVCache`; then ``block_tables``
    ([b, blocks_per_seq] int32) is required and positions are always
    per-row: KV is scattered to physical pool slots
    ``table[pos // bs] * bs + pos % bs`` and attention runs over the
    gathered per-row view (or the Pallas paged-gather kernel at decode).
    """
    from .layers import record
    b, s, _ = x.shape
    record(tape, "wq", x)
    if tape is not None:
        tape["wk"] = tape["wq"]
        tape["wv"] = tape["wq"]
    q = dense(p["wq"], x, rt=rt).reshape(b, s, cfg.n_heads, cfg.head_dim)
    q = constrain(q, BATCH, None, "model", None)

    if cross_kv is not None:
        k, v = cross_kv
        if cfg.mrope_sections:
            q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        out = chunked_attention(q, k, v, causal=False,
                                chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        o_in = out.reshape(b, s, cfg.q_dim)
        record(tape, "wo", o_in)
        return dense(p["wo"], o_in, rt=rt), None

    k = dense(p["wk"], x, rt=rt).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x, rt=rt).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    k = constrain(k, BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)

    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

    if cache is None:
        out = chunked_attention(
            q, k, v, causal=True, window=layer_window,
            logit_cap=cfg.attn_softcap,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        new_cache = None
    elif isinstance(cache, PagedKVCache):
        if block_tables is None:
            raise ValueError("paged KV cache requires block_tables")
        if layer_window > 0:
            raise NotImplementedError(
                "paged KV does not support sliding-window layers")
        out, new_cache = _paged_attention(
            cache, cfg, q, k, v, positions=positions,
            block_tables=block_tables, rt=rt)
    elif ragged:
        cache_len = cache.k.shape[1]
        if layer_window > 0 and cache_len <= layer_window:
            raise NotImplementedError(
                "ragged decode does not support ring-buffer (sliding-window) "
                "KV caches")
        # per-row positioned writes: row i's token lands at positions[i],
        # progressively overwriting whatever prefill padding left there.
        # Out-of-bounds rows (retired slots past max_len) drop their writes.
        row_pos = positions.astype(jnp.int32)                    # [b, s]
        b_idx = jnp.arange(b, dtype=jnp.int32)[:, None]
        quantized = cache.k_scale is not None
        if quantized:
            k, k_s = quantize_kv(k, cache.qmax)
            v, v_s = quantize_kv(v, cache.qmax)
        k_all = cache.k.at[b_idx, row_pos].set(
            k.astype(cache.k.dtype), mode="drop", unique_indices=True)
        v_all = cache.v.at[b_idx, row_pos].set(
            v.astype(cache.v.dtype), mode="drop", unique_indices=True)
        ks_all = vs_all = None
        if quantized:
            ks_all = cache.k_scale.at[b_idx, row_pos].set(
                k_s, mode="drop", unique_indices=True)
            vs_all = cache.v_scale.at[b_idx, row_pos].set(
                v_s, mode="drop", unique_indices=True)
        new_cache = KVCache(k_all, v_all, cache.length + s, cache.pos,
                            ks_all, vs_all, cache.qmax)
        k_att, v_att = ((dequantize_kv(k_all, ks_all).astype(q.dtype),
                         dequantize_kv(v_all, vs_all).astype(q.dtype))
                        if quantized else (k_all, v_all))
        # causal per row: kv slot j visible iff j ≤ that row's own position.
        # Valid prefixes are contiguous (decode writes at lens+t), so the
        # per-row causal bound is also the per-row length mask.
        # NOTE: this always takes the chunked path — the head_dim-sharded
        # TP decode kernel (_decode_attention_hd_sharded) has no per-row
        # offset variant yet, so sharded few-KV-head ragged decode falls
        # back to chunked and re-exposes the cache-rematerialization cost
        # documented in sharding/rules.cache_spec. Port it before serving
        # ragged batches on a "model"-axis mesh with n_kv < TP.
        out = chunked_attention(
            q, k_att, v_att, causal=True, window=layer_window,
            q_offset=row_pos[:, 0], kv_len=row_pos[:, -1] + 1,
            logit_cap=cfg.attn_softcap,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    else:
        cache_len = cache.k.shape[1]
        start = cache.length
        ring = layer_window > 0 and cache_len <= layer_window
        quantized = cache.k_scale is not None
        if quantized and ring:
            raise NotImplementedError(
                "quantized KV does not support ring-buffer (sliding-window) "
                "caches; use kv_dtype='bf16' for windowed layers")
        new_pos = start + jnp.arange(s, dtype=jnp.int32)
        if quantized:
            k, k_s = quantize_kv(k, cache.qmax)
            v, v_s = quantize_kv(v, cache.qmax)
        if ring:
            idx = new_pos % cache_len
            k_all = cache.k.at[:, idx].set(k.astype(cache.k.dtype))
            v_all = cache.v.at[:, idx].set(v.astype(cache.v.dtype))
            pos_all = cache.pos.at[idx].set(new_pos)
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, start, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, start, 0, 0))
            pos_all = jax.lax.dynamic_update_slice(cache.pos, new_pos, (start,))
        ks_all = vs_all = None
        if quantized:
            ks_all = jax.lax.dynamic_update_slice(
                cache.k_scale, k_s, (0, start, 0))
            vs_all = jax.lax.dynamic_update_slice(
                cache.v_scale, v_s, (0, start, 0))
        new_cache = KVCache(k_all, v_all, start + s, pos_all,
                            ks_all, vs_all, cache.qmax)
        k_att, v_att = ((dequantize_kv(k_all, ks_all).astype(q.dtype),
                         dequantize_kv(v_all, vs_all).astype(q.dtype))
                        if quantized else (k_all, v_all))
        if ring:
            q_pos = new_pos
            mask = ((pos_all[None, :] <= q_pos[:, None])
                    & (pos_all[None, :] > q_pos[:, None] - layer_window)
                    & (pos_all[None, :] >= 0))
            out = _masked_attention(q, k_att, v_att, mask, cfg.attn_softcap)
        else:
            out = None
            if s <= 8:
                out = _decode_attention_hd_sharded(
                    q, k_att, v_att, q_offset=start, kv_len=start + s,
                    window=layer_window, logit_cap=cfg.attn_softcap)
            if out is None:
                out = chunked_attention(
                    q, k_att, v_att, causal=True, window=layer_window,
                    q_offset=start, kv_len=start + s,
                    logit_cap=cfg.attn_softcap,
                    chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)

    o_in = out.reshape(b, s, cfg.q_dim)
    record(tape, "wo", o_in)
    return dense(p["wo"], o_in, rt=rt), new_cache


def _paged_attention(cache: PagedKVCache, cfg: ModelConfig, q, k, v, *,
                     positions, block_tables, rt=None):
    """Scatter new KV into the paged pool, attend over the gathered view.

    q/k/v: [b, s, h, hd]; positions: [b, s] per-row global positions;
    block_tables: [b, nb_req] int32 physical block ids (sentinel =
    ``num_blocks`` for unmapped entries). Returns (out, new_cache).

    Writes: position p lands at pool slot ``table[p // bs] * bs + p % bs``.
    Sentinel/overflow targets map out of range and are dropped, so pad
    positions beyond a request's mapped pages never touch another
    request's blocks. Valid prefixes stay position-contiguous per row, so
    the per-row causal bound (``kv_len = last_pos + 1``) is also the
    validity mask — exactly the ragged contiguous discipline, relocated
    through the table.

    Reads: the decode hot loop (s == 1) routes to the Pallas paged-gather
    kernel via :func:`repro.kernels.ops.paged_attention` when the runtime
    and the tuning cost model allow; otherwise (prefill, or kernel not
    applicable) the per-row KV view [b, nb_req * bs, n_kv, hd] is gathered
    and handed to the same chunked attention as the contiguous path, which
    keeps paged decoding bit-identical to the contiguous engine.

    Quantized pools (``cache.k_scale is not None``): inserts quantize
    (abs-max per token per kv head) and the scales scatter through the
    *same* flat index as the codes; reads either dequantize in the gathered
    view or hand the scale pools to the kernel's dequant epilogue.
    """
    from repro.kernels import ops as _ops
    b, s, _, _ = q.shape
    n_total, bs_blk = cache.k.shape[0], cache.k.shape[1]
    nb_req = block_tables.shape[1]
    quantized = cache.k_scale is not None
    row_pos = positions.astype(jnp.int32)                     # [b, s]
    logical = row_pos // bs_blk
    phys = jnp.take_along_axis(block_tables,
                               jnp.clip(logical, 0, nb_req - 1), axis=1)
    flat = phys * bs_blk + row_pos % bs_blk                   # [b, s]
    valid = (row_pos >= 0) & (logical < nb_req)
    flat = jnp.where(valid, flat, n_total * bs_blk)           # OOB ⇒ dropped
    if quantized:
        k, k_s = quantize_kv(k, cache.qmax)
        v, v_s = quantize_kv(v, cache.qmax)
    k_flat = cache.k.reshape(n_total * bs_blk, *cache.k.shape[2:])
    v_flat = cache.v.reshape(n_total * bs_blk, *cache.v.shape[2:])
    k_flat = k_flat.at[flat].set(k.astype(k_flat.dtype), mode="drop")
    v_flat = v_flat.at[flat].set(v.astype(v_flat.dtype), mode="drop")
    ks_flat = vs_flat = None
    if quantized:
        ks_flat = cache.k_scale.reshape(n_total * bs_blk, -1)
        vs_flat = cache.v_scale.reshape(n_total * bs_blk, -1)
        ks_flat = ks_flat.at[flat].set(k_s, mode="drop")
        vs_flat = vs_flat.at[flat].set(v_s, mode="drop")
    new_cache = PagedKVCache(
        k_flat.reshape(cache.k.shape), v_flat.reshape(cache.v.shape),
        cache.length + s,
        ks_flat.reshape(cache.k_scale.shape) if quantized else None,
        vs_flat.reshape(cache.v_scale.shape) if quantized else None,
        cache.qmax)

    kv_len = row_pos[:, -1] + 1                               # [b]
    if s == 1:
        out = _ops.paged_attention(q, new_cache.k, new_cache.v,
                                   block_tables, kv_len,
                                   k_scale=new_cache.k_scale,
                                   v_scale=new_cache.v_scale,
                                   logit_cap=cfg.attn_softcap, rt=rt)
        if out is not None:
            return out, new_cache
    # gather fallback / prefill: per-row contiguous KV view through the table
    idx = (jnp.clip(block_tables, 0, n_total - 1)[:, :, None] * bs_blk
           + jnp.arange(bs_blk, dtype=jnp.int32)[None, None, :])
    k_all = k_flat[idx.reshape(b, nb_req * bs_blk)]
    v_all = v_flat[idx.reshape(b, nb_req * bs_blk)]
    if quantized:
        ks_all = ks_flat[idx.reshape(b, nb_req * bs_blk)]
        vs_all = vs_flat[idx.reshape(b, nb_req * bs_blk)]
        k_all = dequantize_kv(k_all, ks_all).astype(q.dtype)
        v_all = dequantize_kv(v_all, vs_all).astype(q.dtype)
    out = chunked_attention(
        q, k_all, v_all, causal=True,
        q_offset=row_pos[:, 0], kv_len=kv_len,
        logit_cap=cfg.attn_softcap,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    return out, new_cache


def _masked_attention(q, k, v, mask, logit_cap=0.0):
    """Small-q dense attention with explicit mask ([sq, skv] or broadcastable)."""
    b, sq, hq, hd = q.shape
    n_rep = hq // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def _check_kv_dtype(kv_dtype: str):
    from repro.runtime import KV_CACHE_DTYPES
    if kv_dtype not in KV_CACHE_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_CACHE_DTYPES}: "
                         f"{kv_dtype!r}")


def init_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0,
               dtype=jnp.bfloat16, kv_dtype: str = "bf16") -> KVCache:
    _check_kv_dtype(kv_dtype)
    cache_len = min(window, max_len) if window > 0 else max_len
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    if kv_dtype != "bf16":
        if window > 0 and cache_len <= window:
            raise NotImplementedError(
                "quantized KV does not support ring-buffer (sliding-window) "
                "caches; use kv_dtype='bf16' for windowed layers")
        sshape = (batch, cache_len, cfg.n_kv_heads)
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros((), jnp.int32),
                       jnp.full((cache_len,), -(2 ** 30), jnp.int32),
                       jnp.zeros(sshape, jnp.float32),
                       jnp.zeros(sshape, jnp.float32),
                       jnp.asarray(kv_qmax(kv_dtype), jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32),
                   jnp.full((cache_len,), -(2 ** 30), jnp.int32))


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16,
                     kv_dtype: str = "bf16") -> PagedKVCache:
    """One layer's physical block pool (shared by every request)."""
    _check_kv_dtype(kv_dtype)
    shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    if kv_dtype != "bf16":
        sshape = (num_blocks, block_size, cfg.n_kv_heads)
        return PagedKVCache(jnp.zeros(shape, jnp.int8),
                            jnp.zeros(shape, jnp.int8),
                            jnp.zeros((), jnp.int32),
                            jnp.zeros(sshape, jnp.float32),
                            jnp.zeros(sshape, jnp.float32),
                            jnp.asarray(kv_qmax(kv_dtype), jnp.float32))
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.zeros((), jnp.int32))


def _decode_attention_hd_sharded(q, k, v, *, q_offset, kv_len, window=0,
                                 logit_cap=0.0):
    """Few-KV-head decode attention: shard_map over "model" with the KV cache
    sharded on head_dim.

    When n_kv < TP the cache can't shard on heads; sharding cache *length*
    makes the per-token append all-gather the cache every layer (310 GB/step
    measured on nemotron decode_32k — §Perf iteration 3). Sharding head_dim
    keeps the append local; the score contraction over hd psums a
    [b, h, s, L] tile instead. Returns None when not applicable (no mesh /
    divisibility) so the caller falls back to the chunked path.
    """
    from .layers import _active_mesh
    mesh = _active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes["model"]
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    if tp == 1 or n_kv % tp == 0 or hd % tp != 0:
        return None     # regular head sharding works / hd not shardable
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bshard = 1
    for a in batch_axes:
        bshard *= sizes[a]
    bspec = (batch_axes if batch_axes and b % bshard == 0 else None)

    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    scale = hd ** -0.5
    skv = k.shape[1]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(bspec, None, None, "model"),
                       P(bspec, None, None, "model"),
                       P(bspec, None, None, "model"),
                       P(), P()),
             out_specs=P(bspec, None, None, "model"),
             check_rep=False)
    def attn(q_l, k_l, v_l, off, klen):
        n_rep = q_l.shape[2] // k_l.shape[2]
        kk = jnp.repeat(k_l, n_rep, axis=2)
        vv = jnp.repeat(v_l, n_rep, axis=2)
        s_part = jnp.einsum("bqhd,bkhd->bhqk", q_l.astype(jnp.float32),
                            kk.astype(jnp.float32))
        scores = jax.lax.psum(s_part, "model") * scale
        if logit_cap > 0:
            scores = logit_cap * jnp.tanh(scores / logit_cap)
        q_pos = off + jnp.arange(q_l.shape[1], dtype=jnp.int32)
        kv_pos = jnp.arange(kk.shape[1], dtype=jnp.int32)
        mask = (kv_pos[None, :] < klen) & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
        return out.astype(v_l.dtype)

    return attn(q, k, v, jnp.asarray(q_offset, jnp.int32),
                jnp.asarray(kv_len, jnp.int32))
