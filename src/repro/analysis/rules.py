"""JAX-specific AST lint rules (stdlib ``ast`` only — no third-party deps).

Rule catalogue (see ``docs/static_analysis.md`` for rationale + examples):

* ``RA001`` — host-sync calls on the decode hot path: ``.item()``,
  ``.block_until_ready()``, ``jax.device_get``, and ``np.asarray`` /
  ``np.array`` / ``int()`` / ``float()`` / ``bool()`` applied to a
  device-valued expression. Scope: ``kernels/``, ``models/``, ``serve/``.
* ``RA002`` — Python side effects inside traced scopes (``@jax.jit``
  functions, functions handed to ``jax.jit``/``pallas_call``): ``print``,
  ``jax.debug.print`` / ``jax.debug.breakpoint`` left enabled, ``global``
  mutation.
* ``RA003`` — donation hazards: a buffer passed at a ``donate_argnums``
  position of a jitted program is read again before being rebound.
* ``RA004`` — retrace bombs: f-strings or unhashable literals passed as
  ``static_argnames`` arguments of a jitted program.
* ``RA005`` — iteration over unordered sets feeding pytree / output
  construction (nondeterministic structure order).

Design notes: the pass is *per module* and *flow-approximate*. Within a
function, statements are walked in source order; names assigned from
``jnp.`` / ``jax.``-rooted expressions (or from calls whose method name
looks device-returning: ``*decode*``, ``*prefill*``, …) are tainted as
device values, and explicit host escapes (``jax.device_get``) clear the
taint. Branches of ``if``/``try`` are walked sequentially and loop
back-edges are not modeled — precise enough for this tree, cheap enough
to run on every push.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

RULES: Dict[str, str] = {
    "RA001": "host sync on the decode hot path",
    "RA002": "Python side effect inside a traced scope",
    "RA003": "donated buffer read after donation",
    "RA004": "non-hashable / f-string static jit argument (retrace bomb)",
    "RA005": "iteration over an unordered set feeding pytree construction",
}

# Method-name substrings treated as device-returning at call sites
# (``self.engine.decode_chunk(...)`` returns device arrays even though the
# linter can't see across the module boundary).
_DEVICE_HINTS = ("decode", "prefill", "generate", "forward", "sample")

_UNHASHABLE_NODES = (ast.JoinedStr, ast.List, ast.Dict, ast.Set,
                     ast.ListComp, ast.SetComp, ast.DictComp,
                     ast.GeneratorExp)


@dataclass
class JitMeta:
    """donate/static info recorded from one ``jax.jit(...)`` site."""

    donate: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()


@dataclass
class ModuleInfo:
    """Pass-1 facts: import aliases, jit wiring, traced function names."""

    np_aliases: Set[str] = field(default_factory=set)
    jnp_aliases: Set[str] = field(default_factory=set)
    jax_aliases: Set[str] = field(default_factory=set)
    # callee name (bare or dotted tail, e.g. "_decode") -> JitMeta
    jit_meta: Dict[str, JitMeta] = field(default_factory=dict)
    # function names whose bodies run under trace (jitted impls, kernels)
    traced_names: Set[str] = field(default_factory=set)


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _root_and_attr(node: ast.AST) -> Tuple[Optional[str], Optional[str]]:
    """(root name, terminal attr) of an Attribute chain; (name, None) for
    a bare Name."""
    if isinstance(node, ast.Name):
        return node.id, None
    if isinstance(node, ast.Attribute):
        attr = node.attr
        base = node.value
        while isinstance(base, ast.Attribute):
            base = base.value
        if isinstance(base, ast.Name):
            return base.id, attr
        return None, attr
    return None, None


def _const_ints(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return tuple(out)
    return ()


def _const_strs(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def collect_module_info(tree: ast.Module) -> ModuleInfo:
    info = ModuleInfo()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                if alias.name == "numpy":
                    info.np_aliases.add(alias.asname or "numpy")
                elif alias.name == "jax.numpy":
                    info.jnp_aliases.add(alias.asname or "jax")
                elif alias.name == "jax" or alias.name.startswith("jax."):
                    info.jax_aliases.add(name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for alias in node.names:
                    if alias.name == "numpy":
                        info.jnp_aliases.add(alias.asname or "numpy")
            elif node.module == "numpy":
                # `from numpy import asarray` — treat bare name as np-rooted
                for alias in node.names:
                    if alias.name in ("asarray", "array"):
                        info.np_aliases.add("")  # marker; not resolvable

    def is_jax_jit(fn: ast.AST) -> bool:
        root, attr = _root_and_attr(fn)
        return attr == "jit" and root in info.jax_aliases

    def record_jit_call(call: ast.Call, key: Optional[str]):
        meta = JitMeta()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                meta.donate = _const_ints(kw.value)
            elif kw.arg == "static_argnames":
                meta.static_names = _const_strs(kw.value)
        if call.args:
            _, impl_attr = _root_and_attr(call.args[0])
            impl_name = impl_attr or (
                call.args[0].id if isinstance(call.args[0], ast.Name)
                else None)
            if impl_name:
                info.traced_names.add(impl_name)
        if key:
            info.jit_meta[key] = meta

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            val = node.value
            if isinstance(val, ast.Call) and is_jax_jit(val.func):
                for tgt in node.targets:
                    _, tattr = _root_and_attr(tgt)
                    key = tattr or (tgt.id if isinstance(tgt, ast.Name)
                                    else None)
                    record_jit_call(val, key)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if is_jax_jit(dec):
                    info.traced_names.add(node.name)
                    info.jit_meta.setdefault(node.name, JitMeta())
                elif isinstance(dec, ast.Call):
                    _, dattr = _root_and_attr(dec.func)
                    if is_jax_jit(dec.func):
                        info.traced_names.add(node.name)
                        record_jit_call(dec, node.name)
                    elif dattr == "partial" or (
                            isinstance(dec.func, ast.Name)
                            and dec.func.id == "partial"):
                        if dec.args and is_jax_jit(dec.args[0]):
                            info.traced_names.add(node.name)
                            record_jit_call(dec, node.name)
        elif isinstance(node, ast.Call):
            _, attr = _root_and_attr(node.func)
            if attr == "pallas_call" or (
                    isinstance(node.func, ast.Name)
                    and node.func.id == "pallas_call"):
                if node.args and isinstance(node.args[0], ast.Name):
                    info.traced_names.add(node.args[0].id)
    return info


class _ScopeWalker:
    """Source-order walk of one function (or the module body) applying
    RA001/RA003/RA004/RA005 with local device/set taint tracking."""

    def __init__(self, info: ModuleInfo, path: str, hot: bool,
                 findings: List[Finding]):
        self.info = info
        self.path = path
        self.hot = hot
        self.findings = findings
        self.tainted: Set[str] = set()     # device-valued local names
        self.set_names: Set[str] = set()   # names bound to set objects
        self.donated: Dict[str, int] = {}  # name -> line it was donated at

    def flag(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            col=node.col_offset + 1, message=message))

    # -- device-taint classification ---------------------------------------
    def is_device_expr(self, node: ast.AST) -> bool:
        for sub in self._walk_skipping_host_escapes(node):
            if isinstance(sub, ast.Name) and sub.id in self.tainted:
                return True
            if isinstance(sub, ast.Attribute):
                root, _ = _root_and_attr(sub)
                if root in self.info.jnp_aliases:
                    return True
                if root in self.info.jax_aliases and sub.attr != "device_get":
                    return True
            if isinstance(sub, ast.Call):
                _, attr = _root_and_attr(sub.func)
                if attr and any(h in attr for h in _DEVICE_HINTS):
                    return True
        return False

    def _walk_skipping_host_escapes(self, node: ast.AST):
        """ast.walk, but don't descend into jax.device_get(...) calls or
        np-conversion calls — their results live on the host."""
        stack = [node]
        while stack:
            cur = stack.pop()
            if isinstance(cur, ast.Call) and self._is_host_escape(cur):
                continue
            yield cur
            stack.extend(ast.iter_child_nodes(cur))

    def _is_host_escape(self, call: ast.Call) -> bool:
        root, attr = _root_and_attr(call.func)
        if attr == "device_get" and root in self.info.jax_aliases:
            return True
        if attr in ("asarray", "array") and root in self.info.np_aliases:
            return True
        return False

    # -- statement sequencing ----------------------------------------------
    def walk_body(self, body: List[ast.stmt]):
        for stmt in body:
            self.walk_stmt(stmt)

    def walk_stmt(self, stmt: ast.stmt):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested defs get their own scope walk
        if isinstance(stmt, ast.Assign):
            self.visit_loads(stmt.value)
            dev = self.is_device_expr(stmt.value)
            is_set = self._is_set_expr(stmt.value)
            for tgt in stmt.targets:
                self.bind_target(tgt, dev, is_set)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self.visit_loads(stmt.value)
                self.bind_target(stmt.target,
                                 self.is_device_expr(stmt.value),
                                 self._is_set_expr(stmt.value))
        elif isinstance(stmt, ast.For):
            self.visit_loads(stmt.iter)
            self.check_set_iteration(stmt.iter)
            self.bind_target(stmt.target, self.is_device_expr(stmt.iter),
                             False)
            self.walk_body(stmt.body)
            self.walk_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self.visit_loads(stmt.test)
            self.walk_body(stmt.body)
            self.walk_body(stmt.orelse)
        elif isinstance(stmt, ast.If):
            self.visit_loads(stmt.test)
            self._walk_branch(stmt.body)
            self._walk_branch(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.visit_loads(item.context_expr)
            self.walk_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.walk_body(stmt.body)
            for handler in stmt.handlers:
                self.walk_body(handler.body)
            self.walk_body(stmt.orelse)
            self.walk_body(stmt.finalbody)
        elif isinstance(stmt, (ast.Return, ast.Expr, ast.Raise,
                               ast.Assert, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                self.visit_loads(child)
        # Pass/Break/Continue/Import/Global: nothing to scan here (Global
        # is handled by the RA002 traced-scope pass).

    def _walk_branch(self, body: List[ast.stmt]):
        """Walk a conditional branch; if it terminates (return/raise/…),
        its donations and taints never reach the fall-through code."""
        if not body:
            return
        snap = (dict(self.donated), set(self.tainted), set(self.set_names))
        self.walk_body(body)
        if isinstance(body[-1], (ast.Return, ast.Raise, ast.Break,
                                 ast.Continue)):
            self.donated, self.tainted, self.set_names = \
                snap[0], snap[1], snap[2]

    def bind_target(self, tgt: ast.AST, device: bool, is_set: bool):
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self.bind_target(elt, device, is_set)
            return
        name = _dotted(tgt)
        if name is None:
            return
        self.donated.pop(name, None)
        if device:
            self.tainted.add(name)
        else:
            self.tainted.discard(name)
        if is_set:
            self.set_names.add(name)
        else:
            self.set_names.discard(name)

    def _is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub)):
            return self._is_set_expr(node.left) or \
                self._is_set_expr(node.right)
        if isinstance(node, ast.Name):
            return node.id in self.set_names
        return False

    # -- expression scanning (loads) ---------------------------------------
    def visit_loads(self, node: ast.AST):
        # donated reads first, against donations from *earlier* statements
        # only: a statement's arg reads happen before its own call donates
        # (`caches = f(caches)` is the sound rebind pattern, not a hazard).
        self._check_donated_reads(node)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self.check_call(sub)
            elif isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                  ast.GeneratorExp)):
                for gen in sub.generators:
                    self.check_set_iteration(gen.iter)

    def _check_donated_reads(self, node: ast.AST):
        for sub in ast.walk(node):
            name = _dotted(sub) if isinstance(
                sub, (ast.Name, ast.Attribute)) else None
            if name in self.donated and isinstance(
                    getattr(sub, "ctx", None), ast.Load):
                line = self.donated.pop(name)
                self.flag("RA003", sub,
                          f"`{name}` was donated to a jitted program at "
                          f"line {line} and read again before being "
                          f"rebound — donated buffers are invalidated by "
                          f"the call")

    def check_call(self, call: ast.Call):
        root, attr = _root_and_attr(call.func)
        fname = call.func.id if isinstance(call.func, ast.Name) else None

        # RA001 — host syncs (hot-path scope only)
        if self.hot:
            if attr == "item" and not call.args:
                self.flag("RA001", call,
                          "`.item()` forces a device→host sync")
            elif attr == "block_until_ready":
                self.flag("RA001", call,
                          "`.block_until_ready()` blocks the dispatch "
                          "pipeline")
            elif attr == "device_get" and root in self.info.jax_aliases:
                self.flag("RA001", call,
                          "`jax.device_get` is a device→host sync")
            elif attr in ("asarray", "array") \
                    and root in self.info.np_aliases and call.args \
                    and self.is_device_expr(call.args[0]):
                self.flag("RA001", call,
                          f"`np.{attr}` of a device value is an implicit "
                          f"device→host sync")
            elif fname in ("int", "float", "bool") \
                    and len(call.args) == 1 \
                    and self.is_device_expr(call.args[0]):
                self.flag("RA001", call,
                          f"`{fname}()` of a device value is an implicit "
                          f"device→host sync")

        # RA003 / RA004 — jitted-program call sites
        key = attr or fname
        meta = self.info.jit_meta.get(key) if key else None
        if meta is not None:
            for idx in meta.donate:
                if idx < len(call.args):
                    name = _dotted(call.args[idx])
                    if name:
                        self.donated[name] = call.lineno
            for kw in call.keywords:
                if kw.arg in meta.static_names and isinstance(
                        kw.value, _UNHASHABLE_NODES):
                    what = ("an f-string"
                            if isinstance(kw.value, ast.JoinedStr)
                            else "an unhashable literal")
                    self.flag("RA004", kw.value,
                              f"static jit arg `{kw.arg}` built from "
                              f"{what} — every call compiles a new "
                              f"program (retrace bomb)")

    def check_set_iteration(self, iter_node: ast.AST):
        if self._is_set_expr(iter_node):
            self.flag("RA005", iter_node,
                      "iterating an unordered set — ordering is "
                      "nondeterministic across processes; sort first if "
                      "the order feeds pytree/output structure")


class _TracedScopeChecker(ast.NodeVisitor):
    """RA002: side effects in functions that run under trace."""

    def __init__(self, info: ModuleInfo, path: str,
                 findings: List[Finding]):
        self.info = info
        self.path = path
        self.findings = findings
        self._traced_depth = 0

    def flag(self, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule="RA002", path=self.path, line=node.lineno,
            col=node.col_offset + 1, message=message))

    def visit_FunctionDef(self, node: ast.FunctionDef):
        traced = node.name in self.info.traced_names
        if traced:
            self._traced_depth += 1
        self.generic_visit(node)
        if traced:
            self._traced_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        if self._traced_depth > 0:
            root, attr = _root_and_attr(node.func)
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                self.flag(node, "`print` inside a traced scope runs at "
                                "trace time only (or forces a callback) — "
                                "remove or use jax.debug.print behind a "
                                "debug flag")
            elif attr in ("print", "breakpoint") and root in \
                    self.info.jax_aliases:
                self.flag(node, f"`jax.debug.{attr}` left enabled in a "
                                f"traced scope — every decode step pays "
                                f"for the host callback")
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global):
        if self._traced_depth > 0:
            names = ", ".join(node.names)
            self.flag(node, f"`global {names}` inside a traced scope — "
                            f"mutation runs at trace time, not per call")
        self.generic_visit(node)


def run_rules(tree: ast.Module, path: str, hot: bool) -> List[Finding]:
    """All rules over one parsed module; returns unsuppressed findings."""
    info = collect_module_info(tree)
    findings: List[Finding] = []

    # RA001/RA003/RA004/RA005 — one scope walk per function + module body
    module_walker = _ScopeWalker(info, path, hot, findings)
    module_walker.walk_body([s for s in tree.body
                             if not isinstance(s, (ast.FunctionDef,
                                                   ast.AsyncFunctionDef,
                                                   ast.ClassDef))])
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walker = _ScopeWalker(info, path, hot, findings)
            walker.walk_body(node.body)

    # RA002 — traced-scope side effects
    _TracedScopeChecker(info, path, findings).visit(tree)
    return findings
