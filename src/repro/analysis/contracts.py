"""Static Pallas kernel-contract checker — zero device launches.

A bad tuning-table entry should fail CI, not fault on device. This module
verifies, for the **full cross-product** of candidate block shapes the
selectors in ``repro.kernels.tuning`` can ever return:

* ``KC001`` — VMEM budget: the per-grid-step working set of every
  candidate fits ``VMEM_BUDGET``.
* ``KC002`` — grid/index-map divisibility: packed-int4 K blocks are even,
  n-tiles are lane-aligned (multiples of 128) unless they cover the whole
  (padded) dim, low-rank blocks are ``LOWRANK_MULTIPLE``-aligned after
  ``pad_lowrank`` (including odd raw ranks), and the scalar-prefetch
  gather BlockSpecs see pool-uniform padded adapter ranks.
* ``KC003`` — dtype contracts: int4-in-int8 storage, int32 accumulators,
  f32 scale lanes — checked as required dtype tokens per kernel module.
* ``KC004`` — structural: every ``pallas_call`` site in ``kernels/`` must
  belong to a registered kernel with a VMEM cost model, pass ``out_shape``
  and a grid, and thread an ``interpret`` flag.
* ``KC005`` — cost-model consistency: each tuning cost function must
  equal the working set re-derived here from the kernel's actual
  BlockSpec shapes (an undercounting model would silently re-admit
  over-budget shapes). The same rule covers the **measured autotune
  cache** (``repro.kernels.autotune``): every persisted winner must name
  a choice inside the exported candidate lattices and under the VMEM
  budget (``check_autotune_cache``), so a cached BlockSpec can never
  reach a kernel the offline cross-product didn't validate.

Everything is pure Python over static shapes: the kernels are parsed with
``ast``, never imported, and no array is ever created.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding
from repro.kernels import tuning

CONTRACT_RULES: Dict[str, str] = {
    "KC001": "kernel candidate exceeds the VMEM budget",
    "KC002": "kernel candidate violates grid/index-map divisibility",
    "KC003": "kernel module missing a required dtype contract",
    "KC004": "pallas_call site outside the kernel registry",
    "KC005": "tuning cost model disagrees with derived working set",
}

# repro.kernels.ops re-exports these; duplicated logic here would drift.
LOWRANK_MULTIPLE = 8


def _padded_rank(r: int) -> int:
    """Mirror of ``ops.pad_lowrank``: rank 0 pads to one full multiple."""
    if r == 0:
        return LOWRANK_MULTIPLE
    return r + (-r) % LOWRANK_MULTIPLE


# -- candidate cross-products -------------------------------------------------
# Representative serving (k, n) projection shapes: qkv/out/mlp in/out for
# d_model 1k–8k (incl. the 3.5x MLP of the 4k config). The *block*
# lattices come from tuning's exported candidate tables, so any entry a
# selector could return is covered.
CONTRACT_KN_SHAPES: Tuple[Tuple[int, int], ...] = (
    (1024, 1024), (2048, 2048), (2048, 8192), (4096, 4096),
    (4096, 14336), (8192, 2048), (8192, 8192),
)
CONTRACT_GEMM_MS: Tuple[int, ...] = (1, 16, 128, 256, 512, 1024)
# raw (pre-padding) low-rank ranks, odd ones included on purpose
CONTRACT_RAW_RANKS: Tuple[int, ...] = (0, 3, 8, 12, 16, 33, 64)
CONTRACT_ADAPTER_RANKS: Tuple[int, ...] = (4, 8, 16, 32)
PAGED_BLOCK_SIZES: Tuple[int, ...] = (8, 16, 32, 64, 128)
PAGED_GROUPS: Tuple[int, ...] = (1, 2, 4, 8)
PAGED_HEAD_DIMS: Tuple[int, ...] = (64, 128, 256)
FLASH_SEQ_LENS: Tuple[int, ...] = (256, 512, 1024, 2048, 4096)
FLASH_HEAD_DIMS: Tuple[int, ...] = (64, 128)
FLASH_BQ: Tuple[int, ...] = (128,)


# -- derived working sets (mirror each kernel's BlockSpecs) -------------------
def derived_gemm_vmem(bm: int, bn: int, bk: int, r: int) -> int:
    blocks = [
        ((bm, bk), 1),        # xq tile, int8
        ((bk // 2, bn), 1),   # packed int4-in-int8 weights
        ((bk, bn), 1),        # VPU-unpacked int8 weight tile
        ((bm, bn), 4),        # int32 accumulator scratch
        ((bm, 1), 4),         # sx scales, f32
        ((1, bn), 4),         # sw scales, f32
        ((bm, r), 4),         # xlr low-rank activations, f32
        ((r, bn), 4),         # la low-rank factor tile, f32
    ]
    return sum(a * b * size for (a, b), size in blocks)


def derived_fused_vmem(m: int, k: int, bn: int, r: int) -> int:
    blocks = [
        ((m, k), 4),          # x working copy, f32
        ((1, k), 4),          # m_diag, f32
        ((m, k), 4),          # xq int32 codes
        ((k // 2, bn), 1),    # packed weights
        ((k, bn), 1),         # unpacked int8 tile
        ((m, bn), 4),         # accumulator / out tile, f32
        ((1, bn), 4),         # sw, f32
        ((k, r), 4),          # lb, f32
        ((r, bn), 4),         # la, f32
        ((m, r), 4),          # xlr, f32
    ]
    return sum(a * b * size for (a, b), size in blocks)


def derived_gather_vmem(k: int, bn: int, r: int, ra: int) -> int:
    extra = [
        ((k, ra), 4),         # gathered alb block
        ((ra, bn), 4),        # gathered ala tile
        ((1, ra), 4),         # x_s @ alb intermediate
    ]
    return derived_fused_vmem(1, k, bn, r) + sum(
        a * b * size for (a, b), size in extra)


def derived_paged_vmem(block_size: int, group: int, hd: int,
                       quantized: bool) -> int:
    blocks = [
        ((2 * block_size, hd), 4),   # k + v f32 working copies
        ((group, hd), 4),            # q group
        ((group, block_size), 4),    # score tile
        ((2 * group, 1), 4),         # online-softmax m, l scratch
        ((group, hd), 4),            # acc scratch
        ((group, hd), 4),            # out tile
    ]
    if quantized:
        blocks += [
            ((2 * block_size, hd), 1),   # int8 code tiles
            ((2 * block_size, 1), 4),    # per-slot scale tiles
        ]
    return sum(a * b * size for (a, b), size in blocks)


def derived_flash_vmem(bq: int, skv: int, d: int) -> int:
    blocks = [
        ((bq, d), 4),         # q tile
        ((2 * skv, d), 4),    # whole-KV k + v (the kernel holds full KV)
        ((bq, skv), 4),       # score tile
        ((bq, d), 4),         # out tile
    ]
    return sum(a * b * size for (a, b), size in blocks)


# -- registry: every pallas_call site must map to one of these ----------------
# module basename -> (expected pallas_call count, cost model name)
KERNEL_REGISTRY: Dict[str, Tuple[int, str]] = {
    "w4a8_gemm.py": (1, "vmem_bytes"),
    "w4a8_fused.py": (2, "fused_vmem_bytes/gather_vmem_bytes"),
    "act_quant.py": (1, "fused_vmem_bytes (quant stage subset)"),
    "paged_attention.py": (1, "paged_vmem_bytes"),
    "flash_attention.py": (1, "derived_flash_vmem (contracts-local)"),
}

# module basename -> dtype tokens that must appear (int4-in-int8 storage,
# int32 accumulation, f32 scale lanes)
DTYPE_CONTRACTS: Dict[str, Tuple[str, ...]] = {
    "w4a8_gemm.py": ("int8", "int32", "float32"),
    # the fused kernel reuses the GEMM's unpack helper for its int4-in-
    # int8 storage; the helper's name is the storage-contract evidence
    "w4a8_fused.py": ("unpack_int4_block", "float32"),
    "act_quant.py": ("int8", "float32"),
    "paged_attention.py": ("float32",),
}


def _finding(rule: str, path: str, line: int, msg: str) -> Finding:
    return Finding(rule=rule, path=path, line=line, col=1, message=msg)


# -- table checks -------------------------------------------------------------
def check_gemm_candidates(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    out: List[Finding] = []
    path = "repro/kernels/tuning.py"

    def check_blocks(bm, bn, bk, r, origin):
        derived = derived_gemm_vmem(bm, bn, bk, r)
        modeled = tuning.vmem_bytes(bm, bn, bk, r)
        if modeled != derived:
            out.append(_finding(
                "KC005", path, 1,
                f"vmem_bytes({bm},{bn},{bk},r={r}) = {modeled} but the "
                f"BlockSpec-derived working set is {derived} ({origin})"))
        if bk % 2 != 0:
            out.append(_finding(
                "KC002", path, 1,
                f"GEMM bk={bk} must be even: the packed int4 weight block "
                f"is (bk//2, bn) and odd bk drops a K row ({origin})"))
        if r != 0 and r % LOWRANK_MULTIPLE != 0:
            out.append(_finding(
                "KC002", path, 1,
                f"GEMM low-rank r={r} not a multiple of "
                f"{LOWRANK_MULTIPLE}; pad_lowrank must run first "
                f"({origin})"))
        return derived

    # explicit table entries must fit at their keyed rank
    for (mb, k, n, r), (bm, bn, bk) in sorted(
            tuning.GEMM_BLOCK_TABLE.items()):
        origin = f"GEMM_BLOCK_TABLE[{(mb, k, n, r)}]"
        derived = check_blocks(bm, bn, bk, r, origin)
        if derived > budget:
            out.append(_finding(
                "KC001", path, 1,
                f"{origin} -> ({bm},{bn},{bk}) needs {derived} B of VMEM "
                f"(> budget {budget})"))
        if bk > k or bm > mb * 4:
            out.append(_finding(
                "KC002", path, 1,
                f"{origin} -> ({bm},{bn},{bk}) exceeds its keyed shape"))

    # the whole search lattice: anything the modeled search can pick must
    # be divisible-sound; budget is enforced by the search itself, but the
    # selected result for every representative shape must come back under
    # budget after min() clamping
    for bm in tuning.GEMM_BM_CANDIDATES:
        for bn in tuning.GEMM_BN_CANDIDATES:
            for bk in tuning.GEMM_BK_CANDIDATES:
                for raw_r in CONTRACT_RAW_RANKS:
                    check_blocks(bm, bn, bk, _padded_rank(raw_r),
                                 "search lattice")
    for m in CONTRACT_GEMM_MS:
        for k, n in CONTRACT_KN_SHAPES:
            for raw_r in CONTRACT_RAW_RANKS:
                r = _padded_rank(raw_r)
                bm, bn, bk = tuning.select_gemm_blocks(m, k, n, r)
                derived = derived_gemm_vmem(bm, bn, bk, r)
                if derived > budget:
                    out.append(_finding(
                        "KC001", path, 1,
                        f"select_gemm_blocks(m={m},k={k},n={n},r={r}) -> "
                        f"({bm},{bn},{bk}) needs {derived} B (> budget "
                        f"{budget})"))
                if bk % 2 != 0 or bk > k:
                    out.append(_finding(
                        "KC002", path, 1,
                        f"select_gemm_blocks(m={m},k={k},n={n},r={r}) -> "
                        f"bk={bk} (odd or > k)"))
    return out


def check_fused_candidates(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    out: List[Finding] = []
    path = "repro/kernels/tuning.py"
    for m in range(1, tuning.DECODE_M_MAX + 1):
        for k, n in CONTRACT_KN_SHAPES:
            if k % 2 != 0:
                out.append(_finding(
                    "KC002", path, 1,
                    f"fused kernel requires even K, got k={k}"))
                continue
            for raw_r in CONTRACT_RAW_RANKS:
                r = _padded_rank(raw_r)
                bn = tuning.fused_bn(m, k, n, r, budget=budget)
                if bn is None:
                    continue  # routed to the two-kernel pipeline
                derived = derived_fused_vmem(m, k, bn, r)
                modeled = tuning.fused_vmem_bytes(m, k, bn, r)
                if modeled != derived:
                    out.append(_finding(
                        "KC005", path, 1,
                        f"fused_vmem_bytes(m={m},k={k},bn={bn},r={r}) = "
                        f"{modeled}, derived {derived}"))
                if derived > budget:
                    out.append(_finding(
                        "KC001", path, 1,
                        f"fused_bn(m={m},k={k},n={n},r={r}) -> bn={bn} "
                        f"needs {derived} B (> budget {budget})"))
                if bn % 128 != 0 and bn != n:
                    out.append(_finding(
                        "KC002", path, 1,
                        f"fused bn={bn} neither lane-aligned (128) nor "
                        f"the whole n={n}"))
                if bn > n:
                    out.append(_finding(
                        "KC002", path, 1,
                        f"fused bn={bn} exceeds n={n}"))
    # tiled-m prefill variant: everything fused_tiles can return must fit
    for m in CONTRACT_GEMM_MS:
        if m <= tuning.DECODE_M_MAX:
            continue
        for k, n in CONTRACT_KN_SHAPES:
            for raw_r in CONTRACT_RAW_RANKS:
                r = _padded_rank(raw_r)
                tiles = tuning.fused_tiles(m, k, n, r, budget=budget)
                if tiles is None:
                    continue
                bm, bn = tiles
                derived = derived_fused_vmem(bm, k, bn, r)
                if derived > budget:
                    out.append(_finding(
                        "KC001", path, 1,
                        f"fused_tiles(m={m},k={k},n={n},r={r}) -> "
                        f"({bm},{bn}) needs {derived} B (> budget "
                        f"{budget})"))
                if bn % 128 != 0 and bn != n:
                    out.append(_finding(
                        "KC002", path, 1,
                        f"fused_tiles bn={bn} neither lane-aligned (128) "
                        f"nor the whole n={n}"))
    return out


def check_autotune_cache(budget: int = tuning.VMEM_BUDGET,
                         backend: str | None = None) -> List[Finding]:
    """KC005 cache mode: every entry in the measured autotune cache must
    name a choice inside the exported candidate lattices and under the
    VMEM budget (``repro.kernels.autotune.validate_entry`` — the same
    check consult-time lookups apply, so a finding here means the entry
    would also be silently ignored at runtime; CI fails instead of
    shipping a cache that quietly falls back to the model). Walks the
    active cache for ``backend`` — user file if present, else the
    checked-in baseline — plus any demoted tombstones, which are reported
    as informational-grade findings only when *also* invalid."""
    from repro.kernels import autotune
    out: List[Finding] = []
    cache = autotune.AutotuneCache(backend)   # fresh load, not the singleton
    rel = str(cache.path) if cache._loaded_from == "user" else \
        "repro/kernels/autotune_baseline.json"
    for key, entry in sorted(cache.entries.items()):
        reason = autotune.validate_entry(key, entry, budget)
        if reason is not None:
            out.append(_finding("KC005", rel, 1,
                                f"autotune cache entry invalid: {reason}"))
    return out


def check_gather_candidates(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    out: List[Finding] = []
    path = "repro/kernels/tuning.py"
    for k, n in CONTRACT_KN_SHAPES:
        for raw_r in CONTRACT_RAW_RANKS:
            r = _padded_rank(raw_r)
            for raw_ra in CONTRACT_ADAPTER_RANKS:
                ra = _padded_rank(raw_ra)
                if ra % LOWRANK_MULTIPLE != 0:
                    out.append(_finding(
                        "KC002", path, 1,
                        f"adapter rank ra={ra} not pool-uniform padded "
                        f"to {LOWRANK_MULTIPLE} — the gather BlockSpec "
                        f"((None, k, ra)) requires one uniform ra across "
                        f"the pool"))
                bn = tuning.fused_gather_bn(k, n, r, ra, budget=budget)
                if bn is None:
                    continue
                derived = derived_gather_vmem(k, bn, r, ra)
                modeled = tuning.gather_vmem_bytes(k, bn, r, ra)
                if modeled != derived:
                    out.append(_finding(
                        "KC005", path, 1,
                        f"gather_vmem_bytes(k={k},bn={bn},r={r},ra={ra}) "
                        f"= {modeled}, derived {derived}"))
                if derived > budget:
                    out.append(_finding(
                        "KC001", path, 1,
                        f"fused_gather_bn(k={k},n={n},r={r},ra={ra}) -> "
                        f"bn={bn} needs {derived} B (> budget {budget})"))
    return out


def check_paged_candidates(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    out: List[Finding] = []
    path = "repro/kernels/tuning.py"
    for bs in PAGED_BLOCK_SIZES:
        for group in PAGED_GROUPS:
            for hd in PAGED_HEAD_DIMS:
                for quantized in (False, True):
                    derived = derived_paged_vmem(bs, group, hd, quantized)
                    modeled = tuning.paged_vmem_bytes(bs, group, hd,
                                                      quantized)
                    if modeled != derived:
                        out.append(_finding(
                            "KC005", path, 1,
                            f"paged_vmem_bytes(bs={bs},g={group},hd={hd},"
                            f"quantized={quantized}) = {modeled}, derived "
                            f"{derived}"))
                    routed = tuning.use_paged_kernel(
                        1, 1, bs, group, hd, budget=budget,
                        quantized=quantized)
                    if routed and derived > budget:
                        out.append(_finding(
                            "KC001", path, 1,
                            f"use_paged_kernel admits (bs={bs},g={group},"
                            f"hd={hd},quantized={quantized}) at {derived} "
                            f"B (> budget {budget})"))
    return out


def check_flash_candidates(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    out: List[Finding] = []
    path = "repro/kernels/flash_attention.py"
    for bq in FLASH_BQ:
        for skv in FLASH_SEQ_LENS:
            for d in FLASH_HEAD_DIMS:
                derived = derived_flash_vmem(min(bq, skv), skv, d)
                if derived > budget:
                    out.append(_finding(
                        "KC001", path, 1,
                        f"flash attention (bq={bq},skv={skv},d={d}) holds "
                        f"whole-KV in VMEM: {derived} B (> budget "
                        f"{budget}) — shrink the supported prefill "
                        f"envelope or tile KV"))
    return out


# -- pallas_call structural walk ---------------------------------------------
def _pallas_call_sites(tree: ast.Module) -> List[ast.Call]:
    sites = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None)
            if name == "pallas_call":
                sites.append(node)
    return sites


def check_kernel_sources(kernels_dir: str) -> List[Finding]:
    out: List[Finding] = []
    for fname in sorted(os.listdir(kernels_dir)):
        if not fname.endswith(".py") or fname == "__init__.py":
            continue
        path = os.path.join(kernels_dir, fname)
        rel = f"repro/kernels/{fname}"
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        sites = _pallas_call_sites(tree)
        expected = KERNEL_REGISTRY.get(fname)
        if sites and expected is None:
            out.append(_finding(
                "KC004", rel, sites[0].lineno,
                f"pallas_call site in unregistered module {fname}: add a "
                f"VMEM cost model to kernels/tuning.py and register it "
                f"in analysis.contracts.KERNEL_REGISTRY"))
        elif expected is not None and len(sites) != expected[0]:
            out.append(_finding(
                "KC004", rel, sites[0].lineno if sites else 1,
                f"{fname} has {len(sites)} pallas_call sites, registry "
                f"expects {expected[0]} (cost model: {expected[1]}) — "
                f"update the registry and cost model together"))
        for site in sites:
            kwargs = {kw.arg for kw in site.keywords if kw.arg}
            if "out_shape" not in kwargs:
                out.append(_finding(
                    "KC004", rel, site.lineno,
                    "pallas_call without out_shape"))
            if not ({"grid", "grid_spec"} & kwargs):
                out.append(_finding(
                    "KC004", rel, site.lineno,
                    "pallas_call without grid/grid_spec — implicit "
                    "whole-array blocks bypass the VMEM cost model"))
            if "interpret" not in kwargs:
                out.append(_finding(
                    "KC004", rel, site.lineno,
                    "pallas_call without an interpret flag — kernels "
                    "must stay runnable on the CPU interpret backend"))
        dtypes_needed = DTYPE_CONTRACTS.get(fname, ())
        present = {node.attr for node in ast.walk(tree)
                   if isinstance(node, ast.Attribute)}
        present |= {node.id for node in ast.walk(tree)
                    if isinstance(node, ast.Name)}
        for tok in dtypes_needed:
            if tok not in present:
                out.append(_finding(
                    "KC003", rel, 1,
                    f"dtype contract: expected `{tok}` usage in {fname} "
                    f"(int4-in-int8 storage / f32 scale lanes) but the "
                    f"token never appears"))
    return out


def check_kernel_contracts(kernels_dir: str,
                           budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    """Run every contract check; returns all findings (empty = pass)."""
    findings: List[Finding] = []
    findings += check_gemm_candidates(budget)
    findings += check_fused_candidates(budget)
    findings += check_gather_candidates(budget)
    findings += check_paged_candidates(budget)
    findings += check_flash_candidates(budget)
    findings += check_kernel_sources(kernels_dir)
    findings += check_autotune_cache(budget)
    return findings
