"""Runtime sanitizers: retrace counting and implicit-transfer guarding.

Shared by the ``tests/sanitizers.py`` pytest plugin and the
``serve_bench`` steady-state audit, so the test suite and the benchmark
enforce the same two invariants on continuous decode after warmup:

* **zero recompiles** — every scheduler step reuses compiled programs
  (counted via the ``jax.monitoring`` backend-compile event, which fires
  once per compilation and never on cache hits);
* **zero implicit transfers** — the only device↔host crossings are the
  explicit ``jax.device_get`` readbacks / ``jnp.asarray`` uploads the
  scheduler owns (enforced with ``jax.transfer_guard("disallow")``,
  which permits explicit transfers and aborts on implicit ones).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Iterator, List

import jax

# Fired once per backend compilation (trace -> lower -> compile); cache
# hits emit nothing, so deltas of this counter count retraces exactly.
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_compile_count = 0
_listener_installed = False


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    global _compile_count
    if event == _COMPILE_EVENT:
        _compile_count += 1


def _ensure_listener() -> None:
    # One process-lifetime listener; jax.monitoring has no public
    # unregister, so contexts snapshot the counter instead.
    global _listener_installed
    if not _listener_installed:
        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _listener_installed = True


def compile_count() -> int:
    """Total backend compilations observed so far in this process."""
    _ensure_listener()
    return _compile_count


@dataclass
class CompileCounter:
    """Snapshot-delta view over the process compile counter."""

    start: int = 0
    end: int = 0
    closed: bool = False

    @property
    def count(self) -> int:
        return (self.end if self.closed else compile_count()) - self.start


@contextlib.contextmanager
def compile_counter() -> Iterator[CompileCounter]:
    """Count backend compilations inside the block::

        with compile_counter() as cc:
            scheduler.run()
        assert cc.count == 0
    """
    _ensure_listener()
    counter = CompileCounter(start=compile_count())
    try:
        yield counter
    finally:
        counter.end = compile_count()
        counter.closed = True


@contextlib.contextmanager
def no_implicit_transfers() -> Iterator[None]:
    """Forbid implicit device↔host transfers inside the block.

    Explicit crossings (``jax.device_get``, ``jax.device_put``,
    ``jnp.asarray`` of host data) stay legal; implicit ones (a numpy
    array silently uploaded into a jitted call, ``int()`` of a device
    scalar) raise at the offending call site.
    """
    with jax.transfer_guard("disallow"):
        yield


@dataclass
class SteadyStateReport:
    """Result of :func:`audit_steady_state`."""

    recompiles: int
    implicit_transfers: int
    steps: int
    errors: List[str] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return self.recompiles == 0 and self.implicit_transfers == 0

    @property
    def h2d_transfers_per_step(self) -> float:
        return self.implicit_transfers / max(1, self.steps)


def audit_steady_state(make_scheduler, submit) -> SteadyStateReport:
    """Warm up, then replay the identical workload under the sanitizers.

    ``make_scheduler()`` must build a fresh scheduler over a *shared,
    already-constructed* engine (so jit caches persist across the two
    runs) and ``submit(scheduler)`` enqueues the workload. The first
    run compiles every program the workload needs; the second run is the
    steady state under audit: it must hit only compiled programs and
    perform only explicit transfers.
    """
    warm = make_scheduler()
    submit(warm)
    warm.run()

    sched = make_scheduler()
    submit(sched)
    steps = 0
    errors: List[str] = []
    implicit = 0
    with compile_counter() as cc:
        try:
            with no_implicit_transfers():
                while sched.step():
                    steps += 1
        except Exception as err:  # transfer guard aborts at 1st violation
            implicit = 1
            errors.append(f"{type(err).__name__}: {err}")
    return SteadyStateReport(recompiles=cc.count,
                             implicit_transfers=implicit,
                             steps=max(steps, 1), errors=errors)
