"""Static analysis and runtime sanitizers for the JAX/Pallas serving stack.

Three layers, one purpose: keep the decode hot path sync-free,
retrace-free, and inside its modeled VMEM budget —

* :mod:`repro.analysis.lint` — a stdlib-``ast`` lint with JAX-specific
  rules (``RA001``–``RA005``: host syncs on the hot path, side effects
  under trace, donation hazards, retrace bombs, unordered-set pytrees).
  Rule catalogue: ``docs/static_analysis.md``.
* :mod:`repro.analysis.contracts` — a static Pallas kernel-contract
  checker that walks every ``pallas_call`` site and the full tuning
  candidate cross-product without touching a device.
* :mod:`repro.analysis.sanitizers` — runtime transfer-guard / retrace
  counters shared by ``tests/sanitizers.py`` and ``benchmarks/serve_bench``.

CLI entry point: ``tools/repro_analyze.py``.
"""
from repro.analysis.findings import Finding, findings_to_json
from repro.analysis.lint import lint_source, lint_paths, lint_tree
from repro.analysis.rules import RULES

__all__ = [
    "Finding",
    "RULES",
    "findings_to_json",
    "lint_source",
    "lint_paths",
    "lint_tree",
]
