"""Finding records and ``# repro: noqa[RULE]`` suppression parsing."""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Set

# `# repro: noqa[RA001]` / `# repro: noqa[RA001,RA003]` — rule list is
# mandatory: a bare blanket suppression would hide new rules silently.
NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]")


@dataclass(frozen=True)
class Finding:
    """One lint/contract finding with a stable rule ID and location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }


@dataclass
class Suppressions:
    """Per-line rule suppressions parsed from source comments."""

    by_line: Dict[int, Set[str]] = field(default_factory=dict)

    @classmethod
    def parse(cls, source: str) -> "Suppressions":
        by_line: Dict[int, Set[str]] = {}
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = NOQA_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")}
                by_line.setdefault(lineno, set()).update(rules)
        return cls(by_line)

    def suppressed(self, finding: Finding) -> bool:
        return finding.rule in self.by_line.get(finding.line, ())

    def apply(self, findings: Iterable[Finding]) -> List[Finding]:
        return [f for f in findings if not self.suppressed(f)]


def findings_to_json(findings: Iterable[Finding], **extra) -> str:
    """Stable JSON document for CI artifacts / editor integration."""
    items = [f.to_dict() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.col, f.rule))]
    payload = {"findings": items, "count": len(items)}
    payload.update(extra)
    return json.dumps(payload, indent=2, sort_keys=True)
