"""Lint driver: file walking, hot-path classification, noqa filtering."""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional

from repro.analysis.findings import Finding, Suppressions
from repro.analysis.rules import run_rules

# Directories whose modules sit on (or feed) the decode hot path: RA001's
# host-sync scope. Everything else in src/repro is host-side orchestration
# where syncs are the point (calibration, checkpoint IO, reporting).
HOT_PATH_DIRS = ("kernels", "models", "serve")


def is_hot_path(path: str) -> bool:
    parts = os.path.normpath(path).replace("\\", "/").split("/")
    return any(d in parts for d in HOT_PATH_DIRS)


def lint_source(source: str, path: str = "<memory>",
                hot: Optional[bool] = None) -> List[Finding]:
    """Lint one module's source text. ``hot=None`` infers RA001 scope
    from the path (see :func:`is_hot_path`)."""
    if hot is None:
        hot = is_hot_path(path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        return [Finding(rule="RA000", path=path, line=err.lineno or 0,
                        col=(err.offset or 0), message=f"syntax error: "
                        f"{err.msg}")]
    findings = run_rules(tree, path, hot)
    return Suppressions.parse(source).apply(findings)


def lint_paths(paths: Iterable[str],
               root: Optional[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in paths:
        rel = os.path.relpath(path, root) if root else path
        with open(path, "r", encoding="utf-8") as f:
            findings.extend(lint_source(f.read(), rel))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def python_files(root: str) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                   if f.endswith(".py"))
    return out


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``.py`` file under ``root`` (paths reported relative to
    ``root``'s parent so findings read ``repro/serve/engine.py:…``)."""
    base = os.path.dirname(os.path.abspath(root))
    return lint_paths(python_files(root), root=base)
