"""Composable PTQ recipes: pluggable stages + a serializable container.

The ASER paper stresses that error reconstruction is *orthogonal* to the base
weight quantizer and that smoothing / compensation are independently
toggleable. The API mirrors that decomposition: a :class:`QuantRecipe` is a
frozen composition of six stages,

    Smoother           none | smoothquant | awq-scale | aser-outlier
    BaseQuantizer      rtn | gptq
    ErrorReconstructor none | lorc | l2qer | whitened-svd
    ActQuantSpec       bits + per_token / per_tensor granularity
    KVQuantSpec        KV-cache storage dtype (bf16 | int8 | int4)
    AdapterSpec        multi-tenant LoRA pools (rank + resident slots)

executed by :func:`repro.quant.apply.quantize_model`. Every legacy method
name (``rtn``, ``smoothquant``, ``gptq``, ``awq``, ``lorc``, ``l2qer``,
``aser``, ``aser_as``) resolves to a recipe through
:mod:`repro.quant.registry`, and new combinations compose for free
(e.g. awq-scale smoothing + GPTQ base + whitened-SVD reconstruction).

Recipes validate at construction — an unsupported stage combination raises
``ValueError`` immediately rather than silently falling back — and
round-trip through JSON via :meth:`QuantRecipe.to_dict` /
:meth:`QuantRecipe.from_dict` so quantized checkpoints can record exactly
how they were produced.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict

from repro.runtime import (ACT_GRANULARITIES, KV_CACHE_DTYPES,
                           SUPPORTED_ACT_BITS)

SMOOTHER_KINDS = ("none", "smoothquant", "awq-scale", "aser-outlier")
BASE_KINDS = ("none", "rtn", "gptq")
ER_KINDS = ("none", "lorc", "l2qer", "whitened-svd")

# v2 added the KVQuantSpec stage; v3 added the AdapterSpec stage. Older
# blobs (missing "kv" / "adapter" keys) still load with the stage defaults,
# so pre-existing checkpoints keep deserializing.
_RECIPE_FORMAT_VERSION = 3
_ACCEPTED_FORMAT_VERSIONS = (1, 2, 3)


@dataclasses.dataclass(frozen=True)
class Smoother:
    """Diagonal activation-smoothing stage: produces ``m`` with
    ``W X = (W M)(M^{-1} X)``; the runtime divides activations by ``m``."""

    kind: str = "none"
    alpha: float = 0.5      # smoothquant migration strength
    outlier_f: int = 32     # aser-outlier: |I_f| top channels of X̄ ⊙ W̄

    def __post_init__(self):
        if self.kind not in SMOOTHER_KINDS:
            raise ValueError(
                f"unknown smoother kind {self.kind!r}; one of {SMOOTHER_KINDS}")
        if self.kind == "smoothquant" and not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"smoothquant alpha must be in [0, 1]: {self.alpha}")
        if self.kind == "aser-outlier" and self.outlier_f < 1:
            raise ValueError(f"aser-outlier needs outlier_f >= 1: {self.outlier_f}")


@dataclasses.dataclass(frozen=True)
class BaseQuantizer:
    """Weight quantizer applied to the (smoothed) weight matrix.

    ``none`` is the fp passthrough (no quantization at all). AWQ is *not* a
    base kind: its scale search folds into the smoothing diagonal, so it is
    expressed as ``Smoother("awq-scale")`` over an RTN/GPTQ base — asking for
    ``BaseQuantizer("awq")`` raises with that pointer instead of silently
    degrading to RTN (the seed implementation's dead branch).
    """

    kind: str = "rtn"
    bits: int = 4
    damp: float = 1e-2      # GPTQ Hessian dampening

    def __post_init__(self):
        if self.kind == "awq":
            raise ValueError(
                "awq is not a base quantizer: its scale folds into the "
                "smoothing diagonal. Use Smoother(kind='awq-scale') composed "
                "with a 'rtn' or 'gptq' base instead.")
        if self.kind not in BASE_KINDS:
            raise ValueError(
                f"unknown base quantizer {self.kind!r}; one of {BASE_KINDS}")
        if self.kind != "none" and not 2 <= self.bits <= 8:
            raise ValueError(f"weight bits must be in [2, 8]: {self.bits}")


@dataclasses.dataclass(frozen=True)
class ErrorReconstructor:
    """Low-rank reconstruction of the quantization error E_q.

    ``whitened-svd`` is ASER's Gram-whitened SVD; ``alpha > 0`` switches to
    the paper's Eq. 9 adaptive rank selection, capped at ``rank``.
    """

    kind: str = "none"
    rank: int = 64
    alpha: float = 0.0
    damp: float = 1e-2      # Cholesky whitener damping

    def __post_init__(self):
        if self.kind not in ER_KINDS:
            raise ValueError(
                f"unknown reconstructor {self.kind!r}; one of {ER_KINDS}")
        if self.kind != "none" and self.rank < 1:
            raise ValueError(f"reconstruction rank must be >= 1: {self.rank}")
        if self.alpha < 0.0:
            raise ValueError(f"rank-selection alpha must be >= 0: {self.alpha}")
        if self.alpha > 0.0 and self.kind in ("lorc", "l2qer"):
            raise ValueError(
                f"{self.kind} has no adaptive-rank variant (alpha must be 0)")


@dataclasses.dataclass(frozen=True)
class ActQuantSpec:
    """Serving-time activation quantization the recipe was produced for
    (8 = paper's W4A8; 6/4 for W4A6/W4A4; 16 = weight-only)."""

    bits: int = 8
    granularity: str = "per_token"

    def __post_init__(self):
        if self.bits not in SUPPORTED_ACT_BITS:
            raise ValueError(f"activation bits must be one of "
                             f"{SUPPORTED_ACT_BITS}: {self.bits}")
        if self.granularity not in ACT_GRANULARITIES:
            raise ValueError(
                f"unknown act granularity {self.granularity!r}; "
                f"one of {ACT_GRANULARITIES}")

    def runtime(self, **kw):
        """The matching serving :class:`repro.runtime.RuntimeConfig`."""
        from repro.runtime import RuntimeConfig
        return RuntimeConfig(a_bits=self.bits,
                             act_granularity=self.granularity, **kw)


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Serving-time KV-cache quantization the recipe targets.

    The same abs-max-per-channel logic the paper applies to weights and
    activations, pointed at the KV cache: ``int8`` stores per-token
    per-kv-head symmetric codes next to f32 scales (``int4`` keeps the
    4-bit code grid in int8 storage — accuracy path only, no packing yet).
    ``bf16`` is the native passthrough. This stage is *serving* metadata —
    it changes no packed weights, only which ``ServeConfig(kv_dtype=...)``
    the recipe's deployments should use.
    """

    dtype: str = "bf16"

    def __post_init__(self):
        if self.dtype not in KV_CACHE_DTYPES:
            raise ValueError(f"kv cache dtype must be one of "
                             f"{KV_CACHE_DTYPES}: {self.dtype!r}")

    @property
    def bits(self) -> int:
        return {"bf16": 16, "int8": 8, "int4": 4}[self.dtype]

    @property
    def is_noop(self) -> bool:
        return self.dtype == "bf16"

    def serve_config(self, **kw):
        """The matching :class:`repro.serve.engine.ServeConfig`."""
        from repro.serve.engine import ServeConfig
        return ServeConfig(kv_dtype=self.dtype, **kw)


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """Multi-tenant LoRA adapter serving the recipe provisions for.

    ``slots > 0`` means the quantized checkpoint is deployed with device
    factor pools (``serve.adapters.install_pools``): ``slots`` resident
    adapters (slot 0 is the pinned all-zero base) at rank ``rank``, padded
    to the kernel lane multiple at install time. ``slots == 0`` (default)
    is adapter-free serving — no pools, no routing lane, same compiled
    programs as before this stage existed. Serving metadata only: changes
    no packed weights.
    """

    rank: int = 0
    slots: int = 0

    def __post_init__(self):
        if self.slots < 0 or self.rank < 0:
            raise ValueError(
                f"adapter rank/slots must be >= 0: {self.rank}/{self.slots}")
        if self.slots and self.slots < 2:
            raise ValueError(
                f"adapter pools need slots >= 2 (slot 0 is the base "
                f"adapter): {self.slots}")
        if bool(self.slots) != bool(self.rank):
            raise ValueError(
                f"adapter rank and slots must be set together: "
                f"rank={self.rank}, slots={self.slots}")

    @property
    def enabled(self) -> bool:
        return self.slots > 0


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """One fully-specified PTQ pipeline. Frozen, validated, serializable."""

    smoother: Smoother = Smoother()
    base: BaseQuantizer = BaseQuantizer()
    reconstructor: ErrorReconstructor = ErrorReconstructor()
    act: ActQuantSpec = ActQuantSpec()
    kv: KVQuantSpec = KVQuantSpec()
    adapter: AdapterSpec = AdapterSpec()
    name: str = ""          # provenance label (e.g. the legacy method name)

    def __post_init__(self):
        if self.base.kind == "none":
            if self.smoother.kind != "none" or self.reconstructor.kind != "none":
                raise ValueError(
                    "base 'none' (fp passthrough) cannot be combined with "
                    "smoothing or error reconstruction")
        if self.adapter.enabled and self.base.kind == "none":
            raise ValueError(
                "adapter pools ride on quantized leaves (alb/ala alongside "
                "qw); an fp passthrough base has none to install them on")
        if (self.smoother.kind == "aser-outlier"
                and self.reconstructor.kind == "none"):
            raise ValueError(
                "aser-outlier smoothing moves the outlier columns of W into "
                "the reconstruction target; without an error reconstructor "
                "that weight would be silently dropped. Add a reconstructor "
                "(e.g. kind='whitened-svd') or use a different smoother.")

    @property
    def is_noop(self) -> bool:
        return self.base.kind == "none"

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain-dict form (JSON-safe) with a format version stamp."""
        d = dataclasses.asdict(self)
        d["format_version"] = _RECIPE_FORMAT_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "QuantRecipe":
        d = dict(d)
        version = d.pop("format_version", _RECIPE_FORMAT_VERSION)
        if version not in _ACCEPTED_FORMAT_VERSIONS:
            raise ValueError(f"unsupported recipe format version: {version}")
        return cls(smoother=Smoother(**d["smoother"]),
                   base=BaseQuantizer(**d["base"]),
                   reconstructor=ErrorReconstructor(**d["reconstructor"]),
                   act=ActQuantSpec(**d["act"]),
                   kv=KVQuantSpec(**d["kv"]) if "kv" in d else KVQuantSpec(),
                   adapter=(AdapterSpec(**d["adapter"]) if "adapter" in d
                            else AdapterSpec()),
                   name=d.get("name", ""))

    def to_json(self, **json_kw) -> str:
        return json.dumps(self.to_dict(), **json_kw)

    @classmethod
    def from_json(cls, s: str) -> "QuantRecipe":
        return cls.from_dict(json.loads(s))

    def replace(self, **kw) -> "QuantRecipe":
        return dataclasses.replace(self, **kw)
