"""Method registry: legacy method names → :class:`QuantRecipe`.

Every string the old ``PTQConfig(method=...)`` API accepted resolves here to
a composable recipe, so existing callers migrate mechanically and new stage
combinations need no registry entry at all — construct a ``QuantRecipe``
directly. Names support a call-style override syntax::

    resolve("aser")                      # defaults
    resolve("aser", base="gptq", rank=32)
    resolve("aser(base=gptq, rank=32)")  # same thing, string form
    resolve("aser_as(outlier_f=16)")

Overrides use the legacy ``PTQConfig`` field names (``w_bits``, ``rank``,
``alpha``, ``outlier_f``, ``damp``, ``base``, ``a_bits``) so the migration
is a rename, not a remapping. ``adapter_rank``/``adapter_slots`` provision
the serving-time LoRA pools (:class:`repro.quant.recipe.AdapterSpec`) and
compose with every quantized method.
"""
from __future__ import annotations

import inspect
import re
from typing import Callable, Dict

from .recipe import (ActQuantSpec, AdapterSpec, BaseQuantizer,
                     ErrorReconstructor, KVQuantSpec, QuantRecipe, Smoother)

_REGISTRY: Dict[str, Callable[..., QuantRecipe]] = {}


def register(name: str):
    """Register a recipe factory under ``name`` (decorator)."""
    def deco(fn: Callable[..., QuantRecipe]):
        if name in _REGISTRY:
            raise ValueError(f"method {name!r} already registered")
        _REGISTRY[name] = fn
        return fn
    return deco


def available() -> list:
    """Sorted registered method names."""
    return sorted(_REGISTRY)


_CALL_RE = re.compile(r"^([\w.+-]+)\((.*)\)$")


def _parse_overrides(argstr: str) -> dict:
    out = {}
    for part in filter(None, (p.strip() for p in argstr.split(","))):
        if "=" not in part:
            raise ValueError(f"malformed recipe override {part!r} "
                             "(expected key=value)")
        key, val = (s.strip() for s in part.split("=", 1))
        val = val.strip("'\"")
        try:
            out[key] = int(val)
        except ValueError:
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
        if isinstance(out[key], str) and out[key] in ("True", "False"):
            out[key] = out[key] == "True"
    return out


# Shared override vocabulary — the legacy PTQConfig fields plus activation
# spec. A factory may ignore keys that don't apply to its method (so sweeps
# like resolve(m, rank=48) work across heterogeneous methods, exactly like
# PTQConfig did), but a key outside both this vocabulary and the factory's
# own signature is a typo and raises.
_OVERRIDE_VOCAB = frozenset({"w_bits", "rank", "alpha", "outlier_f", "damp",
                             "base", "a_bits", "a_granularity", "sq_alpha",
                             "kv_dtype", "adapter_rank", "adapter_slots"})


def _check_overrides(name: str, fn: Callable, overrides: dict):
    own = {n for n, p in inspect.signature(fn).parameters.items()
           if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}
    unknown = set(overrides) - _OVERRIDE_VOCAB - own
    if unknown:
        raise ValueError(
            f"unknown override(s) {sorted(unknown)} for method {name!r}; "
            f"recognized: {sorted(_OVERRIDE_VOCAB | own)}")


def resolve(spec, **overrides) -> QuantRecipe:
    """Resolve a method name / recipe / legacy config into a QuantRecipe."""
    if isinstance(spec, QuantRecipe):
        if overrides:
            raise ValueError("overrides only apply to method names; "
                             "use recipe.replace(...) on a QuantRecipe")
        return spec
    if hasattr(spec, "to_recipe"):            # legacy PTQConfig shim
        if overrides:
            raise ValueError("overrides only apply to method names; "
                             "dataclasses.replace(...) the PTQConfig instead")
        return spec.to_recipe()
    if not isinstance(spec, str):
        raise TypeError(f"cannot resolve a recipe from {type(spec)!r}")
    name = spec
    m = _CALL_RE.match(spec)
    if m:
        name = m.group(1)
        inline = _parse_overrides(m.group(2))
        clash = set(inline) & set(overrides)
        if clash:
            raise ValueError(f"override(s) given twice: {sorted(clash)}")
        overrides = {**inline, **overrides}
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown quantization method {name!r}; available: {available()}")
    fn = _REGISTRY[name]
    _check_overrides(name, fn, overrides)
    # adapter provisioning composes with every method: peel its keys off and
    # graft the stage onto whatever recipe the factory builds (AdapterSpec
    # validates the rank/slots pairing; QuantRecipe rejects pools on fp).
    adapter_rank = overrides.pop("adapter_rank", 0)
    adapter_slots = overrides.pop("adapter_slots", 0)
    recipe = fn(**overrides)
    if adapter_rank or adapter_slots:
        recipe = recipe.replace(
            adapter=AdapterSpec(rank=adapter_rank, slots=adapter_slots))
    return recipe


# ---------------------------------------------------------------------------
# Built-in methods (the legacy PTQConfig vocabulary)
# ---------------------------------------------------------------------------

def _base_stage(base: str, w_bits: int, damp: float) -> BaseQuantizer:
    # BaseQuantizer rejects "awq" itself with a pointer to Smoother("awq-scale")
    return BaseQuantizer(kind=base, bits=w_bits, damp=damp)


def _act(a_bits: int, a_granularity: str = "per_token") -> ActQuantSpec:
    return ActQuantSpec(bits=a_bits, granularity=a_granularity)


@register("fp16")
def _fp16(a_bits: int = 16, a_granularity: str = "per_token",
          kv_dtype: str = "bf16", **_ignored):
    return QuantRecipe(smoother=Smoother("none"), base=BaseQuantizer("none"),
                       reconstructor=ErrorReconstructor("none"),
                       act=_act(a_bits, a_granularity),
                       kv=KVQuantSpec(kv_dtype), name="fp16")


def _plain(name):
    @register(name)
    def _f(w_bits: int = 4, a_bits: int = 8, a_granularity: str = "per_token",
           kv_dtype: str = "bf16", **_ignored):
        return QuantRecipe(base=BaseQuantizer("rtn", bits=w_bits),
                           act=_act(a_bits, a_granularity),
                           kv=KVQuantSpec(kv_dtype), name=name)
    return _f


_plain("rtn")
_plain("llmint4")       # paper's LLM.int4() row == per-channel RTN here


@register("smoothquant")
def _smoothquant(w_bits: int = 4, sq_alpha: float = 0.5, a_bits: int = 8,
                 a_granularity: str = "per_token", kv_dtype: str = "bf16",
                 **_ignored):
    return QuantRecipe(smoother=Smoother("smoothquant", alpha=sq_alpha),
                       base=BaseQuantizer("rtn", bits=w_bits),
                       act=_act(a_bits, a_granularity),
                       kv=KVQuantSpec(kv_dtype), name="smoothquant")


@register("gptq")
def _gptq(w_bits: int = 4, damp: float = 1e-2, a_bits: int = 8,
          a_granularity: str = "per_token", kv_dtype: str = "bf16",
          **_ignored):
    return QuantRecipe(base=BaseQuantizer("gptq", bits=w_bits, damp=damp),
                       act=_act(a_bits, a_granularity),
                       kv=KVQuantSpec(kv_dtype), name="gptq")


@register("awq")
def _awq(w_bits: int = 4, a_bits: int = 8, a_granularity: str = "per_token",
         kv_dtype: str = "bf16", **_ignored):
    return QuantRecipe(smoother=Smoother("awq-scale"),
                       base=BaseQuantizer("rtn", bits=w_bits),
                       act=_act(a_bits, a_granularity),
                       kv=KVQuantSpec(kv_dtype), name="awq")


def _compensated(name):
    @register(name)
    def _f(w_bits: int = 4, rank: int = 64, a_bits: int = 8,
           a_granularity: str = "per_token", kv_dtype: str = "bf16",
           **_ignored):
        return QuantRecipe(base=BaseQuantizer("rtn", bits=w_bits),
                           reconstructor=ErrorReconstructor(name, rank=rank),
                           act=_act(a_bits, a_granularity),
                           kv=KVQuantSpec(kv_dtype), name=name)
    return _f


_compensated("lorc")
_compensated("l2qer")


@register("aser")
def _aser(w_bits: int = 4, rank: int = 64, alpha: float = 0.0,
          damp: float = 1e-2, base: str = "rtn", a_bits: int = 8,
          a_granularity: str = "per_token", kv_dtype: str = "bf16",
          **_ignored):
    return QuantRecipe(
        base=_base_stage(base, w_bits, damp),
        reconstructor=ErrorReconstructor("whitened-svd", rank=rank,
                                         alpha=alpha, damp=damp),
        act=_act(a_bits, a_granularity), kv=KVQuantSpec(kv_dtype),
        name="aser")


@register("aser_as")
def _aser_as(w_bits: int = 4, rank: int = 64, alpha: float = 0.0,
             outlier_f: int = 32, damp: float = 1e-2, base: str = "rtn",
             a_bits: int = 8, a_granularity: str = "per_token",
             kv_dtype: str = "bf16", **_ignored):
    return QuantRecipe(
        smoother=Smoother("aser-outlier", outlier_f=outlier_f),
        base=_base_stage(base, w_bits, damp),
        reconstructor=ErrorReconstructor("whitened-svd", rank=rank,
                                         alpha=alpha, damp=damp),
        act=_act(a_bits, a_granularity), kv=KVQuantSpec(kv_dtype),
        name="aser_as")
