"""Model-level PTQ integration: calibration, quantization, serving."""
from .calibrate import calibrate, accumulate, reduce_shared
from .apply import PTQConfig, quantize_model

__all__ = ["calibrate", "accumulate", "reduce_shared", "PTQConfig",
           "quantize_model"]
