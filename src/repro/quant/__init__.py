"""Model-level PTQ integration: recipes, calibration, quantization, serving."""
from .calibrate import calibrate, accumulate, reduce_shared
from .recipe import (ActQuantSpec, AdapterSpec, BaseQuantizer,
                     ErrorReconstructor, KVQuantSpec, QuantRecipe, Smoother)
from . import registry
from .registry import resolve as resolve_recipe
from .apply import PTQConfig, quantize_model

__all__ = ["calibrate", "accumulate", "reduce_shared",
           "QuantRecipe", "Smoother", "BaseQuantizer", "ErrorReconstructor",
           "ActQuantSpec", "KVQuantSpec", "AdapterSpec", "registry",
           "resolve_recipe",
           "PTQConfig", "quantize_model"]
