"""Whole-model PTQ: turn fp params + calibration tape into quantized params.

Every quantizable linear leaf ``{"w": [k, n]}`` becomes a serving leaf::

    {"qw":  int8 [k//2, n]   # int4 pairs packed along k
     "sw":  f32 [n]          # per-out-channel weight scale
     "m":   f32 [k]          # smoothing diagonal (ones when off)
     "lb":  f32 [k, r]       # low-rank compensation (r may be 0)
     "la":  f32 [r, n]}

Methods: fp16 (no-op), rtn, llmint4, smoothquant, gptq, awq, lorc, l2qer,
aser (w/o A.S.), aser_as (w/ A.S.), plus base-quantizer composition
aser(base="gptq"/"awq") — the paper notes ER is orthogonal to the weight
quantizer; we implement that compositionality.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import (QuantConfig, W4, aser_smoothing, awq_quantize,
                        cholesky_whitener, gptq_quantize, l2qer,
                        lorc, low_rank_factors, pack_int4, quantize_weight,
                        rank_from_alpha, smoothquant_scales, whiten_svd)
from repro.core.aser import smooth_gram
from repro.models.layers import LinStats


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    method: str = "aser_as"
    w_bits: int = 4
    rank: int = 64              # fixed rank (alpha=0) for lorc/l2qer/aser
    alpha: float = 0.0          # >0: Eq. 9 adaptive rank, capped at ``rank``
    outlier_f: int = 32
    damp: float = 1e-2
    base: str = "rtn"           # weight quantizer under aser: rtn|gptq|awq


def _w_cfg(cfg: PTQConfig) -> QuantConfig:
    return QuantConfig(bits=cfg.w_bits)


def _empty_lr(k: int, n: int):
    return jnp.zeros((k, 0), jnp.float32), jnp.zeros((0, n), jnp.float32)


def _quantize_one(w: jnp.ndarray, st: LinStats, cfg: PTQConfig):
    """w: [k, n] (model layout). Returns serving leaf dict."""
    k, n = w.shape
    wt = w.astype(jnp.float32).T                    # paper layout [out, in]
    count = jnp.maximum(st.count, 1.0)
    g = st.gram
    absmean = st.abssum / count
    wq_cfg = _w_cfg(cfg)
    m = jnp.ones((k,), jnp.float32)
    la = lb = None
    method = cfg.method

    if method in ("rtn", "llmint4"):
        codes, sc = quantize_weight(wt, wq_cfg)
    elif method == "smoothquant":
        w_absmax_in = jnp.max(jnp.abs(wt), axis=0)
        m = smoothquant_scales(st.absmax, w_absmax_in, alpha=0.5)
        codes, sc = quantize_weight(wt * m[None, :], wq_cfg)
    elif method == "gptq":
        w_hat = gptq_quantize(wt, g, wq_cfg, damp=cfg.damp)
        codes, sc = _recode(w_hat, wt, wq_cfg)
    elif method == "awq":
        _, s = awq_quantize(wt, g, absmean, wq_cfg)
        m = s
        codes, sc = quantize_weight(wt * s[None, :], wq_cfg)
    elif method in ("lorc", "l2qer"):
        codes, sc = quantize_weight(wt, wq_cfg)
        w_deq = codes.astype(jnp.float32) * sc
        e_q = wt - w_deq
        r = min(cfg.rank, k, n)
        comp = (lorc(e_q, r) if method == "lorc" else l2qer(e_q, absmean, r))
        la, lb = comp.l_a, comp.l_b
    elif method.startswith("aser"):
        smooth = method == "aser_as"
        if smooth:
            sm = aser_smoothing(wt, absmean, cfg.outlier_f)
            m = sm.m
            w_s = sm.w_smooth
            extra = sm.w_outlier
            g_eff = smooth_gram(g, m)
        else:
            w_s, extra, g_eff = wt, jnp.zeros_like(wt), g
        codes, sc, w_deq = _base_quant(w_s, g_eff, wq_cfg, cfg)
        e_q = (w_s - w_deq) + extra
        r = min(cfg.rank, k, n)
        s_chol = cholesky_whitener(g_eff, damp=cfg.damp)
        u, sig, vt = whiten_svd(e_q, s_chol)
        if cfg.alpha > 0:
            r_sel = jnp.minimum(rank_from_alpha(sig, cfg.alpha), r)
            la_f, lb_f = low_rank_factors(u, sig, vt, s_chol, r)
            keepm = (jnp.arange(r) < r_sel).astype(jnp.float32)
            la, lb = la_f * keepm[None, :], lb_f * keepm[:, None]
        else:
            la, lb = low_rank_factors(u, sig, vt, s_chol, r)
    else:
        raise ValueError(method)

    if la is None:
        lb_m, la_m = _empty_lr(k, n)
    else:
        # convert paper layout (L_A [out,r], L_B [r,in]) to model layout
        lb_m, la_m = lb.T, la.T                      # [k, r], [r, n]

    qw = pack_int4(codes).T if cfg.w_bits == 4 else codes.T   # [k/2, n] | [k, n]
    return {"qw": qw.astype(jnp.int8), "sw": sc[:, 0].astype(jnp.float32),
            "m": m.astype(jnp.float32), "lb": lb_m.astype(jnp.float32),
            "la": la_m.astype(jnp.float32)}


def _recode(w_hat, wt, wq_cfg):
    """Recover int codes + scales from a fake-quantized weight (GPTQ)."""
    qmax = wq_cfg.qmax
    sc = jnp.maximum(jnp.max(jnp.abs(wt), axis=1, keepdims=True), 1e-8) / qmax
    codes = jnp.clip(jnp.round(w_hat / sc), wq_cfg.qmin, wq_cfg.qmax)
    return codes.astype(jnp.int8), sc.astype(jnp.float32)


def _base_quant(w_s, g_eff, wq_cfg, cfg: PTQConfig):
    """Weight quantizer under ASER (orthogonality: rtn | gptq | awq)."""
    if cfg.base == "gptq":
        w_hat = gptq_quantize(w_s, g_eff, wq_cfg, damp=cfg.damp)
        codes, sc = _recode(w_hat, w_s, wq_cfg)
        return codes, sc, codes.astype(jnp.float32) * sc
    if cfg.base == "awq":
        # AWQ scale folds into m upstream only for pure awq; under ASER we
        # keep base=rtn semantics for awq to avoid double-smoothing.
        pass
    codes, sc = quantize_weight(w_s, wq_cfg)
    return codes, sc, codes.astype(jnp.float32) * sc


def _q_leaf(wdict: dict, st: LinStats, cfg: PTQConfig):
    w = wdict["w"]
    if w.ndim > 2:
        lead = w.shape[:-2]
        flat_w = w.reshape((-1,) + w.shape[-2:])
        flat_st = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[len(lead):]), st)
        out = jax.vmap(lambda wi, sti: _quantize_one(wi, sti, cfg))(
            flat_w, flat_st)
        out = {kk: vv.reshape(lead + vv.shape[1:]) for kk, vv in out.items()}
    else:
        out = _quantize_one(w, st, cfg)
    if "b" in wdict:
        out["b"] = wdict["b"]
    return out


def _q_expert_stack(earr: jnp.ndarray, st: LinStats, cfg: PTQConfig):
    """Stacked expert weights [..., e, d, f] + per-expert stats."""
    return _q_leaf({"w": earr}, st, cfg)


def quantize_model(params, tape, cfg: PTQConfig):
    """Return a new param tree with every calibrated linear quantized."""
    if cfg.method == "fp16":
        return params

    def walk(p, t):
        if isinstance(t, LinStats):
            if isinstance(p, dict) and "w" in p:
                return _q_leaf(p, t, cfg)
            if isinstance(p, jnp.ndarray):               # stacked experts
                return _q_expert_stack(p, t, cfg)
            raise ValueError(f"stats for non-linear node: {type(p)}")
        if isinstance(t, dict):
            assert isinstance(p, (dict,)), (type(p), list(t))
            out = dict(p)
            for kk, tv in t.items():
                out[kk] = walk(p[kk], tv)
            return out
        if isinstance(t, (list, tuple)):
            return type(t)(walk(pi, ti) for pi, ti in zip(p, t))
        return p

    new = dict(params)
    if "prefix" in (tape or {}):
        new["prefix"] = [walk(pb, tb) for pb, tb
                         in zip(params["prefix"], tape["prefix"])]
    if "groups" in (tape or {}):
        gt = tape["groups"]
        blocks = params["groups"]           # list of block dicts
        new_blocks = []
        for i, pb in enumerate(blocks):
            tb = gt.get(f"b{i}")
            new_blocks.append(walk(pb, tb) if tb is not None else pb)
        new["groups"] = new_blocks
        if "shared" in gt and "shared" in params:
            new["shared"] = walk(params["shared"], gt["shared"])
    if "encoder" in (tape or {}) and "encoder" in params:
        enc = dict(params["encoder"])
        egt = tape["encoder"]["groups"]
        enc["groups"] = [walk(pb, egt.get(f"b{i}")) if egt.get(f"b{i}")
                         is not None else pb
                         for i, pb in enumerate(params["encoder"]["groups"])]
        new["encoder"] = enc
    return new
