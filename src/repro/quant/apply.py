"""Whole-model PTQ: turn fp params + calibration tape into quantized params.

The pipeline is recipe-driven: a :class:`repro.quant.recipe.QuantRecipe`
composes four pluggable stages, each a pure function of the fp weight and
the calibration statistics —

    1. Smoother            → diagonal m, smoothed weight W_s, outlier split
    2. BaseQuantizer       → int codes + scales of Q(W_s)
    3. ErrorReconstructor  → low-rank factors compensating E_q = W_s − Q(W_s)
    4. ActQuantSpec        → recorded serving-time activation setup

Legacy method strings (``rtn``, ``smoothquant``, ``gptq``, ``awq``,
``lorc``, ``l2qer``, ``aser``, ``aser_as``, ``aser(base=gptq)``) resolve to
recipes through :mod:`repro.quant.registry`; new stage combinations compose
without touching this module. ``PTQConfig`` remains as a deprecated shim.

Every quantizable linear leaf ``{"w": [k, n]}`` becomes a serving leaf::

    {"qw":  int8 [k//2, n]   # int4 pairs packed along k (or [k, n] for W>4)
     "sw":  f32 [n]          # per-out-channel weight scale
     "m":   f32 [k]          # smoothing diagonal (ones when off)
     "lb":  f32 [k, r]       # low-rank compensation (r may be 0)
     "la":  f32 [r, n]}

Non-zero ranks are zero-padded here, once, to the kernel lane multiple
(``repro.kernels.ops.LOWRANK_MULTIPLE``) so the serving hot path never
re-pads ``lb``/``la`` per call; padded columns/rows are zero and thus
mathematically inert. ``r == 0`` (no compensation) stays empty — the leaf
remains introspectable as "no reconstruction" and ops pads the degenerate
case at dispatch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (QuantConfig, awq_quantize, cholesky_whitener,
                        gptq_quantize, l2qer, lorc, low_rank_factors,
                        pack_int4, quantize_weight, rank_from_alpha,
                        smoothquant_scales, whiten_svd)
from repro.core.aser import smooth_gram
from repro.core.smoothing import aser_smoothing
from repro.kernels.ops import LOWRANK_MULTIPLE, pad_lowrank
from repro.models.layers import LinStats

from . import registry
from .recipe import (ActQuantSpec, BaseQuantizer, ErrorReconstructor,
                     QuantRecipe, Smoother)


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    """Deprecated legacy config — a thin veneer over the recipe registry.

    Prefer ``registry.resolve(name, ...)`` or constructing a
    :class:`QuantRecipe` directly; this shim exists for one release so
    pre-recipe callsites keep working unchanged.
    """
    method: str = "aser_as"
    w_bits: int = 4
    rank: int = 64              # fixed rank (alpha=0) for lorc/l2qer/aser
    alpha: float = 0.0          # >0: Eq. 9 adaptive rank, capped at ``rank``
    outlier_f: int = 32
    damp: float = 1e-2
    base: str = "rtn"           # weight quantizer under aser: rtn|gptq

    def to_recipe(self) -> QuantRecipe:
        return registry.resolve(self.method, w_bits=self.w_bits,
                                rank=self.rank, alpha=self.alpha,
                                outlier_f=self.outlier_f, damp=self.damp,
                                base=self.base)


def _empty_lr(k: int, n: int):
    return jnp.zeros((k, 0), jnp.float32), jnp.zeros((0, n), jnp.float32)


# ---------------------------------------------------------------------------
# Pipeline stages (paper layout: W [out, in], Gram [in, in])
# ---------------------------------------------------------------------------

def _apply_smoother(sm: Smoother, wt: jnp.ndarray, g: jnp.ndarray,
                    absmean: jnp.ndarray, absmax: jnp.ndarray,
                    wq_cfg: QuantConfig):
    """→ (m [in], W_s, W_outlier | None, G_eff)."""
    if sm.kind == "none":
        return jnp.ones((wt.shape[1],), jnp.float32), wt, None, g
    if sm.kind == "smoothquant":
        w_absmax_in = jnp.max(jnp.abs(wt), axis=0)
        m = smoothquant_scales(absmax, w_absmax_in, alpha=sm.alpha)
        return m, wt * m[None, :], None, smooth_gram(g, m)
    if sm.kind == "awq-scale":
        _, s = awq_quantize(wt, g, absmean, wq_cfg)
        return s, wt * s[None, :], None, smooth_gram(g, s)
    if sm.kind == "aser-outlier":
        res = aser_smoothing(wt, absmean, sm.outlier_f)
        return res.m, res.w_smooth, res.w_outlier, smooth_gram(g, res.m)
    raise ValueError(sm.kind)       # unreachable: recipe validates kinds


def _apply_base(bq: BaseQuantizer, w_s: jnp.ndarray, g_eff: jnp.ndarray,
                wq_cfg: QuantConfig):
    """→ (codes int8, scales f32 [out, 1], dequantized W)."""
    if bq.kind == "gptq":
        w_hat = gptq_quantize(w_s, g_eff, wq_cfg, damp=bq.damp)
        codes, sc = _recode(w_hat, w_s, wq_cfg)
        return codes, sc, codes.astype(jnp.float32) * sc
    codes, sc = quantize_weight(w_s, wq_cfg)
    return codes, sc, codes.astype(jnp.float32) * sc


def _apply_reconstructor(er: ErrorReconstructor, e_q: jnp.ndarray,
                         g_eff: jnp.ndarray, absmean: jnp.ndarray):
    """→ (L_A [out, r], L_B [r, in]) or None."""
    if er.kind == "none":
        return None
    out, inn = e_q.shape
    r = min(er.rank, out, inn)
    if er.kind == "lorc":
        comp = lorc(e_q, r)
        return comp.l_a, comp.l_b
    if er.kind == "l2qer":
        comp = l2qer(e_q, absmean, r)
        return comp.l_a, comp.l_b
    # whitened-svd (ASER-ER)
    s_chol = cholesky_whitener(g_eff, damp=er.damp)
    u, sig, vt = whiten_svd(e_q, s_chol)
    la, lb = low_rank_factors(u, sig, vt, s_chol, r)
    if er.alpha > 0:
        r_sel = jnp.minimum(rank_from_alpha(sig, er.alpha), r)
        keep = (jnp.arange(r) < r_sel).astype(jnp.float32)
        la, lb = la * keep[None, :], lb * keep[:, None]
    return la, lb


def _recode(w_hat, w_ref, wq_cfg):
    """Recover int codes + scales from a fake-quantized weight (GPTQ)."""
    qmax = wq_cfg.qmax
    sc = jnp.maximum(jnp.max(jnp.abs(w_ref), axis=1, keepdims=True), 1e-8) / qmax
    codes = jnp.clip(jnp.round(w_hat / sc), wq_cfg.qmin, wq_cfg.qmax)
    return codes.astype(jnp.int8), sc.astype(jnp.float32)


def _quantize_one(w: jnp.ndarray, st: LinStats, recipe):
    """w: [k, n] (model layout). Runs the stage pipeline, returns a leaf.

    ``recipe``: QuantRecipe | method string | legacy PTQConfig."""
    recipe = registry.resolve(recipe)
    if recipe.is_noop:
        raise ValueError(
            "noop (fp-passthrough) recipe has no per-leaf quantization; "
            "quantize_model returns the params unchanged for it")
    k, n = w.shape
    wt = w.astype(jnp.float32).T                    # paper layout [out, in]
    count = jnp.maximum(st.count, 1.0)
    absmean = st.abssum / count
    wq_cfg = QuantConfig(bits=recipe.base.bits)

    m, w_s, w_outlier, g_eff = _apply_smoother(
        recipe.smoother, wt, st.gram, absmean, st.absmax, wq_cfg)
    codes, sc, w_deq = _apply_base(recipe.base, w_s, g_eff, wq_cfg)

    comp = None
    if recipe.reconstructor.kind != "none":
        e_q = w_s - w_deq
        if w_outlier is not None:       # Eq. 12: fold W_o into the ER target
            e_q = e_q + w_outlier
        comp = _apply_reconstructor(recipe.reconstructor, e_q, g_eff, absmean)

    if comp is None:
        lb_m, la_m = _empty_lr(k, n)
    else:
        # convert paper layout (L_A [out,r], L_B [r,in]) to model layout
        la, lb = comp
        lb_m, la_m = pad_lowrank(lb.T, la.T)         # [k, r8], [r8, n]
        assert lb_m.shape[1] % LOWRANK_MULTIPLE == 0, lb_m.shape

    qw = pack_int4(codes).T if recipe.base.bits == 4 else codes.T
    return {"qw": qw.astype(jnp.int8), "sw": sc[:, 0].astype(jnp.float32),
            "m": m.astype(jnp.float32), "lb": lb_m.astype(jnp.float32),
            "la": la_m.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Tree walk
# ---------------------------------------------------------------------------

def _q_leaf(wdict: dict, st: LinStats, recipe: QuantRecipe):
    w = wdict["w"]
    if w.ndim > 2:
        lead = w.shape[:-2]
        flat_w = w.reshape((-1,) + w.shape[-2:])
        flat_st = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[len(lead):]), st)
        out = jax.vmap(lambda wi, sti: _quantize_one(wi, sti, recipe))(
            flat_w, flat_st)
        out = {kk: vv.reshape(lead + vv.shape[1:]) for kk, vv in out.items()}
    else:
        out = _quantize_one(w, st, recipe)
    if "b" in wdict:
        out["b"] = wdict["b"]
    return out


def _q_expert_stack(earr: jnp.ndarray, st: LinStats, recipe: QuantRecipe):
    """Stacked expert weights [..., e, d, f] + per-expert stats."""
    return _q_leaf({"w": earr}, st, recipe)


def quantize_model(params, tape, recipe):
    """Return a new param tree with every calibrated linear quantized.

    ``recipe`` may be a :class:`QuantRecipe`, a registered method name
    (string, optionally with overrides — ``"aser(base=gptq)"``), or a legacy
    :class:`PTQConfig`.
    """
    recipe = registry.resolve(recipe)
    if recipe.is_noop:
        return params

    def walk(p, t):
        if isinstance(t, LinStats):
            if isinstance(p, dict) and "w" in p:
                return _q_leaf(p, t, recipe)
            if isinstance(p, jnp.ndarray):               # stacked experts
                return _q_expert_stack(p, t, recipe)
            raise ValueError(f"stats for non-linear node: {type(p)}")
        if isinstance(t, dict):
            assert isinstance(p, (dict,)), (type(p), list(t))
            out = dict(p)
            for kk, tv in t.items():
                out[kk] = walk(p[kk], tv)
            return out
        if isinstance(t, (list, tuple)):
            return type(t)(walk(pi, ti) for pi, ti in zip(p, t))
        return p

    new = dict(params)
    if "prefix" in (tape or {}):
        new["prefix"] = [walk(pb, tb) for pb, tb
                         in zip(params["prefix"], tape["prefix"])]
    if "groups" in (tape or {}):
        gt = tape["groups"]
        blocks = params["groups"]           # list of block dicts
        new_blocks = []
        for i, pb in enumerate(blocks):
            tb = gt.get(f"b{i}")
            new_blocks.append(walk(pb, tb) if tb is not None else pb)
        new["groups"] = new_blocks
        if "shared" in gt and "shared" in params:
            new["shared"] = walk(params["shared"], gt["shared"])
    if "encoder" in (tape or {}) and "encoder" in params:
        enc = dict(params["encoder"])
        egt = tape["encoder"]["groups"]
        enc["groups"] = [walk(pb, egt.get(f"b{i}")) if egt.get(f"b{i}")
                         is not None else pb
                         for i, pb in enumerate(params["encoder"]["groups"])]
        new["encoder"] = enc
    return new
