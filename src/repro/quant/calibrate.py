"""Calibration: run the model over a calibration stream, accumulate per-linear
activation statistics (Gram XXᵀ, Σ|x|, absmax, token count).

The paper uses 128 samples × 2048 tokens; smoke-scale tests use less. Stats
for weight-shared modules (zamba2's shared block) and per-expert MoE stats
come back stacked and are reduced here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, forward
from repro.models.layers import LinStats


def _combine(a: LinStats, b: LinStats) -> LinStats:
    return LinStats(a.gram + b.gram, a.abssum + b.abssum,
                    jnp.maximum(a.absmax, b.absmax), a.count + b.count)


def _is_stats(x) -> bool:
    return isinstance(x, LinStats)


def accumulate(total, new):
    """Merge a new batch's tape into the running total (None → copy)."""
    if total is None:
        return new
    return jax.tree.map(_combine, total, new,
                        is_leaf=lambda x: isinstance(x, LinStats))


def calibrate(params, cfg: ModelConfig, batches, **fwd_kwargs):
    """batches: iterable of token arrays [b, s]. Returns the summed tape."""
    total = None

    def one(tokens, extra):
        tape: Dict[str, Any] = {}
        forward(params, cfg, tokens, tape=tape, **extra)
        return tape

    for item in batches:
        tokens, extra = (item if isinstance(item, tuple) else (item, {}))
        merged = {**fwd_kwargs, **extra}
        tape = one(tokens, merged)
        total = accumulate(total, tape)
    return total


def reduce_shared(tape, cfg: ModelConfig):
    """Sum the shared-block stats over the group axis (weight sharing ⇒ the
    calibration Gram aggregates over every call site)."""
    if cfg.family != "hybrid" or "groups" not in tape:
        return tape
    g = tape["groups"]
    if "shared" in g:
        g = dict(g)
        g["shared"] = jax.tree.map(
            lambda s: LinStats(jnp.sum(s.gram, 0), jnp.sum(s.abssum, 0),
                               jnp.max(s.absmax, 0), jnp.sum(s.count, 0)),
            g["shared"], is_leaf=_is_stats)
        tape = dict(tape)
        tape["groups"] = g
    return tape
