"""Fault-tolerant checkpointing: atomic writes, async save, retention,
auto-resume, elastic re-sharding on restore.

Formats: params/opt-state are flattened to a dict of numpy arrays saved via
``np.savez`` (no orbax offline). Atomicity: write to ``<dir>/tmp.<step>``,
fsync, ``os.replace`` to ``step_<n>`` — a crash mid-save never corrupts the
latest checkpoint. Restore re-shards to whatever mesh is current (elastic
scaling: params are saved unsharded-logical; device placement is re-derived
from the live mesh at load time).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if tree is None:               # absent optional fields (e.g. bf16
        pass                       # caches' scale slots) save nothing
    elif isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}"))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}/{k}"))
        out[f"{prefix}/__namedtuple__"] = np.asarray(type(tree).__name__)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
        out[f"{prefix}/__seq__"] = np.asarray(len(tree))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten_into(template, flat, prefix=""):
    """Rebuild using ``template``'s structure (robust across jax versions)."""
    if template is None:
        return None
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[_unflatten_into(getattr(template, k), flat,
                                                f"{prefix}/{k}")
                                for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(_unflatten_into(v, flat, f"{prefix}/{i}")
                              for i, v in enumerate(template))
    return flat[prefix]


class CheckpointManager:
    """``save(step, state)`` / ``restore_latest(template)`` with retention.

    ``async_save=True`` runs serialization+write on a worker thread so the
    train loop never blocks on I/O (the state is snapshotted to host first).
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- helpers -------------------------------------------------------------
    def _step_dirs(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Write (or enqueue, when ``async_save``) one checkpoint.

        A failure from a *previous* async save is re-raised here (or in
        :meth:`wait`) — background write errors are never silently
        swallowed: a train loop that keeps calling ``save`` finds out
        about a dead disk at the very next step, not at restore time.
        """
        # snapshot to host (cheap on CPU; on TPU this is the device→host copy)
        host_state = jax.tree.map(np.asarray, state)
        if self.async_save:
            self.wait()                     # raises a pending async failure
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_state, metadata))
            self._thread.start()
        else:
            self._write(step, host_state, metadata)

    def wait(self):
        """Block until the in-flight async save (if any) finishes.

        Re-raises the exception of a failed background write — callers that
        ``wait()`` before shutdown get the same error a synchronous save
        would have raised in place.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guarded(self, step: int, host_state, metadata):
        """Worker-thread entry: capture instead of dying silently (a raise
        on a non-main thread only prints — the train loop would never
        know the checkpoint is missing)."""
        try:
            self._write(step, host_state, metadata)
        except BaseException as err:      # noqa: BLE001 - must not lose any
            self._error = err

    def _write(self, step: int, host_state, metadata):
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step}")
        try:
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host_state)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            meta = dict(step=step, time=time.time(), **(metadata or {}))
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            # fsync the npz for crash consistency
            with open(os.path.join(tmp, "state.npz"), "rb") as f:
                os.fsync(f.fileno())
        except BaseException:
            # crash consistency: a failed write leaves no partial tmp dir
            # behind (the atomic os.replace below never ran, so the last
            # good step_<n> is untouched either way)
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        dirs = self._step_dirs()
        for _, path in dirs[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(path, ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: int, template: Any, shardings: Any = None):
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        with np.load(path, allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        state = jax.tree.map(
            lambda t, x: jnp.asarray(x, dtype=t.dtype), template, state)
        if shardings is not None:
            state = jax.device_put(state, shardings)   # elastic re-shard
        return state

    def restore_latest(self, template: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template, shardings)

    def restore_flat(self, step: int) -> dict:
        """The raw flat ``{key: np.ndarray}`` dict of one checkpoint —
        template-free access for states whose structure is self-describing
        (e.g. scheduler snapshots, whose entries vary per save)."""
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        with np.load(path, allow_pickle=False) as z:
            return {k: z[k] for k in z.files}

    def restore_pytree(self, step: int) -> dict:
        """Rebuild a checkpoint of nested plain dicts without a template.

        Inverse of ``_flatten`` for dict-only trees (the scheduler
        snapshot format): keys split on ``/`` into nested dicts, arrays
        stay leaves. Checkpoints holding list/namedtuple markers need the
        templated :meth:`restore` instead.
        """
        flat = self.restore_flat(step)
        out: dict = {}
        for key, value in flat.items():
            if key.endswith(("/__seq__", "/__namedtuple__")):
                raise ValueError(
                    f"{key!r}: non-dict node — restore_pytree only handles "
                    f"dict trees; use restore() with a template")
            parts = [p for p in key.split("/") if p]
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = value
        return out
