"""Roofline terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs/bytes: ``compiled.cost_analysis()`` — NOTE: these are PER-DEVICE
(the SPMD executable one chip runs), so the terms divide by per-chip peaks.
collective_bytes: parsed from the compiled HLO text — sum of operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([\w\[\]{}(), ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of (possibly tuple) shape text like 'f32[128,256]'."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes per collective kind (−start/−done dedup'd)."""
    out: Dict[str, float] = {}
    seen_start = set()
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{}, ]+?))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(-start|-done)?\(", hlo_text, re.M):
        name, shape_str, kind, phase = m.groups()
        if phase == "-done":
            continue                      # counted at -start
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    flops: float                  # whole-program HLO FLOPs
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]
    model_flops: float = 0.0      # 6·N_active·D analytic
    peak_mem_bytes: float = 0.0   # per-device from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS          # per-device program

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        per_dev_model = self.model_flops / self.chips
        return per_dev_model / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / total modeled time (how close to roofline)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / t if t > 0 else 0.0

    def to_dict(self):
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips, "flops": self.flops,
            "bytes": self.bytes_accessed, "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_per_dev_gb": self.peak_mem_bytes / 1e9,
        }


def analyze(compiled, *, arch: str, cell: str, mesh_name: str, chips: int,
            model_flops: float = 0.0, hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(txt)
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes)
    return Roofline(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=sum(coll.values()), coll_breakdown=coll,
        model_flops=model_flops, peak_mem_bytes=float(peak))


def model_flops_estimate(cfg, cell) -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward.

    N_active counts routed-expert params once per activated expert.
    """
    d, v = cfg.d_model, cfg.vocab_size
    # per-layer active params
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d \
        if cfg.n_heads else 0
    if cfg.n_experts:
        ff_active = 3 * d * cfg.moe_d_ff * (cfg.top_k + cfg.n_shared_experts)
        dense_ff = 3 * d * cfg.d_ff
        n_moe = cfg.n_layers - cfg.n_dense_layers
        layer_params = cfg.n_layers * attn + n_moe * ff_active \
            + cfg.n_dense_layers * dense_ff
    elif cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_d_inner
        conv_dim = d_in + 2 * cfg.ssm_n_groups * cfg.ssm_state
        mamba = d * (2 * d_in + 2 * cfg.ssm_n_groups * cfg.ssm_state
                     + cfg.ssm_n_heads) + d_in * d
        if cfg.family == "hybrid":
            n_shared = cfg.n_layers // cfg.shared_attn_period
            shared = 2 * d * d + attn + 3 * d * cfg.d_ff + d * d
            layer_params = cfg.n_layers * mamba + n_shared * shared
        else:
            layer_params = cfg.n_layers * mamba
    else:
        ff_mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        layer_params = cfg.n_layers * (attn + ff_mult * d * cfg.d_ff)
        if cfg.family == "encdec":
            layer_params += cfg.n_encoder_layers * (attn + 2 * d * cfg.d_ff) \
                + cfg.n_layers * (2 * d * cfg.kv_dim + d * cfg.q_dim)
    n_active = layer_params + v * d * (1 if cfg.tie_embeddings else 2)

    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
