"""Host data pipeline: sharded, prefetched, exactly-resumable batches.

On a real fleet each host feeds its addressable devices its slice of the
global batch (`jax.process_index()`-derived). Offline (single process) the
same code produces the full batch. Because the synthetic corpus is a pure
function of (seed, step), resumption after preemption needs only the step
counter from the checkpoint — no data-state files, no skew after elastic
reshapes.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from .synthetic import CorpusConfig, SyntheticCorpus


class DataPipeline:
    def __init__(self, corpus: SyntheticCorpus, batch: int, seq: int,
                 *, sharding=None, prefetch: int = 2,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.corpus = corpus
        self.batch = batch
        self.seq = seq
        self.sharding = sharding
        self.prefetch = prefetch
        self.pidx = (jax.process_index() if process_index is None
                     else process_index)
        self.pcount = (jax.process_count() if process_count is None
                       else process_count)
        assert batch % self.pcount == 0, (batch, self.pcount)
        self._local = batch // self.pcount

    def batch_at(self, step: int) -> jnp.ndarray:
        """Deterministic batch for ``step`` (host-local slice)."""
        full = self.corpus.sample(jnp.asarray(step), self.batch, self.seq)
        local = full[self.pidx * self._local:(self.pidx + 1) * self._local]
        if self.sharding is not None:
            local = jax.device_put(local, self.sharding)
        return local

    def iterate(self, start_step: int, n_steps: int) -> Iterator:
        """Prefetching iterator: a worker thread stays ``prefetch`` batches
        ahead so host data generation overlaps device compute."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def worker():
            for s in range(start_step, start_step + n_steps):
                q.put((s, self.batch_at(s)))
            q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
        t.join()
