"""Deterministic synthetic language corpus (no external datasets offline).

A Zipf-distributed bigram language: a fixed random transition structure over
the vocabulary gives strong, learnable sequential statistics, so a small LM
trained on it reaches non-trivial PPL and quantization-induced degradation is
measurable exactly like on a real corpus. Sampling is stateless: batch ``i``
is a pure function of (seed, i), which makes the data pipeline elastic and
exactly resumable (checkpoint stores only the step counter).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab_size: int = 512
    seed: int = 1234
    branching: int = 8      # candidate successors per token
    temperature: float = 1.0


def _transition_logits(cfg: CorpusConfig) -> np.ndarray:
    """[vocab, branching] successor ids + logits, fixed by seed."""
    rng = np.random.default_rng(cfg.seed)
    succ = rng.integers(0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching))
    # Zipf-ish weights over the branches
    w = 1.0 / (np.arange(1, cfg.branching + 1) ** 1.2)
    logits = np.log(w / w.sum()) * cfg.temperature
    return succ.astype(np.int32), np.broadcast_to(
        logits, (cfg.vocab_size, cfg.branching)).astype(np.float32)


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        succ, logits = _transition_logits(cfg)
        self.succ = jnp.asarray(succ)
        self.logits = jnp.asarray(logits)

    @partial(jax.jit, static_argnames=("self", "batch", "seq"))
    def sample(self, step: jnp.ndarray, batch: int, seq: int) -> jnp.ndarray:
        """Deterministic batch: tokens [batch, seq] for a given step index."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, self.cfg.vocab_size)

        def step_fn(tok, k):
            branch = jax.random.categorical(k, self.logits[tok], axis=-1)
            nxt = self.succ[tok, branch]
            return nxt, tok

        ks = jax.random.split(k1, seq)
        _, toks = jax.lax.scan(step_fn, first, ks)
        return toks.T.astype(jnp.int32)                  # [batch, seq]

    def calibration_batches(self, n_batches: int, batch: int, seq: int):
        """Deterministic calibration stream (disjoint from training steps
        by using negative fold-in indices)."""
        for i in range(n_batches):
            yield self.sample(jnp.asarray(-(i + 1)), batch, seq)

    def entropy_floor(self) -> float:
        """Per-token entropy of the generating process (PPL lower bound)."""
        p = np.exp(np.asarray(self.logits[0]))
        p = p / p.sum()
        return float(np.exp(-(p * np.log(p)).sum()))
