"""Gemma2-9B — alternating local/global attention, logit softcaps,
sandwich norms, GeGLU. [arXiv:2408.00118; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=14336, vocab_size=256000,
    norm="rmsnorm", mlp="geglu",
    post_block_norm=True, scale_embed=True,
    sliding_window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0,
    rope_theta=10000.0, tie_embeddings=True,
)
SMOKE = CONFIG.reduced(local_global_period=2, n_layers=4)
