"""Mamba2-780m — attention-free SSD. [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=1,
    d_ff=0, vocab_size=50280,
    norm="rmsnorm",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    ssm_chunk=256, tie_embeddings=True,
)
SMOKE = CONFIG.reduced(n_heads=0, n_kv_heads=0, head_dim=1, d_ff=0)
