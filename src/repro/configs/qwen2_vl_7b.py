"""Qwen2-VL-7B — M-RoPE backbone; dynamic-resolution vision frontend is a
stub (input_specs provides patch/token embeddings). [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    norm="rmsnorm", mlp="swiglu", qkv_bias=True,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),   # t/h/w splits of hd//2 = 64
    tie_embeddings=False,
)
SMOKE = CONFIG.reduced()
