"""Whisper-medium — encoder-decoder; conv frontend is a stub (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_encoder_layers=24, encoder_seq=1500,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    norm="layernorm", mlp="gelu", scale_embed=True,
    rope_theta=10000.0, tie_embeddings=True,
)
SMOKE = CONFIG.reduced(n_layers=2, n_encoder_layers=2, encoder_seq=64)
