"""LLaMA3-8B — the paper's main evaluation model (Tables 1, 5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    norm="rmsnorm", mlp="swiglu",
    rope_theta=500000.0, tie_embeddings=False,
)
SMOKE = CONFIG.reduced()
