"""Architecture registry + input-shape cells.

Every assigned architecture gets an exact full config (dry-run only, never
allocated) and a reduced smoke config (CPU tests). Shapes follow the
assignment:
    train_4k     seq 4096   global_batch 256   (train_step)
    prefill_32k  seq 32768  global_batch 32    (serve prefill)
    decode_32k   seq 32768  global_batch 128   (serve decode, 1 new token)
    long_500k    seq 524288 global_batch 1     (long-context decode;
                 only sub-quadratic archs: mamba2, zamba2)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS = [
    "stablelm_3b", "olmo_1b", "nemotron_4_340b", "gemma2_9b",
    "whisper_medium", "qwen2_vl_7b", "mamba2_780m", "zamba2_7b",
    "moonshot_v1_16b", "kimi_k2_1t",
]

# paper's own evaluation models (used by benchmarks, not the dry-run grid)
PAPER_IDS = ["llama3_8b", "qwen15_7b"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


SHAPES: List[ShapeCell] = [
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
]

# archs with sub-quadratic long-context support (run long_500k)
LONG_CONTEXT_OK = {"mamba2_780m", "zamba2_7b"}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return getattr(mod, "SMOKE", mod.CONFIG.reduced())


def get_long_config(arch: str) -> ModelConfig:
    """Config variant used for the long_500k cell (may cap attention windows)."""
    mod = importlib.import_module(f"repro.configs.{arch}")
    return getattr(mod, "LONG", mod.CONFIG)


def cells(arch: str) -> List[ShapeCell]:
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and arch not in LONG_CONTEXT_OK:
            continue
        out.append(s)
    return out


def all_cells() -> List[tuple]:
    return [(a, s) for a in ARCH_IDS for s in cells(a)]
