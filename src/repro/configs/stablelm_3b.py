"""StableLM-3B (stablelm-2 family) — dense MHA transformer.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", mlp="swiglu",
    rope_theta=10000.0, rope_fraction=0.25,   # stablelm partial rotary
    tie_embeddings=False,
)
SMOKE = CONFIG.reduced()
