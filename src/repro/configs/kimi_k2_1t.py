"""Kimi-K2 1T-A32B — trillion-param MoE: 384 experts top-8, 61 layers.
Assigned spec uses GQA kv=8 (the release uses MLA; we follow the assignment).
[arXiv:2501.kimi2; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab_size=163840,          # dense lead layer d_ff
    norm="rmsnorm", mlp="swiglu",
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    n_dense_layers=1,
    rope_theta=50000.0, tie_embeddings=False,
)
SMOKE = CONFIG.reduced(n_experts=8, top_k=2)
