"""Moonshot-v1-16B-A3B (Moonlight) — MoE 64 experts top-6, 1 dense lead
layer + shared expert (DeepSeek-style). [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=11264, vocab_size=163840,          # d_ff used by dense lead layer
    norm="rmsnorm", mlp="swiglu",
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    n_dense_layers=1,
    rope_theta=50000.0, tie_embeddings=False,
)
SMOKE = CONFIG.reduced()
