"""Zamba2-7B — Mamba2 backbone + shared attention block every 9 layers
(81 = 9 groups x 9; the released model interleaves two shared blocks every
~6 layers — we use one shared block at a divisible period, see DESIGN.md).
[arXiv:2411.15242; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    shared_attn_period=9,
    rope_theta=10000.0, tie_embeddings=True,
)
# long-context variant: shared attention gets a sliding window
import dataclasses as _dc
LONG = _dc.replace(CONFIG, sliding_window=4096)
SMOKE = CONFIG.reduced(n_layers=4, shared_attn_period=2, head_dim=32)
