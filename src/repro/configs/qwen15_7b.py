"""Qwen1.5-7B — the paper's second evaluation model (Table 2)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=151936,
    norm="rmsnorm", mlp="swiglu", qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=False,
)
SMOKE = CONFIG.reduced()
