"""Activation smoothing (paper Eqs. 10-12) and the SmoothQuant baseline.

Both operate on a linear layer ``y = W @ x`` (W: [out, in], x: [in, tokens])
and produce a diagonal scaling ``M = diag(m)`` applied as
``W X = (W M)(M^{-1} X)``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SmoothingResult(NamedTuple):
    m: jnp.ndarray            # [in] diagonal of M
    outlier_mask: jnp.ndarray  # [in] bool, True for channels in I_f
    w_scaled: jnp.ndarray      # W @ M
    w_smooth: jnp.ndarray      # W_s (outlier columns zeroed)
    w_outlier: jnp.ndarray     # W_o (only outlier columns)


def outlier_indices(x_absmean: jnp.ndarray, w_absmean: jnp.ndarray, f: int):
    """Top-``f`` channels of X̄ ⊙ W̄ (paper's I_f). Returns bool mask [in]."""
    score = x_absmean * w_absmean
    d = score.shape[0]
    f = min(f, d)
    thresh = jnp.sort(score)[d - f]
    return score >= thresh


def aser_smoothing(w: jnp.ndarray, x_absmean: jnp.ndarray, f: int) -> SmoothingResult:
    """ASER activation smoothing (Eqs. 10-12).

    m_i = X̄_i / X̄_min over the outlier set I_f (X̄_min = min over I_f),
    m_i = 1 elsewhere. Outlier columns of W M become W_o (kept unquantized,
    folded into the reconstruction target); the rest is W_s.
    """
    w = w.astype(jnp.float32)
    x_absmean = x_absmean.astype(jnp.float32)
    w_absmean = jnp.mean(jnp.abs(w), axis=0)
    mask = outlier_indices(x_absmean, w_absmean, f)
    x_min = jnp.min(jnp.where(mask, x_absmean, jnp.inf))
    x_min = jnp.maximum(x_min, 1e-8)
    m = jnp.where(mask, x_absmean / x_min, 1.0)
    m = jnp.maximum(m, 1e-8)
    w_scaled = w * m[None, :]
    w_outlier = jnp.where(mask[None, :], w_scaled, 0.0)
    w_smooth = w_scaled - w_outlier
    return SmoothingResult(m, mask, w_scaled, w_smooth, w_outlier)


def smoothquant_scales(x_absmax: jnp.ndarray, w_absmax_in: jnp.ndarray,
                       alpha: float = 0.5) -> jnp.ndarray:
    """SmoothQuant per-channel scale s_j = max|X_j|^a / max|W_:j|^(1-a).

    Applied as ``(W diag(s)) (diag(s)^{-1} X)`` — i.e. activations divided by
    s. Note the inverse convention vs the paper's M (which multiplies W).
    """
    x_absmax = jnp.maximum(x_absmax.astype(jnp.float32), 1e-5)
    w_absmax_in = jnp.maximum(w_absmax_in.astype(jnp.float32), 1e-5)
    s = x_absmax ** alpha / w_absmax_in ** (1.0 - alpha)
    return jnp.maximum(s, 1e-5)
