"""Uniform integer quantizers used throughout the framework.

Conventions (match the paper's experimental setup):
  * Weights:     symmetric, per-output-channel (a row of W in ``y = W @ x``).
  * Activations: symmetric, per-token (a row of X when X is ``[tokens, d]``).

All functions are pure jnp and jit-able. ``fake_quant*`` returns the
dequantized float tensor (the standard PTQ simulation); ``quantize*`` returns
the integer codes + scales for the true-int serving path / Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization setup for one tensor class."""

    bits: int = 4
    symmetric: bool = True
    # granularity: "per_channel" (axis=0 rows), "per_tensor", or
    # "per_group" with group_size along the reduction axis.
    granularity: str = "per_channel"
    group_size: int = -1

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))


W4 = QuantConfig(bits=4)
W8 = QuantConfig(bits=8)
A8 = QuantConfig(bits=8, granularity="per_token")
A6 = QuantConfig(bits=6, granularity="per_token")
A4 = QuantConfig(bits=4, granularity="per_token")


def _absmax_scale(x: jnp.ndarray, axis, qmax: int) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    # Guard all-zero rows; scale==0 would produce NaNs on divide.
    amax = jnp.maximum(amax, 1e-8)
    return amax / qmax


def quantize_weight(w: jnp.ndarray, cfg: QuantConfig = W4):
    """Symmetric quantization of a weight matrix ``w`` of shape [out, in].

    Returns (codes int8, scale f32). Per-channel => one scale per out row.
    Per-group => scales of shape [out, in//group_size].
    """
    if cfg.granularity == "per_tensor":
        scale = _absmax_scale(w, axis=None, qmax=cfg.qmax)
        codes = jnp.clip(jnp.round(w / scale), cfg.qmin, cfg.qmax)
        return codes.astype(jnp.int8), scale.astype(jnp.float32)
    if cfg.granularity == "per_group" and cfg.group_size > 0:
        out, inn = w.shape
        g = cfg.group_size
        wg = w.reshape(out, inn // g, g)
        scale = _absmax_scale(wg, axis=-1, qmax=cfg.qmax)
        codes = jnp.clip(jnp.round(wg / scale), cfg.qmin, cfg.qmax)
        return codes.reshape(out, inn).astype(jnp.int8), scale[..., 0].astype(jnp.float32)
    # per_channel (paper's setting): one scale per output channel (row).
    scale = _absmax_scale(w, axis=1, qmax=cfg.qmax)
    codes = jnp.clip(jnp.round(w / scale), cfg.qmin, cfg.qmax)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_weight(codes: jnp.ndarray, scale: jnp.ndarray,
                      cfg: QuantConfig = W4) -> jnp.ndarray:
    if cfg.granularity == "per_group" and cfg.group_size > 0:
        out, inn = codes.shape
        g = cfg.group_size
        return (codes.reshape(out, inn // g, g).astype(jnp.float32)
                * scale[..., None]).reshape(out, inn)
    return codes.astype(jnp.float32) * scale


def fake_quant_weight(w: jnp.ndarray, cfg: QuantConfig = W4) -> jnp.ndarray:
    """Quantize-dequantize in the weight's own dtype. This is ``Q(W)``."""
    codes, scale = quantize_weight(w.astype(jnp.float32), cfg)
    return dequantize_weight(codes, scale, cfg).astype(w.dtype)


def quantize_activation(x: jnp.ndarray, cfg: QuantConfig = A8):
    """Per-token symmetric quantization. ``x``: [..., tokens, d].

    Returns (codes int8, scale f32 broadcastable against x).
    """
    scale = _absmax_scale(x, axis=-1, qmax=cfg.qmax)
    codes = jnp.clip(jnp.round(x / scale), cfg.qmin, cfg.qmax)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def fake_quant_activation(x: jnp.ndarray, cfg: QuantConfig = A8) -> jnp.ndarray:
    codes, scale = quantize_activation(x.astype(jnp.float32), cfg)
    return (codes.astype(jnp.float32) * scale).astype(x.dtype)


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 codes (stored as int8 in [-8, 7]) pairwise into int8.

    Packs along the LAST axis: out[..., k] holds (codes[..., 2k] & 0xF) in the
    low nibble and codes[..., 2k+1] in the high nibble.
    """
    assert codes.shape[-1] % 2 == 0
    lo = codes[..., 0::2].astype(jnp.uint8) & 0xF
    hi = (codes[..., 1::2].astype(jnp.uint8) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`; returns int8 codes in [-8, 7]."""
    u = packed.astype(jnp.uint8)
    lo = (u & 0xF).astype(jnp.int8)
    hi = ((u >> 4) & 0xF).astype(jnp.int8)
    # sign-extend nibbles
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


@partial(jax.jit, static_argnames=("w_cfg", "a_cfg"))
def fake_quant_matmul(w: jnp.ndarray, x: jnp.ndarray,
                      w_cfg: QuantConfig = W4,
                      a_cfg: QuantConfig | None = A8) -> jnp.ndarray:
    """Simulated quantized ``W @ X`` (weights [out,in], acts [in, tokens])."""
    wq = fake_quant_weight(w, w_cfg)
    if a_cfg is not None:
        xq = fake_quant_activation(x.T, a_cfg).T
    else:
        xq = x
    return wq @ xq
