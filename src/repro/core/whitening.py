"""Whitening, effective rank and rank selection (paper Eqs. 3-9).

Conventions: a linear layer computes ``y = W @ x`` with ``W: [out, in]`` and
calibration activations ``X: [in, tokens]`` (paper notation ``WX``). The
second moment (Gram) is ``G = X @ X.T : [in, in]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """``X @ X.T`` in fp32. x: [in, tokens]."""
    x = x.astype(jnp.float32)
    return x @ x.T


def cholesky_whitener(g: jnp.ndarray, damp: float = 1e-2):
    """Return ``S`` (lower-triangular) with ``G ≈ S @ S.T`` (Eq. 5).

    ``S^{-1} X`` then has identity Gram. ``damp`` adds ``damp * mean(diag)``
    to the diagonal for numerical robustness (same trick GPTQ uses); the
    whitening identity in Eq. 8 holds for the damped Gram.
    """
    g = g.astype(jnp.float32)
    d = g.shape[0]
    eps = damp * jnp.mean(jnp.diag(g)) + 1e-8
    g = g + eps * jnp.eye(d, dtype=jnp.float32)
    return jnp.linalg.cholesky(g)


def whiten_svd(e_q: jnp.ndarray, s: jnp.ndarray):
    """SVD of ``E_q @ S`` (Eq. 6). Returns (U, sigma, Vt)."""
    es = e_q.astype(jnp.float32) @ s.astype(jnp.float32)
    u, sig, vt = jnp.linalg.svd(es, full_matrices=False)
    return u, sig, vt


def effective_rank(singular_values: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Roy & Vetterli effective rank (Eq. 3-4): exp(entropy of normalized σ)."""
    sig = jnp.maximum(singular_values.astype(jnp.float32), 0.0)
    p = sig / (jnp.sum(sig) + eps) + eps
    return jnp.exp(-jnp.sum(p * jnp.log(p)))


def rank_from_alpha(singular_values: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """Largest r with cumsum(σ_1..σ_r)/sum(σ) < alpha (Eq. 9), at least 1."""
    sig = singular_values.astype(jnp.float32)
    frac = jnp.cumsum(sig) / (jnp.sum(sig) + 1e-12)
    r = jnp.sum((frac < alpha).astype(jnp.int32))
    return jnp.maximum(r, 1)


def low_rank_factors(u: jnp.ndarray, sig: jnp.ndarray, vt: jnp.ndarray,
                     s: jnp.ndarray, rank: int):
    """Build ``L_A = U_r Σ_r`` ([out, r]) and ``L_B = V_r^T S^{-1}`` ([r, in]).

    ``rank`` must be static (used for slicing); dynamic-rank users should pad.
    ``V_r^T S^{-1}`` is computed by triangular solve: solve ``Z S = V_r^T``
    i.e. ``S^T Z^T = V_r`` with S lower-triangular => S^T upper-triangular.
    """
    u_r = u[:, :rank]
    sig_r = sig[:rank]
    vt_r = vt[:rank, :]
    l_a = u_r * sig_r[None, :]
    # Solve Z @ S = vt_r  =>  S.T @ Z.T = vt_r.T
    z_t = jax.scipy.linalg.solve_triangular(s.T, vt_r.T, lower=False)
    l_b = z_t.T
    return l_a, l_b
