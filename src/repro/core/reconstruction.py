"""Low-rank quantization-error reconstruction: LoRC, L²QER and ASER-ER.

Every method returns LoRA-style factors (L_A: [out, r], L_B: [r, in]) such
that the compensated layer computes ``W_q x + L_A (L_B x)``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .whitening import cholesky_whitener, low_rank_factors, rank_from_alpha, whiten_svd


class LowRankComp(NamedTuple):
    l_a: jnp.ndarray
    l_b: jnp.ndarray


def lorc(e_q: jnp.ndarray, rank: int) -> LowRankComp:
    """LoRC (Yao et al. 2024): plain SVD of the *weight* error E_q."""
    u, sig, vt = jnp.linalg.svd(e_q.astype(jnp.float32), full_matrices=False)
    return LowRankComp(u[:, :rank] * sig[:rank][None, :], vt[:rank, :])


def l2qer(e_q: jnp.ndarray, x_absmean: jnp.ndarray, rank: int) -> LowRankComp:
    """L²QER (Zhang et al. 2024): scale E_q by an activation-magnitude diagonal
    before SVD, unscale after. ``x_absmean``: [in]."""
    d = jnp.maximum(x_absmean.astype(jnp.float32), 1e-8)
    es = e_q.astype(jnp.float32) * d[None, :]
    u, sig, vt = jnp.linalg.svd(es, full_matrices=False)
    l_a = u[:, :rank] * sig[:rank][None, :]
    l_b = vt[:rank, :] / d[None, :]
    return LowRankComp(l_a, l_b)


def aser_er(e_q: jnp.ndarray, g: jnp.ndarray, rank: int,
            damp: float = 1e-2) -> LowRankComp:
    """ASER error reconstruction: whitening SVD of E_q S with G = S Sᵀ."""
    s = cholesky_whitener(g, damp=damp)
    u, sig, vt = whiten_svd(e_q, s)
    l_a, l_b = low_rank_factors(u, sig, vt, s, rank)
    return LowRankComp(l_a, l_b)


def aser_er_alpha(e_q: jnp.ndarray, g: jnp.ndarray, alpha: float,
                  max_rank: int, damp: float = 1e-2):
    """ASER-ER with Eq. 9 rank selection. Returns (comp, selected_rank).

    Factors are computed at ``max_rank`` and the tail beyond the α-selected
    rank is zeroed, keeping shapes static for jit while matching the paper's
    adaptive-rank semantics.
    """
    s = cholesky_whitener(g, damp=damp)
    u, sig, vt = whiten_svd(e_q, s)
    r_sel = rank_from_alpha(sig, alpha)
    r_sel = jnp.minimum(r_sel, max_rank)
    l_a, l_b = low_rank_factors(u, sig, vt, s, max_rank)
    keep = (jnp.arange(max_rank) < r_sel).astype(l_a.dtype)
    return LowRankComp(l_a * keep[None, :], l_b * keep[:, None]), r_sel
