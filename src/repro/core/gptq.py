"""GPTQ baseline (Frantar et al. 2022) in pure JAX.

Column-by-column quantization with Hessian-based error propagation into the
remaining (not yet quantized) columns. We implement the Cholesky formulation:

    H = X Xᵀ + damp·I ;  Hinv = cholesky_inverse(H)  (upper form)
    for each column j:   q_j = Q(w_j);  err = (w_j − q_j)/Hinv[j,j]
                         w_{>j} -= err · Hinv[j, >j]

The loop is a ``lax.fori_loop`` over columns; per-channel (row) scales are
precomputed from the original W (as in the reference implementation for
per-channel symmetric quantization).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import QuantConfig, W4


def _hinv_cholesky(g: jnp.ndarray, damp: float) -> jnp.ndarray:
    d = g.shape[0]
    g = g.astype(jnp.float32)
    g = g + (damp * jnp.mean(jnp.diag(g)) + 1e-8) * jnp.eye(d, dtype=jnp.float32)
    # Hinv via Cholesky of the inverse: GPTQ uses chol(inv(H)) upper.
    hinv = jnp.linalg.inv(g)
    # upper-triangular factor: chol(hinv)ᵀ
    l = jnp.linalg.cholesky(hinv)
    return l.T  # upper


@partial(jax.jit, static_argnames=("cfg",))
def gptq_quantize(w: jnp.ndarray, g: jnp.ndarray,
                  cfg: QuantConfig = W4, damp: float = 1e-2) -> jnp.ndarray:
    """Returns the fake-quantized weight Ŵ ([out, in])."""
    w = w.astype(jnp.float32)
    out, inn = w.shape
    hinv = _hinv_cholesky(g, damp)

    # Per-channel symmetric scales from the original weights.
    qmax = cfg.qmax
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True), 1e-8) / qmax

    def body(j, carry):
        w_cur, w_q = carry
        col = w_cur[:, j]
        q = jnp.clip(jnp.round(col[:, None] / scale), cfg.qmin, cfg.qmax)[:, 0]
        deq = q * scale[:, 0]
        w_q = w_q.at[:, j].set(deq)
        err = (col - deq) / hinv[j, j]
        # propagate to remaining columns (mask keeps already-done ones fixed)
        row = hinv[j, :]
        mask = (jnp.arange(inn) > j).astype(w_cur.dtype)
        w_cur = w_cur - jnp.outer(err, row * mask)
        return (w_cur, w_q)

    _, w_q = jax.lax.fori_loop(0, inn, body, (w, jnp.zeros_like(w)))
    return w_q
