"""Core ASER algorithm + PTQ baselines (paper: AAAI 2025, ASER)."""
from .quantizers import (QuantConfig, W4, W8, A4, A6, A8, quantize_weight,
                         dequantize_weight, fake_quant_weight,
                         quantize_activation, fake_quant_activation,
                         pack_int4, unpack_int4)
from .whitening import (gram, cholesky_whitener, whiten_svd, effective_rank,
                        rank_from_alpha, low_rank_factors)
from .smoothing import aser_smoothing, smoothquant_scales, outlier_indices
from .reconstruction import LowRankComp, lorc, l2qer, aser_er, aser_er_alpha
from .aser import AserConfig, AserLayer, quantize_layer, layer_forward
from .gptq import gptq_quantize
from .awq import awq_quantize
from . import metrics

__all__ = [
    "QuantConfig", "W4", "W8", "A4", "A6", "A8",
    "quantize_weight", "dequantize_weight", "fake_quant_weight",
    "quantize_activation", "fake_quant_activation", "pack_int4", "unpack_int4",
    "gram", "cholesky_whitener", "whiten_svd", "effective_rank",
    "rank_from_alpha", "low_rank_factors",
    "aser_smoothing", "smoothquant_scales", "outlier_indices",
    "LowRankComp", "lorc", "l2qer", "aser_er", "aser_er_alpha",
    "AserConfig", "AserLayer", "quantize_layer", "layer_forward",
    "gptq_quantize", "awq_quantize", "metrics",
]
