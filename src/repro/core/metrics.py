"""Quantization quality metrics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .whitening import effective_rank


def output_error(w: jnp.ndarray, w_hat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """‖W X − Ŵ X‖_F (the paper's objective, Eq. 1). x: [in, tokens]."""
    return jnp.linalg.norm((w - w_hat).astype(jnp.float32) @ x.astype(jnp.float32))


def relative_output_error(w, w_hat, x):
    base = jnp.linalg.norm(w.astype(jnp.float32) @ x.astype(jnp.float32))
    return output_error(w, w_hat, x) / jnp.maximum(base, 1e-12)


def error_effective_rank(e: jnp.ndarray) -> jnp.ndarray:
    sig = jnp.linalg.svd(e.astype(jnp.float32), compute_uv=False)
    return effective_rank(sig)


def perplexity(logits: jnp.ndarray, labels: jnp.ndarray,
               mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """PPL from [..., seq, vocab] logits and [..., seq] int labels."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(nll)
    mean_nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.exp(mean_nll)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def top1_accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(hit)
    return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)
