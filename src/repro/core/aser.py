"""ASER Algorithm 1: per-layer quantization with activation smoothing and
whitening-SVD error reconstruction.

The layer convention is ``y = W @ x`` (W: [out, in]); calibration provides the
activation Gram ``G = X Xᵀ`` ([in, in]) and per-channel absolute means X̄.
The returned artifacts reproduce exactly the paper's serving decomposition:

    y ≈ Q(W_s) (M^{-1} x) + L_A (L_B (M^{-1} x))

where M is identity when activation smoothing is off. The ``m`` diagonal is
meant to be *folded into the previous op* (norm scale / preceding weight) at
deployment; the runtime in repro.quant applies it explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from .quantizers import QuantConfig, W4, fake_quant_weight, quantize_weight
from .reconstruction import aser_er, aser_er_alpha
from .smoothing import aser_smoothing


@dataclasses.dataclass(frozen=True)
class AserConfig:
    """Per-layer Algorithm-1 config (reference implementation).

    Whole-model quantization uses the composable
    :class:`repro.quant.recipe.QuantRecipe` pipeline instead; use
    :meth:`from_recipe` to run this single-layer reference under the same
    settings a recipe describes.
    """

    w_cfg: QuantConfig = W4
    # rank selection: fixed rank if > 0, else α-threshold (Eq. 9)
    rank: int = 64
    alpha: float = 0.0
    max_rank: int = 128
    # activation smoothing
    smooth: bool = True
    outlier_f: int = 32
    # Cholesky damping for the whitener
    damp: float = 1e-2

    @classmethod
    def from_recipe(cls, recipe) -> "AserConfig":
        """Project an ASER-shaped QuantRecipe onto the per-layer config.

        Only recipes this reference implements are accepted: an RTN base
        with whitened-SVD reconstruction, with or without the aser-outlier
        smoother.
        """
        if (recipe.base.kind != "rtn"
                or recipe.reconstructor.kind != "whitened-svd"
                or recipe.smoother.kind not in ("none", "aser-outlier")):
            raise ValueError(
                "AserConfig.from_recipe needs an ASER-shaped recipe "
                "(rtn base + whitened-svd reconstructor, optional "
                f"aser-outlier smoother); got {recipe}")
        er = recipe.reconstructor
        return cls(w_cfg=QuantConfig(bits=recipe.base.bits),
                   rank=0 if er.alpha > 0 else er.rank,
                   alpha=er.alpha, max_rank=er.rank,
                   smooth=recipe.smoother.kind == "aser-outlier",
                   outlier_f=recipe.smoother.outlier_f, damp=er.damp)


class AserLayer(NamedTuple):
    """Quantized layer artifacts (per linear)."""

    w_q: jnp.ndarray      # fake-quantized (dequantized) smooth weight [out, in]
    codes: jnp.ndarray    # int codes of Q(W_s) [out, in] (int8 storage)
    w_scale: jnp.ndarray  # per-channel scales [out, 1]
    l_a: jnp.ndarray      # [out, r]
    l_b: jnp.ndarray      # [r, in]
    m: jnp.ndarray        # smoothing diagonal [in] (ones if smoothing off)
    rank: jnp.ndarray     # selected rank (scalar int)


def smooth_gram(g: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """Gram of M^{-1} X given Gram of X: M^{-1} G M^{-1} (M diagonal)."""
    inv = 1.0 / m
    return g * inv[:, None] * inv[None, :]


def quantize_layer(w: jnp.ndarray, g: jnp.ndarray, x_absmean: jnp.ndarray,
                   cfg: AserConfig = AserConfig()) -> AserLayer:
    """Run Algorithm 1 on one linear layer."""
    w = w.astype(jnp.float32)
    in_dim = w.shape[1]

    if cfg.smooth:
        sm = aser_smoothing(w, x_absmean, cfg.outlier_f)
        m = sm.m
        w_s = sm.w_smooth
        # E_q^l = W M - Q(W_s) = (W_s - Q(W_s)) + W_o   (Eq. 12)
        target_extra = sm.w_outlier
        g_eff = smooth_gram(g, m)
    else:
        m = jnp.ones((in_dim,), jnp.float32)
        w_s = w
        target_extra = jnp.zeros_like(w)
        g_eff = g

    codes, w_scale = quantize_weight(w_s, cfg.w_cfg)
    w_q = fake_quant_weight(w_s, cfg.w_cfg)
    e_q = (w_s - w_q) + target_extra

    if cfg.alpha > 0.0:
        comp, r_sel = aser_er_alpha(e_q, g_eff, cfg.alpha, cfg.max_rank,
                                    damp=cfg.damp)
    else:
        comp = aser_er(e_q, g_eff, cfg.rank, damp=cfg.damp)
        r_sel = jnp.asarray(cfg.rank, jnp.int32)

    return AserLayer(w_q=w_q, codes=codes, w_scale=w_scale,
                     l_a=comp.l_a, l_b=comp.l_b, m=m, rank=r_sel)


def layer_forward(layer: AserLayer, x: jnp.ndarray,
                  act_fake_quant=None) -> jnp.ndarray:
    """Reference forward of a quantized layer: x is [in, tokens].

    ``act_fake_quant`` optionally simulates activation quantization applied to
    the smoothed activation (the paper's A8/A6 path).
    """
    x_s = x / layer.m[:, None]
    if act_fake_quant is not None:
        x_s = act_fake_quant(x_s.T).T  # per-token quant expects [tokens, in]
    return layer.w_q @ x_s + layer.l_a @ (layer.l_b @ x_s)
