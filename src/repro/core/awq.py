"""AWQ baseline (Lin et al. 2024): activation-aware per-channel weight scaling.

Grid-search the exponent ``a`` of s_j = X̄_j^a, apply W' = W diag(s),
x' = diag(s)^{-1} x, quantize W' and pick the ``a`` minimizing the output
error on calibration statistics. Error is evaluated through the Gram matrix:

    ‖(W − Ŵ diag(s)^{-1}) X‖_F² = Tr(Δ G Δᵀ),  Δ = W − Ŵ diag(s)^{-1}
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import QuantConfig, W4, fake_quant_weight


def _gram_error(delta: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("oi,ij,oj->", delta, g, delta)


@partial(jax.jit, static_argnames=("cfg", "n_grid"))
def awq_quantize(w: jnp.ndarray, g: jnp.ndarray, x_absmean: jnp.ndarray,
                 cfg: QuantConfig = W4, n_grid: int = 20):
    """Returns (w_hat_effective [out,in], scales [in]) where
    ``w_hat_effective = Q(W diag(s)) diag(s)^{-1}`` — drop-in replacement for W.
    """
    w = w.astype(jnp.float32)
    xm = jnp.maximum(x_absmean.astype(jnp.float32), 1e-5)

    def eval_alpha(a):
        s = jnp.maximum(xm ** a, 1e-5)
        wq = fake_quant_weight(w * s[None, :], cfg) / s[None, :]
        return _gram_error(w - wq, g), s

    alphas = jnp.linspace(0.0, 1.0, n_grid)
    errs, scales = jax.vmap(eval_alpha)(alphas)
    best = jnp.argmin(errs)
    s = scales[best]
    w_hat = fake_quant_weight(w * s[None, :], cfg) / s[None, :]
    return w_hat, s
