"""Explicit serving-runtime configuration.

Replaces the old process-global ``_STATE`` dict in ``repro.kernels.ops``:
activation bit-width, activation-quant granularity, and the pallas-vs-XLA
kernel choice are now carried by an immutable :class:`RuntimeConfig` that is
threaded explicitly through ``serve.Engine``, ``models.forward`` and the
benchmark harnesses. Per-deployment configuration (e.g. a sharded server
running W4A8 next to a weight-only W4A16 replica in the same process) falls
out of this: each engine holds its own ``RuntimeConfig`` instead of racing
on module state.

``RuntimeConfig`` is plain Python data, never traced: it only steers
Python-level branching at trace time, so two engines with different configs
simply compile different programs.
"""
from __future__ import annotations

import dataclasses

# Single source of truth for what the serving runtime implements; recipe
# validation (repro.quant.recipe.ActQuantSpec) references these too.
SUPPORTED_ACT_BITS = (4, 6, 8, 16)
ACT_GRANULARITIES = ("per_token", "per_tensor")

# Autotune modes for the measured kernel-plan cache
# (repro.kernels.autotune). "off" = modeled cost tables only (today's
# behaviour, bit-for-bit); "cache" = consult persisted measured winners,
# fall back to the model on a miss; "force" = measure on miss, persist,
# then use the winner.
AUTOTUNE_MODES = ("off", "cache", "force")

# KV-cache storage dtypes the serving stack implements. "bf16" means the
# model's native cache dtype (bf16 on TPU, f32 for float32 smoke configs);
# "int8"/"int4" store abs-max per-token-per-head quantized codes next to
# f32 scale tensors (int4 codes currently ride in int8 storage — the
# accuracy path exists, the packing does not, so only int8 changes the
# memory footprint). Referenced by ServeConfig(kv_dtype=...) and
# repro.quant.recipe.KVQuantSpec.
KV_CACHE_DTYPES = ("bf16", "int8", "int4")


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """How quantized leaves execute at serving time.

    a_bits: activation bit-width (8 = paper's W4A8; 6/4 for W4A6/W4A4;
        >=16 = weight-only, no activation quantization).
    act_granularity: "per_token" (paper setup) or "per_tensor".
    use_pallas: Pallas kernel path vs the pure-XLA reference (identical math
        up to f32 reduction order).
    interpret: run Pallas kernels in interpret mode (CPU) vs compiled (TPU).
    fused_decode: route small-m (decode/GEMV) quantized linears to the
        single-pass fused kernel (``repro.kernels.w4a8_fused``) instead of
        the two-kernel act_quant → w4a8_gemm pipeline. Only consulted when
        ``use_pallas`` is on; turn off to pin the tiled pipeline for A/B
        debugging.
    autotune: measured kernel-plan cache mode ("off" | "cache" | "force",
        see ``repro.kernels.autotune``). "off" keeps every routing decision
        on the modeled VMEM cost tables in ``repro.kernels.tuning`` —
        bit-for-bit today's behaviour. "cache" consults the persisted
        measured winners first (block shapes, fused-vs-tiled routing, and
        the decode execution plan) and falls back to the model on a miss;
        "force" measures on miss and persists the winner. Like every other
        field this is trace-time Python config: flipping it compiles a
        different program, it never becomes a traced value.
    force_reference: numeric-guard escape hatch — route every kernel
        entry point to the pure-XLA reference path regardless of
        ``use_pallas``/``fused_decode``. This is the one-shot fallback the
        serving stack flips when a non-finite value escapes the fused
        Pallas kernels (``serve.Engine.activate_reference_fallback``): the
        reference math is the ground truth the kernels are pinned against,
        so a suspected-kernel NaN quarantines onto it instead of silently
        poisoning co-batched requests.
    """

    a_bits: int = 8
    act_granularity: str = "per_token"
    use_pallas: bool = False
    interpret: bool = True
    fused_decode: bool = True
    autotune: str = "off"
    force_reference: bool = False

    def __post_init__(self):
        if self.autotune not in AUTOTUNE_MODES:
            raise ValueError(f"autotune must be one of {AUTOTUNE_MODES}: "
                             f"{self.autotune!r}")
        if self.a_bits not in SUPPORTED_ACT_BITS:
            raise ValueError(f"activation bits must be one of "
                             f"{SUPPORTED_ACT_BITS}: {self.a_bits}")
        if self.act_granularity not in ACT_GRANULARITIES:
            raise ValueError(
                f"unknown act granularity {self.act_granularity!r}; "
                f"one of {ACT_GRANULARITIES}")

    def replace(self, **kw) -> "RuntimeConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_RUNTIME = RuntimeConfig()
