"""Serving engine: batched prefill + decode with KV caches.

Designed for the quantized (W4A8 + ASER compensation) model but works for fp
params identically — the ``dense`` dispatch picks the path per leaf. Requests
are padded into fixed batch slots (static shapes ⇒ one compiled program per
(batch, max_len) bucket, the standard TPU serving discipline).

Decode runs as a device-resident ``lax.scan`` over steps: one dispatch for
the whole generation instead of one per token, with the KV caches donated
into the compiled loop so the buffers are updated in place rather than
copied every token. The per-step Python loop survives as
``decode_loop="step"`` — the debug mode whose parity with the scan path is
pinned in tests.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ModelConfig, encode, forward, init_caches,
                          prepare_cross_caches)
from repro.runtime import RuntimeConfig

DECODE_LOOPS = ("scan", "step")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early
    decode_loop: str = "scan"      # "scan" (device-resident) | "step" (debug)

    def __post_init__(self):
        if self.decode_loop not in DECODE_LOOPS:
            raise ValueError(f"decode_loop must be one of {DECODE_LOOPS}: "
                             f"{self.decode_loop!r}")


class Engine:
    """Per-deployment engine: holds its own :class:`RuntimeConfig`, so two
    engines in one process can serve e.g. W4A8-pallas next to W4A16-XLA
    without racing on process state. ``rt=None`` follows the process
    default runtime, read when the engine first traces — the seed
    semantics, so legacy callers that construct an Engine and *then* call
    the deprecated ``ops.set_act_bits``/``ops.use_pallas`` shims before the
    first ``generate()`` still get what they asked for."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig = ServeConfig(),
                 rt: Optional[RuntimeConfig] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt                # None → ops.default_runtime() at trace
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)
        # caches are donated: the loop updates the KV buffers in place
        # instead of copying max_len·layers of cache every token. n_steps
        # is static — one compiled program per generation-length bucket.
        self._decode_loop = jax.jit(self._decode_loop_impl,
                                    static_argnames=("n_steps",),
                                    donate_argnums=(2,))

    # -- compiled steps ----------------------------------------------------
    def _prefill_impl(self, params, tokens, caches, encoder_out=None):
        """tokens: [b, s_prompt]. Runs the prompt through, filling caches."""
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    encoder_out=encoder_out, rt=self.rt)
        return logits[:, -1], caches

    def _sample(self, lg, key):
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(key, lg / self.scfg.temperature,
                                         axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32)

    def _decode_impl(self, params, last_tok, caches, key):
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    caches=caches, rt=self.rt)
        return self._sample(logits[:, 0], key), caches

    def _decode_loop_impl(self, params, tok0, caches, key, done0, *,
                          n_steps: int):
        """Device-resident decode: [b] tok0 → [b, n_steps] next tokens.

        Finished slots (``done``) keep emitting ``eos_id`` and stop
        advancing their sampled continuation; once every slot is done the
        whole forward is skipped on device (``jnp.all(done)`` cond)."""
        eos = self.scfg.eos_id

        def step(carry, _):
            tok, caches, key, done = carry
            key, sub = jax.random.split(key)
            logits, new_caches, _ = forward(params, self.cfg, tok[:, None],
                                            caches=caches, rt=self.rt)
            nxt = self._sample(logits[:, 0], sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            return (nxt, new_caches, key, done), nxt

        def body(carry, _):
            if eos < 0:
                return step(carry, _)
            # early-stop: skip the whole forward once every slot finished
            return jax.lax.cond(
                jnp.all(carry[3]),
                lambda c: (c, jnp.full_like(c[0], eos)),
                lambda c: step(c, _),
                carry)

        (tok, caches, key, done), toks = jax.lax.scan(
            body, (tok0, caches, key, done0), None, length=n_steps)
        return toks.T, caches                     # [b, n_steps]

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, n_steps: int,
                 frames: Optional[jnp.ndarray] = None, seed: int = 0):
        """prompts: [b, s]. Returns generated tokens [b, n_steps].

        With ``eos_id >= 0``, slots that emit eos keep emitting it for the
        remaining steps (masked continuation) — output shape stays static.
        """
        b = prompts.shape[0]
        eos = self.scfg.eos_id
        caches = init_caches(self.cfg, b, self.scfg.max_len)
        enc_out = None
        if self.cfg.family == "encdec":
            assert frames is not None
            enc_out = encode(self.params, self.cfg, frames, rt=self.rt)
            caches = prepare_cross_caches(self.params, self.cfg, enc_out,
                                          caches, rt=self.rt)
        last, caches = self._prefill(self.params, prompts, caches)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(seed)
        done = (tok == eos) if eos >= 0 else jnp.zeros((b,), bool)

        if self.scfg.decode_loop == "scan":
            toks, _ = self._decode_loop(self.params, tok, caches, key, done,
                                        n_steps=max(n_steps - 1, 0))
            return jnp.concatenate([tok[:, None], toks], axis=1)

        out = [tok]
        for i in range(n_steps - 1):
            key, sub = jax.random.split(key)
            nxt, caches = self._decode(self.params, tok, caches, sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            tok = nxt
            out.append(tok)
        return jnp.stack(out, axis=1)
