"""Serving engine: batched prefill + decode with KV caches.

Designed for the quantized (W4A8 + ASER compensation) model but works for fp
params identically — the ``dense`` dispatch picks the path per leaf. Requests
are padded into fixed batch slots (static shapes ⇒ one compiled program per
(batch, max_len) bucket, the standard TPU serving discipline) — but batches
do **not** have to be equal-length: ``generate(..., prompt_lens=...)`` runs a
ragged batch, sampling each row's first token from its true last prompt
position (not the pad) and decoding each row at its own cache position.

Decode runs as a device-resident ``lax.scan`` over steps: one dispatch for
the whole generation instead of one per token, with the KV caches donated
into the compiled loop so the buffers are updated in place rather than
copied every token. The per-step Python loop survives as
``decode_loop="step"`` — the debug mode whose parity with the scan path is
pinned in tests.

For continuous batching (``repro.serve.scheduler``) the engine additionally
exposes slot-level primitives: ``prefill_slot`` (single-request prefill
scattered into one row of a live batch cache) and ``decode_chunk`` (a
fixed-size ragged scan chunk carrying per-slot ``done``/``pos`` so the
scheduler can retire and backfill slots between chunks).

``ServeConfig(kv_layout="paged")`` swaps the per-slot contiguous lanes for
a **block-paged** KV cache: per-layer physical pools of
``num_blocks × block_size`` token slots plus per-request block tables
(``[b, max_len // block_size]`` int32, sentinel ``num_blocks`` for unmapped
entries). The compiled programs are the same shapes either way; the host
side (``serve.paged_cache.BlockPool`` + the scheduler) owns allocation,
prefix sharing, and copy-on-write.

``ServeConfig(kv_dtype="int8")`` (or ``"int4"``) stores the KV cache
quantized — abs-max per-token-per-head int8 codes next to f32 scale
tensors — in either layout. Inserts quantize, reads dequantize (fused into
the paged-gather decode kernel's epilogue on the Pallas path). At a fixed
KV HBM budget the smaller page lets :func:`blocks_for_hbm_budget` roughly
double ``num_blocks``, which the page-aware scheduler converts into
admitted concurrency; the accuracy cost is bounded by the parity tests
(int8-KV vs native decode tolerance documented in
``docs/serving_perf.md``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (KVCache, ModelConfig, PagedKVCache, encode,
                          forward, init_caches, init_paged_caches,
                          prepare_cross_caches)
from repro.runtime import KV_CACHE_DTYPES, RuntimeConfig

DECODE_LOOPS = ("scan", "step")
KV_LAYOUTS = ("contiguous", "paged")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early
    decode_loop: str = "scan"      # "scan" (device-resident) | "step" (debug)
    kv_layout: str = "contiguous"  # "contiguous" (per-slot lanes) | "paged"
    block_size: int = 16           # tokens per page (paged layout)
    num_blocks: int = 0            # pool size; 0 → batch_slots * max_len/bs
    kv_dtype: str = "bf16"         # "bf16" (native) | "int8" | "int4"
    prefill_chunk: int = 0         # tokens prefilled per chunk (0 = one-shot)
    step_token_budget: int = 0     # max tokens one Scheduler.step spends
    #                                across prefill chunks + the decode chunk
    #                                (0 = unbounded)

    def __post_init__(self):
        if self.decode_loop not in DECODE_LOOPS:
            raise ValueError(f"decode_loop must be one of {DECODE_LOOPS}: "
                             f"{self.decode_loop!r}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}: "
                             f"{self.kv_layout!r}")
        if self.kv_dtype not in KV_CACHE_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_CACHE_DTYPES}: "
                             f"{self.kv_dtype!r}")
        if self.kv_layout == "paged":
            if self.block_size < 1:
                raise ValueError(f"block_size must be >= 1: "
                                 f"{self.block_size}")
            if self.max_len % self.block_size:
                raise ValueError(
                    f"max_len ({self.max_len}) must be a multiple of "
                    f"block_size ({self.block_size}) so per-request views "
                    f"and contiguous lanes have identical widths")
            if self.num_blocks and \
                    self.num_blocks * self.block_size < self.max_len:
                raise ValueError(
                    f"num_blocks ({self.num_blocks}) * block_size "
                    f"({self.block_size}) must cover max_len "
                    f"({self.max_len}): one max-length request must fit a "
                    f"drained pool or admission can livelock")
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0: {self.prefill_chunk}")
        if self.step_token_budget < 0:
            raise ValueError(
                f"step_token_budget must be >= 0: {self.step_token_budget}")
        if self.step_token_budget and not self.prefill_chunk:
            raise ValueError(
                "step_token_budget requires chunked prefill "
                "(prefill_chunk > 0): a one-shot prefill is a single "
                "unbudgetable dispatch")
        if self.step_token_budget \
                and self.step_token_budget < self.prefill_chunk:
            raise ValueError(
                f"step_token_budget ({self.step_token_budget}) must be >= "
                f"prefill_chunk ({self.prefill_chunk}) or no chunk could "
                f"ever be scheduled")

    @property
    def blocks_per_seq(self) -> int:
        return self.max_len // self.block_size

    @property
    def pool_blocks(self) -> int:
        return self.num_blocks or self.batch_slots * self.blocks_per_seq

    @property
    def kv_bits(self) -> int:
        return {"bf16": 16, "int8": 8, "int4": 4}[self.kv_dtype]


def kv_page_bytes(cfg: ModelConfig, block_size: int,
                  kv_dtype: str = "bf16") -> int:
    """HBM bytes one pool page costs across all layers (K + V [+ scales]).

    ``"bf16"`` means the model's native cache dtype (bf16, or f32 for
    float32 configs). Quantized pages store 1-byte codes plus one f32
    scale per token slot per kv head for each of K and V; int4 codes
    currently ride in int8 storage, so only int8 shrinks the page (the
    accounting is honest about that — int4 pages cost int8 bytes).
    """
    if kv_dtype not in KV_CACHE_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_CACHE_DTYPES}: "
                         f"{kv_dtype!r}")
    slots = block_size * cfg.n_kv_heads
    if kv_dtype == "bf16":
        item = 4 if cfg.dtype == "float32" else 2
        per_layer = 2 * slots * cfg.head_dim * item
    else:
        per_layer = 2 * slots * cfg.head_dim + 2 * slots * 4
    return per_layer * cfg.n_layers


def blocks_for_hbm_budget(cfg: ModelConfig, block_size: int, kv_dtype: str,
                          hbm_bytes: int) -> int:
    """Largest pool (``num_blocks``) whose K/V/scale tensors fit a KV-cache
    HBM budget — the knob that converts KV quantization into *concurrency*:
    at a fixed budget an int8 pool admits ~2× (native bf16) or ~4×
    (native f32) the pages, which the page-aware scheduler turns directly
    into admitted requests.

    Raises when the budget can't hold even one page: returning 0 would
    read as ``ServeConfig(num_blocks=0)`` — "use the default pool" — and
    silently blow the budget it was asked to respect.
    """
    blocks = int(hbm_bytes) // kv_page_bytes(cfg, block_size, kv_dtype)
    if blocks < 1:
        raise ValueError(
            f"KV HBM budget {hbm_bytes} B is smaller than one "
            f"{kv_dtype} page "
            f"({kv_page_bytes(cfg, block_size, kv_dtype)} B across "
            f"{cfg.n_layers} layers)")
    return blocks


class Engine:
    """Per-deployment engine: holds its own :class:`RuntimeConfig`, so two
    engines in one process can serve e.g. W4A8-pallas next to W4A16-XLA
    without racing on process state. ``rt=None`` uses the process default
    ``repro.runtime.DEFAULT_RUNTIME`` at trace time."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig = ServeConfig(),
                 rt: Optional[RuntimeConfig] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt                # None → ops.default_runtime() at trace
        # measured-autotune engine hook: under rt.autotune "cache"/"force"
        # the decode-plan cache entry may rewrite quantized params into the
        # prepared layout (repro.kernels.autotune); "off"/miss is identity
        if rt is not None and rt.autotune != "off":
            from repro.kernels import autotune as _autotune
            params, self.decode_plan = _autotune.maybe_prepare_engine_params(
                params, cfg, scfg, rt)
        else:
            self.decode_plan = "default"
        self.params = params
        self.fallback_active = False
        self._build_programs()

    def _build_programs(self):
        """(Re)create the jit wrappers. The impls read ``self.rt`` at trace
        time and jit caches key on input avals only, so changing ``self.rt``
        **must** go through here — mutating it in place would keep serving
        the stale compiled programs."""
        self._prefill = jax.jit(self._prefill_impl)
        self._prefill_ragged = jax.jit(self._prefill_ragged_impl)
        # per-token steps donate the caches too: without it every debug-loop
        # token copies the full max_len·layers KV tree
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,))
        self._decode_ragged = jax.jit(self._decode_ragged_impl,
                                      donate_argnums=(2,))
        # caches are donated: the loop updates the KV buffers in place
        # instead of copying max_len·layers of cache every token. n_steps
        # is static — one compiled program per generation-length bucket.
        self._decode_loop = jax.jit(self._decode_loop_impl,
                                    static_argnames=("n_steps",),
                                    donate_argnums=(2,))
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("n_steps",),
                                     donate_argnums=(2,))
        self._prefill_slot = jax.jit(self._prefill_slot_impl,
                                     donate_argnums=(3,))
        # resumable chunked prefill (contiguous lanes): unlike the one-shot
        # _prefill_slot it must *read* KV earlier chunks wrote, so it
        # gathers the slot's lane, runs the ragged forward at explicit
        # positions, and scatters the lane back
        self._prefill_slot_chunk = jax.jit(self._prefill_slot_chunk_impl,
                                           donate_argnums=(4,))
        # paged-only programs: suffix prefill through a block table and the
        # device-side COW copy; the ragged prefill/decode programs above
        # serve both layouts (``tables=None`` ⇒ contiguous), with the pool
        # tree donated exactly like the lane caches
        self._prefill_slot_paged = jax.jit(self._prefill_slot_paged_impl,
                                           donate_argnums=(4,))
        self._copy_blocks = jax.jit(self._copy_blocks_impl,
                                    donate_argnums=(0,))
        self._fill_blocks = jax.jit(self._fill_blocks_impl,
                                    static_argnames=("value",),
                                    donate_argnums=(0,))

    def activate_reference_fallback(self) -> bool:
        """One-shot numeric-guard fallback: reroute every kernel entry
        point to the pure-XLA reference path
        (``RuntimeConfig.force_reference``) and rebuild the compiled
        programs. Called by the scheduler the first time a non-finite
        value escapes a decode chunk while the Pallas path is active — the
        reference math is the ground truth the kernels are pinned against,
        so a suspected-kernel NaN is quarantined onto it instead of
        poisoning co-batched requests. Returns True if the engine actually
        switched (False when already on the reference path — including
        engines that never used Pallas: there is nothing to fall back
        from, and the quarantine/retry machinery alone handles the
        fault)."""
        from repro.kernels.ops import default_runtime
        base = self.rt if self.rt is not None else default_runtime()
        if not base.use_pallas or base.force_reference \
                or self.fallback_active:
            return False
        self.rt = base.replace(force_reference=True)
        self.fallback_active = True
        self._build_programs()        # fresh jit caches ⇒ retrace on next call
        return True

    # -- compiled steps ----------------------------------------------------
    def _prefill_impl(self, params, tokens, caches, encoder_out=None):
        """tokens: [b, s_prompt]. Runs the prompt through, filling caches."""
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    encoder_out=encoder_out, rt=self.rt)
        return logits[:, -1], caches

    def _prefill_ragged_impl(self, params, tokens, lens, caches,
                             tables=None):
        """Ragged prefill: tokens [b, s_pad] right-padded, lens [b].

        The padded forward itself is already sound under causal attention —
        a real token at position p < len only ever attends positions ≤ p,
        all real — so the fix is where we *read*: gather each row's logits
        at its true last prompt position ``lens-1``, never the pad tail.
        Pad positions do write garbage KV beyond each row's length; ragged
        decode overwrites them positionally and masks the rest per row
        (contiguous), or drops them at unmapped pages (paged —
        ``tables`` [b, nb] routes each row's writes through its block
        table; jit specializes on the None-vs-array structure, so both
        layouts share this one impl).
        """
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    block_tables=tables, rt=self.rt)
        b = tokens.shape[0]
        last = logits[jnp.arange(b), jnp.maximum(lens - 1, 0)]
        return last, caches

    def _sample(self, lg, key):
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(key, lg / self.scfg.temperature,
                                         axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32)

    def _decode_impl(self, params, last_tok, caches, key):
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    caches=caches, rt=self.rt)
        return self._sample(logits[:, 0], key), caches

    def _decode_ragged_impl(self, params, last_tok, caches, key, pos,
                            tables=None):
        """One ragged decode step: row i's token is at position pos[i].
        ``tables=None`` ⇒ contiguous lanes; [b, nb] ⇒ paged pool."""
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    positions=pos[:, None], caches=caches,
                                    ragged=True, block_tables=tables,
                                    rt=self.rt)
        return self._sample(logits[:, 0], key), caches

    def _decode_loop_impl(self, params, tok0, caches, key, done0, *,
                          n_steps: int):
        """Device-resident decode: [b] tok0 → [b, n_steps] next tokens.

        Finished slots (``done``) keep emitting ``eos_id`` and stop
        advancing their sampled continuation; once every slot is done the
        whole forward is skipped on device (``jnp.all(done)`` cond)."""
        eos = self.scfg.eos_id

        def step(carry, _):
            tok, caches, key, done = carry
            key, sub = jax.random.split(key)
            logits, new_caches, _ = forward(params, self.cfg, tok[:, None],
                                            caches=caches, rt=self.rt)
            nxt = self._sample(logits[:, 0], sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            return (nxt, new_caches, key, done), nxt

        def body(carry, _):
            if eos < 0:
                return step(carry, _)
            # early-stop: skip the whole forward once every slot finished
            return jax.lax.cond(
                jnp.all(carry[3]),
                lambda c: (c, jnp.full_like(c[0], eos)),
                lambda c: step(c, _),
                carry)

        (tok, caches, key, done), toks = jax.lax.scan(
            body, (tok0, caches, key, done0), None, length=n_steps)
        return toks.T, caches                     # [b, n_steps]

    def _decode_chunk_impl(self, params, tok0, caches, key, done0, pos0,
                           tables=None, aslots=None, *, n_steps: int):
        """Ragged device-resident decode chunk: per-row positions.

        Carries per-slot ``pos`` (each row writes KV at its own frontier)
        next to the ``done`` mask of :meth:`_decode_loop_impl`. Returns the
        full carry so the continuous-batching scheduler can stitch chunks:
        ``(toks [b, n_steps], caches, key, done, pos, bad)``.

        ``tables`` ([b, nb] int32, or None for contiguous lanes) is
        constant across the chunk — the scheduler grows tables only
        between chunks. Retired paged slots hold all-sentinel rows, so
        their writes drop on device and freed pages can be re-used by
        neighbours mid-flight. ``aslots`` ([b] int32, or None when no
        adapter pools are routed) carries each slot's adapter-pool index —
        constant across the chunk for the same reason; retired slots point
        at slot 0 (the all-zero base adapter).

        Numeric guard: the carry accumulates a per-slot ``bad`` mask — any
        non-finite logit in a slot's row on any step of the chunk (rows
        are independent in every batched op, so a NaN in slot i poisons
        slot i alone). The scheduler quarantines flagged slots (their
        chunk tokens are garbage) without touching their neighbours.
        """
        eos = self.scfg.eos_id

        def step(carry, _):
            tok, caches, key, done, pos, bad = carry
            key, sub = jax.random.split(key)
            logits, new_caches, _ = forward(params, self.cfg, tok[:, None],
                                            positions=pos[:, None],
                                            caches=caches, ragged=True,
                                            block_tables=tables,
                                            adapter_idx=aslots, rt=self.rt)
            lg = logits[:, 0]
            bad = bad | (~jnp.all(jnp.isfinite(lg), axis=-1) & ~done)
            nxt = self._sample(lg, sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            return (nxt, new_caches, key, done, pos + 1, bad), nxt

        def body(carry, _):
            if eos < 0:
                return step(carry, _)
            return jax.lax.cond(
                jnp.all(carry[3]),
                lambda c: (c, jnp.full_like(c[0], eos)),
                lambda c: step(c, _),
                carry)

        bad0 = jnp.zeros_like(done0)
        carry, toks = jax.lax.scan(
            body, (tok0, caches, key, done0, pos0, bad0), None,
            length=n_steps)
        tok, caches, key, done, pos, bad = carry
        return toks.T, caches, key, done, pos, bad  # toks: [b, n_steps]

    def _prefill_slot_impl(self, params, tokens, length, caches, slot,
                           aslot=None):
        """Single-request prefill into one slot of a live batch cache.

        tokens: [1, s_bucket] right-padded; ``length``/``slot`` traced
        scalars. Runs a b=1 prefill against fresh caches, then scatters the
        resulting KV rows into ``caches`` at ``slot`` — the other slots'
        cached state is untouched, which is what lets the scheduler backfill
        a retired slot while its neighbours keep decoding. ``aslot`` ([1]
        int32 or None): the request's adapter-pool slot.
        """
        one = init_caches(self.cfg, 1, self.scfg.max_len,
                          kv_dtype=self.scfg.kv_dtype)
        logits, one, _ = forward(params, self.cfg, tokens, caches=one,
                                 adapter_idx=aslot, rt=self.rt)
        last = logits[0, jnp.maximum(length - 1, 0)]

        def put(bc, oc):
            if not isinstance(bc, KVCache):
                return bc          # SSM caches are gated out of ragged mode
            ax = bc.k.ndim - 4     # batch axis (scanned groups lead with G)

            def upd_ax(dst, src, a):
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), slot, axis=a)

            ks = vs = None
            if bc.k_scale is not None:
                # scale lanes [*, b, L, n_kv]: batch axis sits one dim
                # closer to the end than on the [*, b, L, n_kv, hd] codes
                s_ax = bc.k_scale.ndim - 3
                ks = upd_ax(bc.k_scale, oc.k_scale, s_ax)
                vs = upd_ax(bc.v_scale, oc.v_scale, s_ax)
            return KVCache(upd_ax(bc.k, oc.k, ax), upd_ax(bc.v, oc.v, ax),
                           bc.length, bc.pos, ks, vs, bc.qmax)

        caches = jax.tree.map(put, caches, one,
                              is_leaf=lambda x: isinstance(x, KVCache))
        return last, caches

    def _prefill_slot_chunk_impl(self, params, tokens, length, start,
                                 caches, slot, aslot=None):
        """Resumable contiguous prefill: one chunk of a prompt into one
        slot's live lane, at positions ``[start, start + length)``.

        tokens: [1, w_bucket] right-padded; ``length``/``start``/``slot``
        traced scalars. The one-shot :meth:`_prefill_slot_impl` runs
        against *fresh* b=1 caches and scatters — sound only because a
        whole prompt never attends KV outside itself. A later chunk must
        attend the KV earlier chunks already wrote into the slot's lane,
        so this impl gathers that lane into a b=1 view, runs the ragged
        forward at explicit positions (writes land at the chunk's own
        offsets, causal attention reads everything before them), and
        scatters the lane back. Pad positions beyond ``length`` write
        garbage KV past the chunk's frontier — safe under the same
        positional-overwrite discipline as bucketed one-shot prefill: the
        next chunk (or decode) rewrites those positions before any real
        query can attend them.

        Returns the logits at the chunk's last real position (only the
        final chunk's are ever sampled) and the updated cache tree.
        """
        b, w = tokens.shape
        positions = start + jnp.broadcast_to(
            jnp.arange(w, dtype=jnp.int32)[None], (b, w))

        def take(bc):
            if not isinstance(bc, KVCache):
                return bc      # SSM caches are gated out of ragged mode

            def sl(x, a):
                return jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=a)

            ks = vs = None
            if bc.k_scale is not None:
                s_ax = bc.k_scale.ndim - 3
                ks = sl(bc.k_scale, s_ax)
                vs = sl(bc.v_scale, s_ax)
            ax = bc.k.ndim - 4     # batch axis (scanned groups lead with G)
            return KVCache(sl(bc.k, ax), sl(bc.v, ax), bc.length, bc.pos,
                           ks, vs, bc.qmax)

        one = jax.tree.map(take, caches,
                           is_leaf=lambda x: isinstance(x, KVCache))
        logits, one, _ = forward(params, self.cfg, tokens,
                                 positions=positions, caches=one,
                                 ragged=True, adapter_idx=aslot, rt=self.rt)
        last = logits[0, jnp.maximum(length - 1, 0)]

        def put(bc, oc):
            if not isinstance(bc, KVCache):
                return bc
            ax = bc.k.ndim - 4

            def upd_ax(dst, src, a):
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), slot, axis=a)

            ks = vs = None
            if bc.k_scale is not None:
                s_ax = bc.k_scale.ndim - 3
                ks = upd_ax(bc.k_scale, oc.k_scale, s_ax)
                vs = upd_ax(bc.v_scale, oc.v_scale, s_ax)
            return KVCache(upd_ax(bc.k, oc.k, ax), upd_ax(bc.v, oc.v, ax),
                           bc.length, bc.pos, ks, vs, bc.qmax)

        caches = jax.tree.map(put, caches, one,
                              is_leaf=lambda x: isinstance(x, KVCache))
        return last, caches

    # -- paged compiled steps ---------------------------------------------
    def _prefill_slot_paged_impl(self, params, tokens, length, start,
                                 caches, table, aslot=None):
        """Single-request paged prefill of a prompt *suffix*.

        tokens: [1, s_bucket] right-padded; ``start`` is the number of
        prompt tokens already present via shared prefix blocks (their KV is
        read through ``table`` but never re-computed); ``length`` is the
        suffix length. Unlike the contiguous ``prefill_slot`` there is no
        scatter-into-slot step: the pool is global, so writing through the
        table IS the admission. ``aslot`` ([1] int32 or None): the
        request's adapter-pool slot.
        """
        b, w = tokens.shape
        positions = start + jnp.broadcast_to(
            jnp.arange(w, dtype=jnp.int32)[None], (b, w))
        logits, caches, _ = forward(params, self.cfg, tokens,
                                    positions=positions, caches=caches,
                                    ragged=True, block_tables=table,
                                    adapter_idx=aslot, rt=self.rt)
        last = logits[0, jnp.maximum(length - 1, 0)]
        return last, caches

    def _copy_blocks_impl(self, caches, src, dst):
        """Device-side block copy (copy-on-write): pool[dst] = pool[src].

        src/dst: [n] int32 physical block ids. Applied to every paged leaf
        (all layers share the same table geometry)."""
        def cp(leaf):
            if not isinstance(leaf, PagedKVCache):
                return leaf
            def one(arr, tail):            # block axis (scanned groups lead)
                ax = arr.ndim - tail
                taken = jnp.take(arr, src, axis=ax)
                idx = [slice(None)] * arr.ndim
                idx[ax] = dst
                return arr.at[tuple(idx)].set(taken)
            ks = vs = None
            if leaf.k_scale is not None:   # scale pools [*, nb, bs, n_kv]
                ks = one(leaf.k_scale, 3)
                vs = one(leaf.v_scale, 3)
            return PagedKVCache(one(leaf.k, 4), one(leaf.v, 4), leaf.length,
                                ks, vs, leaf.qmax)
        return jax.tree.map(cp, caches,
                            is_leaf=lambda x: isinstance(x, PagedKVCache))

    def _fill_blocks_impl(self, caches, ids, *, value: float):
        """Overwrite pool blocks ``ids`` with ``value`` in every layer.

        ``value=0.0`` is the quarantine **scrub**: freed pages that held
        (possibly non-finite) garbage are zeroed before reuse, because a
        NaN lingering in the masked tail of a recycled page would poison
        its next owner through ``0 * NaN`` in the attention value product.
        Non-float leaves (int8 KV codes) are filled with 0; float scale
        pools take ``value`` directly (a NaN scale is how a corrupted
        quantized page manifests).
        """
        ids = jnp.asarray(ids, jnp.int32)

        def fill(leaf):
            if not isinstance(leaf, PagedKVCache):
                return leaf

            def one(arr, tail):
                ax = arr.ndim - tail
                v = value if jnp.issubdtype(arr.dtype, jnp.floating) else 0
                idx = [slice(None)] * arr.ndim
                idx[ax] = ids
                return arr.at[tuple(idx)].set(jnp.asarray(v, arr.dtype))
            ks = vs = None
            if leaf.k_scale is not None:
                ks = one(leaf.k_scale, 3)
                vs = one(leaf.v_scale, 3)
            return PagedKVCache(one(leaf.k, 4), one(leaf.v, 4), leaf.length,
                                ks, vs, leaf.qmax)
        return jax.tree.map(fill, caches,
                            is_leaf=lambda x: isinstance(x, PagedKVCache))

    # -- scheduler-facing API ---------------------------------------------
    def new_caches(self):
        """Fresh caches for this engine's layout.

        Contiguous: per-slot lanes ``[batch_slots, max_len, n_kv, hd]`` per
        layer. Paged: per-layer pools ``[pool_blocks, block_size, n_kv,
        hd]`` (no batch axis; ownership lives in host-side block tables).
        """
        if self.scfg.kv_layout == "paged":
            self._check_ragged_supported()
            return init_paged_caches(self.cfg, self.scfg.pool_blocks,
                                     self.scfg.block_size,
                                     kv_dtype=self.scfg.kv_dtype)
        return init_caches(self.cfg, self.scfg.batch_slots, self.scfg.max_len,
                           kv_dtype=self.scfg.kv_dtype)

    @property
    def adapter_slots(self) -> int:
        """Pool slots installed in this engine's params (0 = no pools)."""
        from repro.serve.adapters import adapter_slot_count
        return adapter_slot_count(self.params)

    def load_adapter(self, factors, slot: int):
        """Write one adapter's folded factors into pool slot ``slot`` of
        every quantized leaf (see ``serve.adapters.load_adapter``).

        Host-driven per-leaf functional updates — deliberately *not* a
        whole-tree donated jit program: donating params would invalidate
        the packed base weights (``qw``/``sw``/…) that other engines in
        the process may share, and the pools are tiny next to them.
        """
        from repro.serve.adapters import load_adapter
        self.params = load_adapter(self.params, factors, slot)

    def prefill_slot(self, tokens, length, caches, slot, *,
                     block_table=None, start: int = 0, adapter_slot=None):
        """Prefill one request into the live serving state.

        Args:
          tokens: ``[1, s_bucket]`` int32, right-padded to a power-of-two
            bucket width (pad value is arbitrary; pad positions are never
            sampled and their cache writes are overwritten positionally —
            contiguous — or dropped at unmapped pages — paged).
          length: true token count (``1 <= length <= s_bucket``); traced.
          caches: the live cache tree. **Donated** — rebind to the result.
          slot: destination batch row (contiguous layout; ignored for
            paged, where the block table *is* the destination).
          block_table: paged only — ``[blocks_per_seq]`` int32 physical ids
            (sentinel ``num_blocks`` beyond the mapped prefix).
          start: paged only — prompt tokens already present via shared
            prefix pages; ``tokens`` then holds the remaining suffix and
            positions start at ``start``.
          adapter_slot: adapter-pool index for this request (None = no
            routing; 0 = explicit base). Requires installed pools.

        Returns ``(next_tok, caches, bad)``: the greedily sampled first
        token as a **host int**, the updated cache tree, and a python bool
        that is True when the sampled logits contain a non-finite value —
        the scheduler must then quarantine the request (and its freshly
        written pages) instead of emitting the garbage token. Token and
        guard bit come back through one explicit ``jax.device_get`` — the
        admission-time sync point; steady-state decode chunks never sync
        (see ``Scheduler.step``).
        """
        self._check_ragged_supported()
        # jax.device_put, not jnp.asarray: scalar/list uploads through
        # jnp.asarray are *implicit* transfers (blocked under
        # jax.transfer_guard("disallow"), which the serving sanitizers run
        # steady-state paths under); device_put is the explicit form.
        aslot = (None if adapter_slot is None
                 else jax.device_put(np.asarray([adapter_slot], np.int32)))
        if self.scfg.kv_layout == "paged":
            if block_table is None:
                raise ValueError("paged prefill_slot needs a block_table")
            last, caches = self._prefill_slot_paged(
                self.params, tokens, jax.device_put(np.int32(length)),
                jax.device_put(np.int32(start)), caches,
                jax.device_put(np.asarray(block_table, np.int32)[None]),
                aslot)
        else:
            last, caches = self._prefill_slot(
                self.params, tokens, jax.device_put(np.int32(length)),
                caches, jax.device_put(np.int32(slot)), aslot)
        tok_dev = jnp.argmax(last, axis=-1).astype(jnp.int32)
        ok_dev = jnp.all(jnp.isfinite(last))
        # One explicit transfer for both scalars: the sampled token must
        # reach the host scheduler to enter its Python token list, and the
        # finite guard gates quarantine. Admission-time only — legal under
        # jax.transfer_guard("disallow").
        tok, ok = jax.device_get((tok_dev, ok_dev))  # repro: noqa[RA001] admission sync point: token + finite guard leave the device here by design
        return int(tok), caches, not bool(ok)

    def prefill_slot_chunk(self, tokens, length, caches, slot, *,
                           start: int = 0, block_table=None,
                           adapter_slot=None, final: bool = False):
        """Prefill one *chunk* of a request, resumably.

        The chunked counterpart of :meth:`prefill_slot`: the scheduler
        calls it repeatedly with ``start`` advancing by the chunk length,
        writing KV for positions ``[start, start + length)`` only. Unlike
        one-shot prefill, a chunk must attend KV written by earlier
        chunks, so the contiguous path runs a dedicated gather → ragged
        forward → scatter program; the paged path reads earlier KV through
        the block table exactly like suffix prefill already does.

        Args:
          tokens: ``[1, w_bucket]`` int32, the chunk's tokens right-padded
            to a power-of-two bucket width.
          length: true chunk token count (``1 <= length <= w_bucket``).
          caches: live cache tree. **Donated** — rebind to the result.
          slot: destination batch row (contiguous; ignored for paged).
          start: absolute position of the chunk's first token (prompt
            tokens already written by earlier chunks / shared prefix
            pages).
          block_table: paged only — ``[blocks_per_seq]`` int32 physical
            ids covering at least ``start + length`` token slots.
          adapter_slot: adapter-pool index (None = no routing).
          final: True for the prompt's last chunk — sample the first
            generated token and run the finite guard.

        Returns ``(tok, caches, bad)``. Non-final chunks return
        ``(None, caches, False)`` with **zero host syncs** — interleaving
        prefill chunks with decode must not stall the step pipeline; only
        the final chunk performs the one explicit admission
        ``device_get`` (token + finite guard), identical to
        :meth:`prefill_slot`.
        """
        self._check_ragged_supported()
        aslot = (None if adapter_slot is None
                 else jax.device_put(np.asarray([adapter_slot], np.int32)))
        if self.scfg.kv_layout == "paged":
            if block_table is None:
                raise ValueError("paged prefill_slot_chunk needs a "
                                 "block_table")
            last, caches = self._prefill_slot_paged(
                self.params, tokens, jax.device_put(np.int32(length)),
                jax.device_put(np.int32(start)), caches,
                jax.device_put(np.asarray(block_table, np.int32)[None]),
                aslot)
        else:
            last, caches = self._prefill_slot_chunk(
                self.params, tokens, jax.device_put(np.int32(length)),
                jax.device_put(np.int32(start)), caches,
                jax.device_put(np.int32(slot)), aslot)
        if not final:
            return None, caches, False
        tok_dev = jnp.argmax(last, axis=-1).astype(jnp.int32)
        ok_dev = jnp.all(jnp.isfinite(last))
        tok, ok = jax.device_get((tok_dev, ok_dev))  # repro: noqa[RA001] final-chunk admission sync: the first token + finite guard leave the device by design
        return int(tok), caches, not bool(ok)

    def decode_chunk(self, tok, caches, key, done, pos, n_steps: int,
                     block_tables=None, adapter_slots=None):
        """Run ``n_steps`` ragged decode steps as one compiled program.

        Args:
          tok: ``[batch_slots]`` int32 — each slot's last sampled token.
          caches: live cache tree. **Donated** — rebind to the result.
          key: PRNG key (consumed; a new one is returned in the carry).
          done: ``[batch_slots]`` bool — finished/free slots (they emit
            ``eos_id`` and, once all slots are done, the remaining steps
            skip the forward entirely on device).
          pos: ``[batch_slots]`` int32 — each slot's KV frontier (the cache
            position its next token writes).
          n_steps: chunk length; static ⇒ one compiled program per value.
          block_tables: paged only — ``[batch_slots, blocks_per_seq]``
            int32, constant across the chunk (grow tables between chunks).
          adapter_slots: ``[batch_slots]`` int32 adapter-pool indices
            (0 = base), or None when no adapter routing is active. Like
            ``block_tables`` it is constant across the chunk — the
            scheduler only swaps a slot's adapter between chunks.

        Returns ``(toks [batch_slots, n_steps], caches, key, done, pos,
        bad)`` where ``bad`` is a ``[batch_slots]`` bool mask: slots whose
        logits went non-finite at any step of the chunk (their tokens are
        garbage and must be quarantined, not emitted).
        """
        # explicit uploads (see prefill_slot): these run every chunk under
        # the transfer sanitizer's disallow guard
        aslots = (None if adapter_slots is None
                  else jax.device_put(np.asarray(adapter_slots, np.int32)))
        if self.scfg.kv_layout == "paged":
            if block_tables is None:
                raise ValueError("paged decode_chunk needs block_tables")
            return self._decode_chunk(
                self.params, tok, caches, key, done, pos,
                jax.device_put(np.asarray(block_tables, np.int32)), aslots,
                n_steps=n_steps)
        return self._decode_chunk(self.params, tok, caches, key, done, pos,
                                  None, aslots, n_steps=n_steps)

    def copy_blocks(self, caches, src, dst):
        """Copy pool blocks ``src → dst`` in every layer (copy-on-write).

        ``caches`` is donated — rebind to the returned tree."""
        return self._copy_blocks(caches, jnp.asarray(src, jnp.int32),
                                 jnp.asarray(dst, jnp.int32))

    def fill_blocks(self, caches, ids, value: float = 0.0):
        """Overwrite pool blocks ``ids`` with ``value`` in every layer.

        ``value=0.0`` scrubs quarantined pages before they return to the
        free list (a recycled page carrying NaN would poison its next
        owner through the masked-lane ``0 * NaN`` in attention); the
        fault-injection harness uses ``value=nan`` to plant a corrupted
        page. ``caches`` is donated — rebind to the returned tree."""
        if not ids:
            return caches
        return self._fill_blocks(caches, jnp.asarray(ids, jnp.int32),
                                 value=float(value))

    def _check_ragged_supported(self):
        if self.cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                f"ragged serving not supported for family "
                f"{self.cfg.family!r} (per-row state/frames)")
        if self.cfg.sliding_window > 0 or self.cfg.local_global_period > 0:
            raise NotImplementedError(
                "ragged serving not supported with sliding-window "
                "(ring-buffer) KV caches")

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, n_steps: int,
                 frames: Optional[jnp.ndarray] = None, seed: int = 0,
                 prompt_lens: Optional[jnp.ndarray] = None):
        """Generate ``n_steps`` tokens per row from a (possibly ragged)
        prompt batch.

        Args:
          prompts: ``[b, s]`` int32 token ids, right-padded to a common
            width ``s`` when rows differ in length (pad value arbitrary —
            pad positions are never sampled from).
          n_steps: tokens to generate per row; ``<= 0`` returns ``[b, 0]``.
          frames: enc-dec (whisper) only — ``[b, encoder_seq, d]`` float
            encoder-frontend frames.
          seed: PRNG seed for ``temperature > 0`` sampling (greedy decoding
            ignores it).
          prompt_lens: ``[b]`` int32 true per-row lengths, each in
            ``[1, s]``. When given, the batch is served **ragged**: row
            ``i``'s first token is sampled from ``logits[i, lens[i]-1]``
            (its own last real position, never the pad tail) and its decode
            continues from cache position ``lens[i]`` — not the padded
            width. Requires ``lens.max() + n_steps <= max_len + 1``.

        Returns ``[b, n_steps]`` int32 generated tokens. With
        ``eos_id >= 0``, rows that emit eos keep emitting it for the
        remaining steps (masked continuation — output shape stays static).

        ``ServeConfig(kv_layout="paged")`` runs the same math through a
        transient block pool (one ``max_len``-worth of pages per row) —
        token-for-token identical to the contiguous layout, on both decode
        loops; the property test in ``tests/test_paged_cache.py`` pins it.
        """
        b = prompts.shape[0]
        if n_steps <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        eos = self.scfg.eos_id
        key = jax.random.PRNGKey(seed)

        if self.scfg.kv_layout == "paged":
            return self._generate_paged(prompts, n_steps, key, prompt_lens)
        caches = init_caches(self.cfg, b, self.scfg.max_len,
                             kv_dtype=self.scfg.kv_dtype)

        if prompt_lens is not None:
            self._check_ragged_supported()
            lens = jnp.asarray(self._check_lens(prompt_lens, prompts,
                                                n_steps))
            last, caches = self._prefill_ragged(self.params, prompts, lens,
                                                caches)
        else:
            enc_out = None
            if self.cfg.family == "encdec":
                assert frames is not None
                enc_out = encode(self.params, self.cfg, frames, rt=self.rt)
                caches = prepare_cross_caches(self.params, self.cfg, enc_out,
                                              caches, rt=self.rt)
            last, caches = self._prefill(self.params, prompts, caches)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        done = (tok == eos) if eos >= 0 else jnp.zeros((b,), bool)

        if prompt_lens is not None:
            pos = lens
            if self.scfg.decode_loop == "scan":
                toks, *_ = self._decode_chunk(self.params, tok, caches, key,
                                              done, pos, n_steps=n_steps - 1)
                return jnp.concatenate([tok[:, None], toks], axis=1)
            out = [tok]
            for _ in range(n_steps - 1):
                key, sub = jax.random.split(key)
                nxt, caches = self._decode_ragged(self.params, tok, caches,
                                                  sub, pos)
                if eos >= 0:
                    nxt = jnp.where(done, jnp.int32(eos), nxt)
                    done = done | (nxt == eos)
                pos = pos + 1
                tok = nxt
                out.append(tok)
            return jnp.stack(out, axis=1)

        if self.scfg.decode_loop == "scan":
            toks, _ = self._decode_loop(self.params, tok, caches, key, done,
                                        n_steps=n_steps - 1)
            return jnp.concatenate([tok[:, None], toks], axis=1)

        out = [tok]
        for i in range(n_steps - 1):
            key, sub = jax.random.split(key)
            nxt, caches = self._decode(self.params, tok, caches, sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            tok = nxt
            out.append(tok)
        return jnp.stack(out, axis=1)

    def _check_lens(self, prompt_lens, prompts, n_steps) -> np.ndarray:
        b = prompts.shape[0]
        lens_np = np.asarray(prompt_lens, np.int32).reshape(-1)
        if lens_np.shape != (b,):
            raise ValueError(f"prompt_lens shape {lens_np.shape} != ({b},)")
        if lens_np.min() < 1 or lens_np.max() > prompts.shape[1]:
            raise ValueError(
                f"prompt_lens must be in [1, {prompts.shape[1]}] "
                f"(padded width): {lens_np}")
        if int(lens_np.max()) + n_steps > self.scfg.max_len + 1:
            raise ValueError(
                f"longest prompt ({int(lens_np.max())}) + n_steps "
                f"({n_steps}) overflows max_len ({self.scfg.max_len})")
        return lens_np

    def _generate_paged(self, prompts, n_steps, key, prompt_lens):
        """Whole-batch generation through a transient block pool.

        Row i owns pages ``[i * nb, (i+1) * nb)`` of a fresh pool (nb =
        blocks_per_seq), so the per-row gathered view has exactly the
        contiguous lane's width — which keeps this path bit-identical to
        the contiguous engine while exercising the full paged machinery.
        """
        self._check_ragged_supported()
        b = prompts.shape[0]
        eos = self.scfg.eos_id
        nb = self.scfg.blocks_per_seq
        if prompt_lens is None:
            lens_np = np.full((b,), prompts.shape[1], np.int32)
            if prompts.shape[1] + n_steps > self.scfg.max_len + 1:
                raise ValueError(
                    f"prompt ({prompts.shape[1]}) + n_steps ({n_steps}) "
                    f"overflows max_len ({self.scfg.max_len})")
        else:
            lens_np = self._check_lens(prompt_lens, prompts, n_steps)
        lens = jnp.asarray(lens_np)
        caches = init_paged_caches(self.cfg, b * nb, self.scfg.block_size,
                                   kv_dtype=self.scfg.kv_dtype)
        tables = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)

        last, caches = self._prefill_ragged(self.params, prompts, lens,
                                            caches, tables)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        done = (tok == eos) if eos >= 0 else jnp.zeros((b,), bool)
        pos = lens
        if self.scfg.decode_loop == "scan":
            toks, *_ = self._decode_chunk(self.params, tok, caches, key,
                                          done, pos, tables,
                                          n_steps=n_steps - 1)
            return jnp.concatenate([tok[:, None], toks], axis=1)
        out = [tok]
        for _ in range(n_steps - 1):
            key, sub = jax.random.split(key)
            nxt, caches = self._decode_ragged(self.params, tok, caches,
                                              sub, pos, tables)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            pos = pos + 1
            tok = nxt
            out.append(tok)
        return jnp.stack(out, axis=1)
