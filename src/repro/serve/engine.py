"""Serving engine: batched prefill + decode with KV caches.

Designed for the quantized (W4A8 + ASER compensation) model but works for fp
params identically — the ``dense`` dispatch picks the path per leaf. Requests
are padded into fixed batch slots (static shapes ⇒ one compiled program per
(batch, max_len) bucket, the standard TPU serving discipline) — but batches
do **not** have to be equal-length: ``generate(..., prompt_lens=...)`` runs a
ragged batch, sampling each row's first token from its true last prompt
position (not the pad) and decoding each row at its own cache position.

Decode runs as a device-resident ``lax.scan`` over steps: one dispatch for
the whole generation instead of one per token, with the KV caches donated
into the compiled loop so the buffers are updated in place rather than
copied every token. The per-step Python loop survives as
``decode_loop="step"`` — the debug mode whose parity with the scan path is
pinned in tests.

For continuous batching (``repro.serve.scheduler``) the engine additionally
exposes slot-level primitives: ``prefill_slot`` (single-request prefill
scattered into one row of a live batch cache) and ``decode_chunk`` (a
fixed-size ragged scan chunk carrying per-slot ``done``/``pos`` so the
scheduler can retire and backfill slots between chunks).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (KVCache, ModelConfig, encode, forward, init_caches,
                          prepare_cross_caches)
from repro.runtime import RuntimeConfig

DECODE_LOOPS = ("scan", "step")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early
    decode_loop: str = "scan"      # "scan" (device-resident) | "step" (debug)

    def __post_init__(self):
        if self.decode_loop not in DECODE_LOOPS:
            raise ValueError(f"decode_loop must be one of {DECODE_LOOPS}: "
                             f"{self.decode_loop!r}")


class Engine:
    """Per-deployment engine: holds its own :class:`RuntimeConfig`, so two
    engines in one process can serve e.g. W4A8-pallas next to W4A16-XLA
    without racing on process state. ``rt=None`` follows the process
    default runtime, read when the engine first traces — the seed
    semantics, so legacy callers that construct an Engine and *then* call
    the deprecated ``ops.set_act_bits``/``ops.use_pallas`` shims before the
    first ``generate()`` still get what they asked for."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig = ServeConfig(),
                 rt: Optional[RuntimeConfig] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt                # None → ops.default_runtime() at trace
        self._prefill = jax.jit(self._prefill_impl)
        self._prefill_ragged = jax.jit(self._prefill_ragged_impl)
        # per-token steps donate the caches too: without it every debug-loop
        # token copies the full max_len·layers KV tree
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,))
        self._decode_ragged = jax.jit(self._decode_ragged_impl,
                                      donate_argnums=(2,))
        # caches are donated: the loop updates the KV buffers in place
        # instead of copying max_len·layers of cache every token. n_steps
        # is static — one compiled program per generation-length bucket.
        self._decode_loop = jax.jit(self._decode_loop_impl,
                                    static_argnames=("n_steps",),
                                    donate_argnums=(2,))
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("n_steps",),
                                     donate_argnums=(2,))
        self._prefill_slot = jax.jit(self._prefill_slot_impl,
                                     donate_argnums=(3,))

    # -- compiled steps ----------------------------------------------------
    def _prefill_impl(self, params, tokens, caches, encoder_out=None):
        """tokens: [b, s_prompt]. Runs the prompt through, filling caches."""
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    encoder_out=encoder_out, rt=self.rt)
        return logits[:, -1], caches

    def _prefill_ragged_impl(self, params, tokens, lens, caches):
        """Ragged prefill: tokens [b, s_pad] right-padded, lens [b].

        The padded forward itself is already sound under causal attention —
        a real token at position p < len only ever attends positions ≤ p,
        all real — so the fix is where we *read*: gather each row's logits
        at its true last prompt position ``lens-1``, never the pad tail.
        Pad positions do write garbage KV beyond each row's length; ragged
        decode overwrites them positionally and masks the rest per row.
        """
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    rt=self.rt)
        b = tokens.shape[0]
        last = logits[jnp.arange(b), jnp.maximum(lens - 1, 0)]
        return last, caches

    def _sample(self, lg, key):
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(key, lg / self.scfg.temperature,
                                         axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32)

    def _decode_impl(self, params, last_tok, caches, key):
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    caches=caches, rt=self.rt)
        return self._sample(logits[:, 0], key), caches

    def _decode_ragged_impl(self, params, last_tok, caches, key, pos):
        """One ragged decode step: row i's token is at position pos[i]."""
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    positions=pos[:, None], caches=caches,
                                    ragged=True, rt=self.rt)
        return self._sample(logits[:, 0], key), caches

    def _decode_loop_impl(self, params, tok0, caches, key, done0, *,
                          n_steps: int):
        """Device-resident decode: [b] tok0 → [b, n_steps] next tokens.

        Finished slots (``done``) keep emitting ``eos_id`` and stop
        advancing their sampled continuation; once every slot is done the
        whole forward is skipped on device (``jnp.all(done)`` cond)."""
        eos = self.scfg.eos_id

        def step(carry, _):
            tok, caches, key, done = carry
            key, sub = jax.random.split(key)
            logits, new_caches, _ = forward(params, self.cfg, tok[:, None],
                                            caches=caches, rt=self.rt)
            nxt = self._sample(logits[:, 0], sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            return (nxt, new_caches, key, done), nxt

        def body(carry, _):
            if eos < 0:
                return step(carry, _)
            # early-stop: skip the whole forward once every slot finished
            return jax.lax.cond(
                jnp.all(carry[3]),
                lambda c: (c, jnp.full_like(c[0], eos)),
                lambda c: step(c, _),
                carry)

        (tok, caches, key, done), toks = jax.lax.scan(
            body, (tok0, caches, key, done0), None, length=n_steps)
        return toks.T, caches                     # [b, n_steps]

    def _decode_chunk_impl(self, params, tok0, caches, key, done0, pos0, *,
                           n_steps: int):
        """Ragged device-resident decode chunk: per-row positions.

        Carries per-slot ``pos`` (each row writes KV at its own frontier)
        next to the ``done`` mask of :meth:`_decode_loop_impl`. Returns the
        full carry so the continuous-batching scheduler can stitch chunks:
        ``(toks [b, n_steps], caches, key, done, pos)``.
        """
        eos = self.scfg.eos_id

        def step(carry, _):
            tok, caches, key, done, pos = carry
            key, sub = jax.random.split(key)
            logits, new_caches, _ = forward(params, self.cfg, tok[:, None],
                                            positions=pos[:, None],
                                            caches=caches, ragged=True,
                                            rt=self.rt)
            nxt = self._sample(logits[:, 0], sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            return (nxt, new_caches, key, done, pos + 1), nxt

        def body(carry, _):
            if eos < 0:
                return step(carry, _)
            return jax.lax.cond(
                jnp.all(carry[3]),
                lambda c: (c, jnp.full_like(c[0], eos)),
                lambda c: step(c, _),
                carry)

        carry, toks = jax.lax.scan(
            body, (tok0, caches, key, done0, pos0), None, length=n_steps)
        tok, caches, key, done, pos = carry
        return toks.T, caches, key, done, pos     # toks: [b, n_steps]

    def _prefill_slot_impl(self, params, tokens, length, caches, slot):
        """Single-request prefill into one slot of a live batch cache.

        tokens: [1, s_bucket] right-padded; ``length``/``slot`` traced
        scalars. Runs a b=1 prefill against fresh caches, then scatters the
        resulting KV rows into ``caches`` at ``slot`` — the other slots'
        cached state is untouched, which is what lets the scheduler backfill
        a retired slot while its neighbours keep decoding.
        """
        one = init_caches(self.cfg, 1, self.scfg.max_len)
        logits, one, _ = forward(params, self.cfg, tokens, caches=one,
                                 rt=self.rt)
        last = logits[0, jnp.maximum(length - 1, 0)]

        def put(bc, oc):
            if not isinstance(bc, KVCache):
                return bc          # SSM caches are gated out of ragged mode
            ax = bc.k.ndim - 4     # batch axis (scanned groups lead with G)
            return KVCache(
                jax.lax.dynamic_update_slice_in_dim(
                    bc.k, oc.k.astype(bc.k.dtype), slot, axis=ax),
                jax.lax.dynamic_update_slice_in_dim(
                    bc.v, oc.v.astype(bc.v.dtype), slot, axis=ax),
                bc.length, bc.pos)

        caches = jax.tree.map(put, caches, one,
                              is_leaf=lambda x: isinstance(x, KVCache))
        return last, caches

    # -- scheduler-facing API ---------------------------------------------
    def new_caches(self):
        """Fresh batch caches sized to this engine's slots/max_len."""
        return init_caches(self.cfg, self.scfg.batch_slots, self.scfg.max_len)

    def prefill_slot(self, tokens, length, caches, slot):
        """Prefill one request into ``slot``; returns (next_tok, caches).

        ``caches`` is donated — rebind to the returned tree."""
        self._check_ragged_supported()
        last, caches = self._prefill_slot(
            self.params, tokens, jnp.asarray(length, jnp.int32), caches,
            jnp.asarray(slot, jnp.int32))
        return jnp.argmax(last, axis=-1).astype(jnp.int32), caches

    def decode_chunk(self, tok, caches, key, done, pos, n_steps: int):
        """Run one ragged decode chunk; caches are donated."""
        return self._decode_chunk(self.params, tok, caches, key, done, pos,
                                  n_steps=n_steps)

    def _check_ragged_supported(self):
        if self.cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                f"ragged serving not supported for family "
                f"{self.cfg.family!r} (per-row state/frames)")
        if self.cfg.sliding_window > 0 or self.cfg.local_global_period > 0:
            raise NotImplementedError(
                "ragged serving not supported with sliding-window "
                "(ring-buffer) KV caches")

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, n_steps: int,
                 frames: Optional[jnp.ndarray] = None, seed: int = 0,
                 prompt_lens: Optional[jnp.ndarray] = None):
        """prompts: [b, s]. Returns generated tokens [b, n_steps].

        ``prompt_lens`` [b] serves a ragged batch: prompts are right-padded
        to a common width, each row's first token is sampled from its own
        last real position and its decode continues from ``prompt_lens[i]``
        — not the padded width.

        With ``eos_id >= 0``, slots that emit eos keep emitting it for the
        remaining steps (masked continuation) — output shape stays static.
        """
        b = prompts.shape[0]
        if n_steps <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        eos = self.scfg.eos_id
        caches = init_caches(self.cfg, b, self.scfg.max_len)
        key = jax.random.PRNGKey(seed)

        if prompt_lens is not None:
            self._check_ragged_supported()
            lens_np = np.asarray(prompt_lens, np.int32).reshape(-1)
            if lens_np.shape != (b,):
                raise ValueError(f"prompt_lens shape {lens_np.shape} != "
                                 f"({b},)")
            if lens_np.min() < 1 or lens_np.max() > prompts.shape[1]:
                raise ValueError(
                    f"prompt_lens must be in [1, {prompts.shape[1]}] "
                    f"(padded width): {lens_np}")
            if int(lens_np.max()) + n_steps > self.scfg.max_len + 1:
                raise ValueError(
                    f"longest prompt ({int(lens_np.max())}) + n_steps "
                    f"({n_steps}) overflows max_len ({self.scfg.max_len})")
            lens = jnp.asarray(lens_np)
            last, caches = self._prefill_ragged(self.params, prompts, lens,
                                                caches)
        else:
            enc_out = None
            if self.cfg.family == "encdec":
                assert frames is not None
                enc_out = encode(self.params, self.cfg, frames, rt=self.rt)
                caches = prepare_cross_caches(self.params, self.cfg, enc_out,
                                              caches, rt=self.rt)
            last, caches = self._prefill(self.params, prompts, caches)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        done = (tok == eos) if eos >= 0 else jnp.zeros((b,), bool)

        if prompt_lens is not None:
            pos = lens
            if self.scfg.decode_loop == "scan":
                toks, *_ = self._decode_chunk(self.params, tok, caches, key,
                                              done, pos, n_steps=n_steps - 1)
                return jnp.concatenate([tok[:, None], toks], axis=1)
            out = [tok]
            for _ in range(n_steps - 1):
                key, sub = jax.random.split(key)
                nxt, caches = self._decode_ragged(self.params, tok, caches,
                                                  sub, pos)
                if eos >= 0:
                    nxt = jnp.where(done, jnp.int32(eos), nxt)
                    done = done | (nxt == eos)
                pos = pos + 1
                tok = nxt
                out.append(tok)
            return jnp.stack(out, axis=1)

        if self.scfg.decode_loop == "scan":
            toks, _ = self._decode_loop(self.params, tok, caches, key, done,
                                        n_steps=n_steps - 1)
            return jnp.concatenate([tok[:, None], toks], axis=1)

        out = [tok]
        for i in range(n_steps - 1):
            key, sub = jax.random.split(key)
            nxt, caches = self._decode(self.params, tok, caches, sub)
            if eos >= 0:
                nxt = jnp.where(done, jnp.int32(eos), nxt)
                done = done | (nxt == eos)
            tok = nxt
            out.append(tok)
        return jnp.stack(out, axis=1)
