"""Serving engine: batched prefill + decode with KV caches.

Designed for the quantized (W4A8 + ASER compensation) model but works for fp
params identically — the ``dense`` dispatch picks the path per leaf. Requests
are padded into fixed batch slots (static shapes ⇒ one compiled program per
(batch, max_len) bucket, the standard TPU serving discipline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ModelConfig, encode, forward, init_caches,
                          prepare_cross_caches)
from repro.runtime import RuntimeConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    batch_slots: int = 8
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early


class Engine:
    """Per-deployment engine: holds its own :class:`RuntimeConfig`, so two
    engines in one process can serve e.g. W4A8-pallas next to W4A16-XLA
    without racing on process state. ``rt=None`` follows the process
    default runtime, read when the engine first traces — the seed
    semantics, so legacy callers that construct an Engine and *then* call
    the deprecated ``ops.set_act_bits``/``ops.use_pallas`` shims before the
    first ``generate()`` still get what they asked for."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig = ServeConfig(),
                 rt: Optional[RuntimeConfig] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt                # None → ops.default_runtime() at trace
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # -- compiled steps ----------------------------------------------------
    def _prefill_impl(self, params, tokens, caches, encoder_out=None):
        """tokens: [b, s_prompt]. Runs the prompt through, filling caches."""
        logits, caches, _ = forward(params, self.cfg, tokens, caches=caches,
                                    encoder_out=encoder_out, rt=self.rt)
        return logits[:, -1], caches

    def _decode_impl(self, params, last_tok, caches, key):
        logits, caches, _ = forward(params, self.cfg, last_tok[:, None],
                                    caches=caches, rt=self.rt)
        lg = logits[:, 0]
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(key, lg / self.scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32), caches

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, n_steps: int,
                 frames: Optional[jnp.ndarray] = None, seed: int = 0):
        """prompts: [b, s]. Returns generated tokens [b, n_steps]."""
        b = prompts.shape[0]
        caches = init_caches(self.cfg, b, self.scfg.max_len)
        enc_out = None
        if self.cfg.family == "encdec":
            assert frames is not None
            enc_out = encode(self.params, self.cfg, frames, rt=self.rt)
            caches = prepare_cross_caches(self.params, self.cfg, enc_out,
                                          caches, rt=self.rt)
        last, caches = self._prefill(self.params, prompts, caches)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        out = [tok]
        key = jax.random.PRNGKey(seed)
        for i in range(n_steps - 1):
            key, sub = jax.random.split(key)
            tok, caches = self._decode(self.params, tok, caches, sub)
            out.append(tok)
        return jnp.stack(out, axis=1)
