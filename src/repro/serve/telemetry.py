"""Per-request serving latency telemetry: timestamps + exact percentiles.

Production serving is judged on TTFT/TPOT *tails*, not goodput averages —
a single head-of-line-blocking prefill is invisible in tokens/sec and
glaring at p99. This module is the measurement half of the chunked-prefill
work: the scheduler stamps every request's :class:`RequestTiming` against
its injectable ``clock`` (the same one deadlines use, so deterministic
tests drive both), and :func:`latency_summary` reduces a drained run to
the p50/p95/p99 numbers ``serve_bench/v7`` reports.

Percentiles are **exact** (sort + nearest-rank), never interpolated or
approximated: the sample sets here are at most thousands of requests, and
an approximate quantile sketch would let a pathological tail hide inside
its error bound — the exact rank statistic is the whole point of the
measurement. ``percentile`` raises on empty samples and non-finite values
instead of guessing; a NaN timing is a stamping bug upstream, not a data
point.

Definitions (matching vLLM / industry convention):

* **TTFT** — ``first_token_at - submitted_at``: queueing + (possibly
  chunked) prefill + first sample. Measured from *submit*, not admission,
  so admission-queue waits count — that is the number an SLO bounds.
* **TPOT** — ``(last_token_at - first_token_at) / (n_tokens - 1)``: mean
  inter-token time over the decode phase. Requests with fewer than two
  tokens have no inter-token gap and are excluded from the TPOT sample
  (not counted as zero, which would drag the tail down artificially).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = ["RequestTiming", "percentile", "percentiles", "latency_summary"]


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile of ``values``.

    ``q`` in [0, 100]. Sorts a copy and returns the element at rank
    ``ceil(q/100 * n)`` (1-indexed; q=0 returns the minimum) — the
    classic nearest-rank definition, so the result is always an actual
    observed sample, never an interpolation between two.

    Raises ``ValueError`` on an empty sample, a non-finite value (NaN or
    inf is a measurement bug, not a latency), or ``q`` outside [0, 100].
    """
    vals: List[float] = [float(v) for v in values]
    if not vals:
        raise ValueError("percentile of an empty sample")
    if not 0.0 <= float(q) <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100]: {q}")
    for v in vals:
        if not math.isfinite(v):
            raise ValueError(f"non-finite value in percentile sample: {v!r}")
    vals.sort()
    rank = math.ceil(float(q) / 100.0 * len(vals))
    return vals[max(rank - 1, 0)]


def percentiles(values: Sequence[float],
                qs: Sequence[float] = (50, 95, 99)) -> dict:
    """``{"p50": ..., "p95": ..., "p99": ...}`` via :func:`percentile`.

    One sort for all ranks; same raising behaviour as :func:`percentile`.
    """
    vals = sorted(float(v) for v in values)
    out = {}
    for q in qs:
        out[f"p{q:g}"] = percentile(vals, q)
    return out


@dataclasses.dataclass
class RequestTiming:
    """One request's latency trace, stamped by the scheduler's clock.

    All timestamps are in the scheduler clock's units (seconds for the
    default ``time.monotonic``); ``None`` means the event has not happened
    (yet, or ever — a rejected request never gets ``first_token_at``).

    ``prefill_chunks`` records the completion time of every prefill chunk
    the request's admission ran (a single entry for one-shot prefill);
    ``token_events`` records ``(time, cumulative_tokens)`` after every
    chunk that appended tokens, which is what TPOT is derived from.
    """

    submitted_at: Optional[float] = None
    admitted_at: Optional[float] = None      # first slot claim
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None      # any terminal status
    prefill_chunks: List[float] = dataclasses.field(default_factory=list)
    token_events: List[Tuple[float, int]] = \
        dataclasses.field(default_factory=list)

    def ttft(self) -> Optional[float]:
        """Submit → first token, or None if no token was ever emitted."""
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at

    def tpot(self) -> Optional[float]:
        """Mean inter-token time over the decode phase, or None when the
        request produced fewer than two tokens (no inter-token gap
        exists — excluded from the sample, not zero)."""
        if self.first_token_at is None or not self.token_events:
            return None
        t_last, n_last = self.token_events[-1]
        if n_last < 2:
            return None
        return (t_last - self.first_token_at) / (n_last - 1)


def latency_summary(timings: Iterable[RequestTiming],
                    qs: Sequence[float] = (50, 95, 99)) -> dict:
    """Reduce a run's timings to TTFT/TPOT percentiles (milliseconds).

    Returns ``{"n_ttft": ..., "n_tpot": ..., "ttft_ms": {"p50": ...},
    "tpot_ms": {...}}``. Requests that never emitted a token contribute to
    neither sample; single-token requests contribute TTFT only. Raises
    ``ValueError`` when a sample is empty — summarizing a run in which
    nothing generated is a harness bug, not a zero.
    """
    ttft = []
    tpot = []
    for t in timings:
        v = t.ttft()
        if v is not None:
            ttft.append(v * 1e3)
        v = t.tpot()
        if v is not None:
            tpot.append(v * 1e3)
    if not ttft:
        raise ValueError("latency_summary: no request ever emitted a token")
    if not tpot:
        raise ValueError("latency_summary: no request emitted two tokens "
                         "(TPOT sample empty)")
    return {"n_ttft": len(ttft), "n_tpot": len(tpot),
            "ttft_ms": percentiles(ttft, qs),
            "tpot_ms": percentiles(tpot, qs)}
