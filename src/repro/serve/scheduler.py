"""Continuous-batching scheduler over :class:`repro.serve.engine.Engine`.

The engine owns the compiled programs; the scheduler owns request
lifecycle. Requests queue up via :meth:`Scheduler.submit` and are admitted
into free slots with a **per-slot prefill**, so admitting a new request
never disturbs the slots that are mid-generation. Decode then runs in
fixed-size chunks through the engine's donated ragged ``lax.scan``
(``Engine.decode_chunk``), carrying per-slot ``done``/``pos`` across chunks.
Between chunks the scheduler retires slots that hit EOS or their
``max_new_tokens`` budget and immediately backfills them from the queue —
one long request no longer holds ``batch_slots - 1`` finished neighbours
hostage, which is where the goodput win over static batching comes from
(``benchmarks/serve_bench.py --mode continuous``).

With a **paged** engine (``ServeConfig(kv_layout="paged")``) the fixed
per-slot cache lanes disappear: the scheduler owns a
:class:`repro.serve.paged_cache.BlockPool` and admits on *pages*, not
slots —

* **admission** allocates exactly the pages a prompt needs (instead of
  reserving a worst-case ``max_len`` lane), and stops only when the pool
  (minus what the prefix cache can evict) is exhausted;
* **prefix reuse**: prompts are matched block-wise against the ref-counted
  prefix index, so requests sharing a system prompt / few-shot header map
  to the *same* physical pages and skip re-prefilling them (the
  ``prefix_hit_rate`` the benchmark reports); a fully-cached prompt
  copy-on-writes its last shared page before re-prefilling just the final
  token for its logits;
* **decode** allocates pages lazily, one chunk ahead; on exhaustion the
  newest active request is **preempted to the queue** (its pages freed,
  its prompt + generated tokens re-queued at the front) rather than
  wedging the batch;
* **retire** frees pages immediately; pages the prefix index knows stay
  resident as evictable cache, so a retired prompt's prefix is still a hit
  for the next request.

Results stream: ``submit`` returns a :class:`RequestHandle` whose ``poll()``
yields the token delta generated since the last poll, so callers can
stream partial generations while the batch keeps running.

**Request lifecycle** (``serve.lifecycle``): every handle walks an explicit
status machine and always reaches a terminal status —

* **load shedding**: ``queue_cap`` bounds the admission queue; a submit
  over the cap (or one whose prompt + budget can never fit the engine)
  returns immediately with status ``REJECTED`` instead of growing the
  queue without bound or hanging ``run()`` forever;
* **deadlines**: per-request (or scheduler-default) TTFT and total
  deadlines are enforced at every chunk boundary against an injectable
  ``clock`` — expired requests terminate as ``TIMED_OUT`` with their
  partial tokens intact;
* **cancel**: ``handle.cancel()`` tears the request down at the next
  chunk boundary (``CANCELLED``);
* **numeric guard**: the engine flags any slot whose logits went
  non-finite during a chunk. The scheduler quarantines *only that slot* —
  its pages are dropped from the prefix index, scrubbed, and freed; the
  request retries from its last good token (token-exact, greedy) — and
  the first detection one-shot-falls-back the engine from the fused
  Pallas kernels to the reference path
  (``Engine.activate_reference_fallback``). Retries are bounded
  (``max_fault_retries``); exhaustion terminates the request ``FAILED``;
* **device faults**: a failed decode dispatch preempts every active
  request back to the queue (their resume is token-exact via the
  re-prefill machinery) under the same bounded-retry accounting;
* **no-progress detector**: if the queue is non-empty but nothing can be
  admitted for ``stall_limit`` consecutive steps (and nothing is
  decoding), the head-of-queue request is failed instead of spinning
  ``run()`` forever;
* **snapshot/restore**: :meth:`snapshot` serializes the queue and every
  in-flight request as host state (prompt + tokens so far — in-flight
  requests are snapshotted *as preempted*, so restore replays the
  existing re-prefill machinery and resumes token-exactly);
  :meth:`restore` rebuilds the queue in a fresh scheduler. Round-trips
  through :class:`repro.checkpoint.manager.CheckpointManager`.

**Chunked prefill + token-budgeted steps**
(``ServeConfig(prefill_chunk=N, step_token_budget=B)``): one-shot
admission prefills the whole prompt inside ``_admit`` — a long prompt
head-of-line-blocks every in-flight decode for its full prefill. With
``prefill_chunk > 0`` admission becomes a host-only *claim* (slot, pages,
adapter ref — no device work), and the prompt then prefills
``prefill_chunk`` tokens at a time through
``Engine.prefill_slot_chunk``, round-robin across claiming slots in
admission order and interleaved with the decode chunk, so a short
prompt's first token is never stuck behind a long prompt's prefill. With
``step_token_budget > 0`` each ``step()`` spends at most that many
tokens: the decode chunk's ``chunk_size × n_decoding`` is reserved first
(decodes are never starved by prefill), and the remainder is dealt to
pending prefill chunks. No token is sampled until the final chunk;
intermediate chunks perform **zero host syncs**. Cancellation and the
TTFT/total deadlines are enforced *between chunks* — with one-shot
prefill a long prompt could sail past its ``ttft_ms`` inside one
admission call. Prefix registration is deferred to prefill completion
(a partial page chain must never be a prefix hit), and a mid-prefill
request preempts / quarantines / snapshots exactly like a decoding one
(no tokens yet ⇒ resume is a plain re-prefill). Per-request latency is
stamped against ``clock`` into ``handle.timing``
(:class:`repro.serve.telemetry.RequestTiming`) in both modes — TTFT/TPOT
percentiles for a drained run come from
:func:`repro.serve.telemetry.latency_summary`.

Fault injection (``serve.faults.FaultInjector``) hooks the same seams the
real failures use, so the chaos suite drives every one of these paths
deterministically.

Chunk-size tradeoff: each chunk is one device dispatch, so large chunks
amortize dispatch overhead, but a slot can only be retired/backfilled at a
chunk boundary — up to ``chunk_size - 1`` wasted slot-steps per retirement.
Small chunks react faster at more dispatches. The default (8) favors
responsiveness at smoke scales; production TPU deployments want it nearer
the dispatch/step-cost break-even from ``BENCH_serve.json``.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .adapters import BASE_SLOT, AdapterPool
from .engine import Engine
from .faults import DeviceStepFault
from .lifecycle import (Request, RequestHandle, RequestStatus,
                        TERMINAL_STATUSES)
from .paged_cache import BlockPool

__all__ = ["Scheduler", "Request", "RequestHandle", "RequestStatus",
           "TERMINAL_STATUSES"]


def _bucket(n: int, cap: int, lo: int = 8) -> int:
    """Next power-of-two width ≥ n (≥ lo, ≤ cap): bounds slot-prefill
    recompiles to log2(max_len) buckets."""
    w = lo
    while w < n:
        w *= 2
    return min(w, cap)


class Scheduler:
    """Admit → decode-in-chunks → retire → backfill, over the engine's slots.

    Host-side state is numpy (`tok`/`pos`/`done` per slot plus, in paged
    mode, the block tables and pool refcounts — a few hundred bytes); the
    KV cache tree stays device-resident and is donated through every
    prefill/chunk, so the scheduler adds one small host transfer per chunk
    (the sampled tokens) and nothing per token.

    ``prefix_reuse`` (paged engines only) enables the block-granular
    prefix cache; it changes which pages hold a prompt's KV but never the
    tokens generated.

    ``adapters`` (an :class:`repro.serve.adapters.AdapterRegistry`, against
    an engine whose params carry installed factor pools) turns on
    multi-tenant LoRA serving: ``submit(..., adapter_id=...)`` routes a
    request through its adapter's factors. Admission then accounts adapter
    pool slots alongside KV pages — an :class:`AdapterPool` ref-counts
    residency, loads factors on a miss (LRU-evicting an idle adapter), and
    a request whose adapter cannot get a slot waits in the queue exactly
    like one the KV pool cannot admit. Prefix caching stays correct across
    tenants because each adapter salts its hash chains (an adapter rewrites
    the K/V projections, so identical tokens do *not* share KV across
    adapters).

    Robustness knobs (all keyword-only):

    * ``queue_cap`` — bound on the admission queue; submits over it are
      shed with status ``REJECTED``. Preemptions may transiently push the
      queue past the cap (they re-queue work that was already admitted).
    * ``ttft_ms`` / ``deadline_ms`` — default first-token / total
      deadlines applied to every request that doesn't override them at
      ``submit``; enforced at chunk boundaries against ``clock``.
    * ``clock`` — monotonic-seconds source (injectable for deterministic
      deadline tests; defaults to ``time.monotonic``).
    * ``faults`` — a :class:`repro.serve.faults.FaultInjector` attached to
      the scheduler's fault seams (chaos testing).
    * ``max_fault_retries`` — quarantine/device-fault retries per request
      before it terminates ``FAILED``.
    * ``stall_limit`` — consecutive no-progress steps before the
      head-of-queue request is failed instead of spinning forever.
    """

    def __init__(self, engine: Engine, chunk_size: int = 8, seed: int = 0,
                 prefix_reuse: bool = True, adapters=None,
                 adapter_pool: Optional[AdapterPool] = None, *,
                 queue_cap: Optional[int] = None,
                 ttft_ms: Optional[float] = None,
                 deadline_ms: Optional[float] = None,
                 clock=time.monotonic,
                 faults=None,
                 max_fault_retries: int = 3,
                 stall_limit: int = 64):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1: {queue_cap}")
        if stall_limit < 1:
            raise ValueError(f"stall_limit must be >= 1: {stall_limit}")
        engine._check_ragged_supported()
        self.engine = engine
        self.chunk_size = chunk_size
        self.slots = engine.scfg.batch_slots
        self.max_len = engine.scfg.max_len
        self.eos_id = engine.scfg.eos_id
        self.paged = engine.scfg.kv_layout == "paged"
        # -- chunked prefill / token budget ---------------------------------
        self.prefill_chunk = engine.scfg.prefill_chunk
        self.step_token_budget = engine.scfg.step_token_budget
        if self.step_token_budget and chunk_size > self.step_token_budget:
            raise ValueError(
                f"chunk_size ({chunk_size}) exceeds step_token_budget "
                f"({self.step_token_budget}): one decode chunk alone would "
                f"blow the per-step budget")
        if self.step_token_budget and \
                self.prefill_chunk + chunk_size > self.step_token_budget:
            raise ValueError(
                f"prefill_chunk + chunk_size "
                f"({self.prefill_chunk} + {chunk_size}) exceeds "
                f"step_token_budget ({self.step_token_budget}): a final "
                f"prefill chunk joins the same step's decode, so both "
                f"must fit the budget together or the prefill can stall")
        # per-slot resumable-prefill state (all idle when prefill_chunk=0):
        # the effective prompt being prefilled (None = not prefilling), the
        # next absolute position to write, the prompt length, and the
        # deferred prefix registration (prompt, blocks, salt) applied only
        # once the final chunk lands — a partial chain must never be a hit
        self._prefill_prompt: List[Optional[np.ndarray]] = [None] * self.slots
        self._prefill_pos = np.zeros((self.slots,), np.int64)
        self._prefill_len = np.zeros((self.slots,), np.int64)
        self._prefill_register: List[Optional[tuple]] = [None] * self.slots
        self.prefill_chunks_run = 0
        self.tokens_spent = 0         # cumulative device tokens, all steps
        self.last_step_tokens = 0     # tokens the most recent step() spent
        self._caches = engine.new_caches()
        self._key = jax.random.PRNGKey(seed)
        self._queue: Deque[RequestHandle] = deque()
        self._slot_handle: List[Optional[RequestHandle]] = [None] * self.slots
        self._tok = np.zeros((self.slots,), np.int32)
        self._pos = np.zeros((self.slots,), np.int32)
        self._done = np.ones((self.slots,), bool)      # free slots are "done"
        self._next_rid = 0
        self.chunks_run = 0
        self.steps_run = 0
        # -- lifecycle state ------------------------------------------------
        self.queue_cap = queue_cap
        self.default_ttft_ms = ttft_ms
        self.default_deadline_ms = deadline_ms
        self._clock = clock
        self.max_fault_retries = max_fault_retries
        self.stall_limit = stall_limit
        self._live_handles: set = set()    # submitted, not yet terminal
        self._stall_steps = 0
        self._admitted_this_step = 0
        self.completed = 0
        self.rejected = 0
        self.cancelled = 0
        self.timed_out = 0
        self.failed = 0
        self.quarantines = 0
        self.device_faults = 0
        self.kernel_fallbacks = 0
        # -- paged state ----------------------------------------------------
        self.prefix_reuse = prefix_reuse and self.paged
        if self.paged:
            scfg = engine.scfg
            self.pool = BlockPool(scfg.pool_blocks, scfg.block_size)
            self._bs = scfg.block_size
            self._nbr = scfg.blocks_per_seq
            self._tables = np.full((self.slots, self._nbr),
                                   self.pool.sentinel, np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in range(self.slots)]
            self._admit_seq = np.zeros((self.slots,), np.int64)
            self._seq_counter = 0
        # -- adapter state --------------------------------------------------
        self._adapters = adapters           # AdapterRegistry or None
        self.apool: Optional[AdapterPool] = None
        self._aslot = np.zeros((self.slots,), np.int32)   # BASE_SLOT lanes
        self.adapter_loads = 0
        # per-adapter prefix telemetry: id -> [shared_tokens, prompt_tokens]
        self._adapter_prefix: Dict[Optional[str], List[int]] = {}
        if adapter_pool is not None and adapters is None:
            raise ValueError("adapter_pool without an adapter registry")
        if adapters is not None:
            n = engine.adapter_slots
            if n < 2:
                raise ValueError(
                    "adapter registry given but the engine's params carry "
                    "no factor pools — quantize with install_pools first")
            if adapter_pool is not None and adapter_pool.num_slots != n:
                raise ValueError(
                    f"adapter_pool has {adapter_pool.num_slots} slots but "
                    f"the engine's params carry {n}")
            # a shared pool outlives this scheduler: its residency map
            # mirrors the *engine's* device pools, so a restarted scheduler
            # (or several schedulers over one engine) skips reloading
            # factors that are already resident
            self.apool = adapter_pool or AdapterPool(n)
        # prefix-cache telemetry (all zeros for contiguous engines)
        self.prompt_tokens = 0      # Σ effective prompt lengths admitted
        self.shared_tokens = 0      # Σ prompt tokens served from cached pages
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.preemptions = 0
        self.cow_copies = 0
        # -- fault injection ------------------------------------------------
        self._faults = faults
        if faults is not None:
            faults.attach(self)

    # -- submission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               adapter_id: Optional[str] = None, *,
               ttft_ms: Optional[float] = None,
               deadline_ms: Optional[float] = None) -> RequestHandle:
        """Queue one generation request.

        Args:
          prompt: non-empty 1-D sequence of int token ids (any integer
            array-like; stored as int32). Not padded — the scheduler
            buckets it internally.
          max_new_tokens: generation budget, ``>= 1``. The request retires
            at EOS (when the engine's ``eos_id >= 0``) or after exactly
            this many tokens, whichever comes first.
          adapter_id: route this request through a registered adapter's
            factors (requires the scheduler's ``adapters`` registry); None
            serves the quantized base model.
          ttft_ms: deadline to the FIRST token, milliseconds from submit
            (None = the scheduler's ``ttft_ms`` default, which may itself
            be None = no TTFT deadline).
          deadline_ms: total deadline, milliseconds from submit (None =
            scheduler default). Both are enforced at chunk boundaries.

        Returns a :class:`RequestHandle` immediately — generation happens
        during subsequent :meth:`step` / :meth:`run` calls; stream tokens
        off the handle with ``poll()`` and read the terminal outcome off
        ``handle.status``.

        Malformed input (empty prompt, non-positive budget, unknown
        adapter) raises ``ValueError`` — a caller bug. *Capacity* is a
        load condition, not a bug: a request that can never fit the engine
        (``len(prompt) + max_new_tokens > max_len``) or that arrives while
        the queue is at ``queue_cap`` is **shed** — the returned handle is
        already terminal with status ``REJECTED`` and ``error`` says why.
        Shedding instead of raising keeps one overloaded/oversized request
        from ever wedging ``run()`` into the no-progress spin the old
        scheduler suffered.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1: {max_new_tokens}")
        if adapter_id is not None:
            if self._adapters is None:
                raise ValueError(
                    f"adapter_id {adapter_id!r} but this scheduler has no "
                    f"adapter registry")
            if adapter_id not in self._adapters.ids():
                raise ValueError(f"unknown adapter {adapter_id!r}")
        handle = RequestHandle(Request(
            self._next_rid, prompt, max_new_tokens, adapter_id,
            ttft_ms if ttft_ms is not None else self.default_ttft_ms,
            deadline_ms if deadline_ms is not None
            else self.default_deadline_ms))
        handle._stats_fn = lambda aid=adapter_id: self._request_stats(aid)
        handle.submitted_at = self._clock()
        handle.timing.submitted_at = handle.submitted_at
        self._next_rid += 1
        # capacity validation: reject-with-status, never enqueue-and-hang
        if prompt.size + max_new_tokens > self.max_len:
            handle._finish(RequestStatus.REJECTED,
                           f"prompt ({prompt.size}) + max_new_tokens "
                           f"({max_new_tokens}) exceeds max_len "
                           f"({self.max_len})")
            self.rejected += 1
            return handle
        if self.paged:
            need = -(-(prompt.size + max_new_tokens) // self._bs)
            if need > self.pool.num_blocks:
                handle._finish(RequestStatus.REJECTED,
                               f"request needs {need} pages but the whole "
                               f"pool holds {self.pool.num_blocks}")
                self.rejected += 1
                return handle
        if self.queue_cap is not None and len(self._queue) >= self.queue_cap:
            handle._finish(RequestStatus.REJECTED,
                           f"admission queue at capacity "
                           f"({self.queue_cap}): load shed")
            self.rejected += 1
            return handle
        self._live_handles.add(handle)
        self._queue.append(handle)
        return handle

    @property
    def pending(self) -> int:
        """Requests queued or occupying a slot."""
        return len(self._queue) + sum(h is not None
                                      for h in self._slot_handle)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cached pages."""
        return self.shared_tokens / self.prompt_tokens \
            if self.prompt_tokens else 0.0

    def adapter_prefix_hit_rate(self, adapter_id: Optional[str] = None
                                ) -> float:
        """Per-adapter prefix hit rate (None = base traffic). Adapters only
        ever share prefixes with themselves (salted hash chains), so this
        is the number the benchmark reports per tenant."""
        st = self._adapter_prefix.get(adapter_id)
        return st[0] / st[1] if st and st[1] else 0.0

    def adapter_stats(self) -> dict:
        """Adapter-pool telemetry snapshot (zeros when adapter-free)."""
        out = {"adapter_loads": self.adapter_loads}
        if self.apool is not None:
            out.update(self.apool.stats())
        else:
            out.update({"capacity": 0, "resident": 0, "live": 0,
                        "occupancy": 0.0, "hits": 0, "misses": 0,
                        "evictions": 0})
        return out

    def lifecycle_stats(self) -> dict:
        """Terminal-outcome and fault-recovery counters."""
        return {"completed": self.completed, "rejected": self.rejected,
                "cancelled": self.cancelled, "timed_out": self.timed_out,
                "failed": self.failed, "preemptions": self.preemptions,
                "quarantines": self.quarantines,
                "device_faults": self.device_faults,
                "kernel_fallbacks": self.kernel_fallbacks}

    def _request_stats(self, adapter_id: Optional[str]) -> dict:
        stats = {"adapter_id": adapter_id,
                 "adapter_prefix_hit_rate":
                     self.adapter_prefix_hit_rate(adapter_id)}
        stats.update(self.adapter_stats())
        return stats

    # -- lifecycle transitions ---------------------------------------------
    def _finish(self, handle: RequestHandle, status: RequestStatus,
                error: Optional[str] = None):
        """Terminal transition + outcome accounting."""
        handle._finish(status, error)
        if handle.timing.finished_at is None:
            handle.timing.finished_at = self._clock()
        self._live_handles.discard(handle)
        if status == RequestStatus.COMPLETED:
            self.completed += 1
        elif status == RequestStatus.CANCELLED:
            self.cancelled += 1
        elif status == RequestStatus.TIMED_OUT:
            self.timed_out += 1
        elif status == RequestStatus.FAILED:
            self.failed += 1
        elif status == RequestStatus.REJECTED:   # pragma: no cover
            self.rejected += 1                   # (rejects finish in submit)

    def _expiry(self, handle: RequestHandle, now: float) -> Optional[str]:
        """Which deadline (if any) ``handle`` has missed at time ``now``."""
        req = handle.request
        elapsed_ms = (now - handle.submitted_at) * 1e3
        if req.deadline_ms is not None and elapsed_ms > req.deadline_ms:
            return (f"total deadline {req.deadline_ms:g} ms missed "
                    f"({elapsed_ms:.0f} ms elapsed)")
        if not handle.tokens and req.ttft_ms is not None \
                and elapsed_ms > req.ttft_ms:
            return (f"TTFT deadline {req.ttft_ms:g} ms missed "
                    f"({elapsed_ms:.0f} ms elapsed, no token yet)")
        return None

    def _sweep(self):
        """Chunk-boundary lifecycle sweep: cancellations and deadlines,
        queued and running alike."""
        now = self._clock()
        if self._queue:
            kept: Deque[RequestHandle] = deque()
            for handle in self._queue:
                if handle._cancel_requested:
                    self._finish(handle, RequestStatus.CANCELLED)
                    continue
                why = self._expiry(handle, now)
                if why is not None:
                    self._finish(handle, RequestStatus.TIMED_OUT, why)
                    continue
                kept.append(handle)
            self._queue = kept
        for slot in range(self.slots):
            handle = self._slot_handle[slot]
            if handle is None:
                continue
            if handle._cancel_requested:
                self._release_slot(slot)
                self._finish(handle, RequestStatus.CANCELLED)
                continue
            why = self._expiry(handle, now)
            if why is not None:
                self._release_slot(slot)
                self._finish(handle, RequestStatus.TIMED_OUT, why)

    def _requeue_or_fail(self, handle: RequestHandle, reason: str):
        """Bounded-retry recovery: the request resumes token-exactly from
        its last good token (front of queue), unless its fault budget is
        spent — then it terminates ``FAILED``."""
        handle.fault_retries += 1
        if handle.fault_retries > self.max_fault_retries:
            self._finish(handle, RequestStatus.FAILED,
                         f"{reason} ({handle.fault_retries - 1} retries "
                         f"exhausted)")
            return
        handle.status = RequestStatus.QUEUED
        self._queue.appendleft(handle)

    def _note_fallback(self):
        """One-shot fused-kernel → reference-path fallback on the first
        non-finite detection (no-op once flipped or already on XLA)."""
        if self.engine.activate_reference_fallback():
            self.kernel_fallbacks += 1

    # -- adapter residency -------------------------------------------------
    @staticmethod
    def _salt(adapter_id: Optional[str]) -> bytes:
        """Prefix-hash salt: adapters never share KV with each other or
        with the base (their K/V projections differ)."""
        return f"adapter:{adapter_id}".encode() \
            if adapter_id is not None else b""

    def _acquire_adapter(self, adapter_id: Optional[str]) -> Optional[int]:
        """Resolve a request's adapter to a pool slot, loading factors on a
        miss. Returns the slot (``BASE_SLOT`` for base requests), or None
        when every slot is pinned by live requests — the caller leaves the
        request queued, exactly like KV-page exhaustion."""
        if adapter_id is None:
            return BASE_SLOT
        got = self.apool.acquire(adapter_id)
        if got is None:
            return None
        aslot, needs_load = got
        if needs_load:
            self.engine.load_adapter(self._adapters.folded(adapter_id),
                                     aslot)
            self.adapter_loads += 1
        return aslot

    def _release_adapter(self, adapter_id: Optional[str]):
        if adapter_id is not None and self.apool is not None:
            self.apool.release(adapter_id)

    # -- admission ---------------------------------------------------------
    def _effective_prompt(self, handle: RequestHandle) -> np.ndarray:
        """Prompt plus tokens already generated (preempted requests resume
        by re-prefilling their own partial generation)."""
        if not handle.tokens:
            return handle.request.prompt
        return np.concatenate([handle.request.prompt,
                               np.asarray(handle.tokens, np.int32)])

    def _finish_prefill(self, slot, handle, first: int, plen: int) -> bool:
        """Shared admit tail: returns True if the slot is now occupied."""
        handle.tokens.append(first)
        now = self._clock()
        t = handle.timing
        if t.admitted_at is None:
            t.admitted_at = now
        if t.first_token_at is None:
            # resumed (preempted) requests keep their original first-token
            # stamp: TTFT measures the first token the *caller* saw
            t.first_token_at = now
        t.token_events.append((now, len(handle.tokens)))
        self._admitted_this_step += 1
        if ((self.eos_id >= 0 and first == self.eos_id)
                or len(handle.tokens) >= handle.request.max_new_tokens):
            self._release_adapter(handle.request.adapter_id)
            self._aslot[slot] = BASE_SLOT
            if self.paged:
                self.pool.free(self._slot_blocks[slot])
                self._slot_blocks[slot] = []
                self._tables[slot] = self.pool.sentinel
            self._finish(handle, RequestStatus.COMPLETED)
            return False                 # one-token request: slot stays free
        handle.status = RequestStatus.RUNNING
        self._slot_handle[slot] = handle
        self._tok[slot] = first
        self._pos[slot] = plen
        self._done[slot] = False
        return True

    def _quarantine_prefill(self, slot, handle, blocks: List[int]):
        """A prefill whose sampled logits went non-finite: drop the pages
        it touched from the prefix index, scrub + free them, and retry the
        request on the (now reference-path) engine."""
        self._note_fallback()
        self.quarantines += 1
        self._release_adapter(handle.request.adapter_id)
        self._aslot[slot] = BASE_SLOT
        if self.paged and blocks:
            self.pool.invalidate(blocks)
            self.pool.free(blocks)
            scrub = [b for b in blocks if self.pool.ref[b] == 0]
            self._caches = self.engine.fill_blocks(self._caches, scrub, 0.0)
        self._requeue_or_fail(handle, "non-finite logits at prefill")

    def _admit_contiguous(self, slot) -> bool:
        while self._queue:
            handle = self._queue[0]
            req = handle.request
            aslot = self._acquire_adapter(req.adapter_id)
            if aslot is None:
                return False     # adapter pool pinned solid: stop admitting
            self._queue.popleft()
            self._aslot[slot] = aslot
            prompt = self._effective_prompt(handle)
            width = _bucket(prompt.size, self.max_len)
            padded = np.zeros((1, width), np.int32)
            padded[0, :prompt.size] = prompt
            tok, self._caches, bad = self.engine.prefill_slot(
                jnp.asarray(padded), prompt.size, self._caches, slot,
                adapter_slot=aslot if self.apool is not None else None)
            if bad:
                self._quarantine_prefill(slot, handle, [])
                continue
            if self._finish_prefill(slot, handle, int(tok), prompt.size):  # repro: noqa[RA001] tok is already a host int (prefill_slot owns the admission sync)
                return True
        return False

    def _admit_paged(self, slot) -> bool:
        while self._queue:
            handle = self._queue[0]
            aid = handle.request.adapter_id
            prompt = self._effective_prompt(handle)
            plen = prompt.size
            aslot = self._acquire_adapter(aid)
            if aslot is None:
                return False     # adapter pool pinned solid: stop admitting
            salt = self._salt(aid)
            shared_ids, shared_tok = (self.pool.match_prefix(prompt, salt)
                                      if self.prefix_reuse else ([], 0))
            cow_src = shared_ids[-1] if shared_tok == plen else None
            need = -(-(plen + 1) // self._bs) - len(shared_ids) \
                + (1 if cow_src is not None else 0)
            fresh = self.pool.alloc(need)
            if fresh is None:
                # page-aware admission: pool (incl. evictable prefix cache)
                # is exhausted — leave the request queued, stop admitting
                self.pool.free(shared_ids)
                self._release_adapter(aid)
                return False
            self._queue.popleft()
            self._aslot[slot] = aslot
            blocks = list(shared_ids)
            if cow_src is not None:
                # whole prompt cached: take a private copy of the last
                # shared page, then re-prefill only the final token (its
                # logits seed sampling; its KV write must not land in a
                # page other requests hold)
                cow_dst = fresh[0]
                self._caches = self.engine.copy_blocks(
                    self._caches, [cow_src], [cow_dst])
                self.pool.free([cow_src])      # drop our ref on the original
                blocks[-1] = cow_dst
                fresh = fresh[1:]
                self.cow_copies += 1
            blocks += fresh
            start = plen - 1 if cow_src is not None else shared_tok

            table = np.full((self._nbr,), self.pool.sentinel, np.int32)
            table[:len(blocks)] = blocks
            suffix = prompt[start:]
            width = _bucket(suffix.size, self.max_len)
            padded = np.zeros((1, width), np.int32)
            padded[0, :suffix.size] = suffix
            tok, self._caches, bad = self.engine.prefill_slot(
                jnp.asarray(padded), suffix.size, self._caches, slot,
                block_table=table, start=start,
                adapter_slot=aslot if self.apool is not None else None)
            if bad:
                # the corrupted KV may live in the shared prefix pages this
                # prefill read — quarantine the whole chain, never register
                # it, and retry from a clean re-prefill
                self._quarantine_prefill(slot, handle, blocks)
                continue

            self._slot_blocks[slot] = blocks
            self._tables[slot] = table
            self._seq_counter += 1
            self._admit_seq[slot] = self._seq_counter
            if self.prefix_reuse:
                self.pool.register_prefix(prompt, blocks, salt)
            if not handle.tokens:
                # telemetry counts fresh admissions only: a preempted
                # request re-matching its own still-cached pages on resume
                # is not cross-request sharing and must not inflate the
                # hit rate the benchmark reports
                self.prefix_queries += 1
                self.prefix_hits += bool(start)
                self.prompt_tokens += plen
                self.shared_tokens += start
                st = self._adapter_prefix.setdefault(aid, [0, 0])
                st[0] += start
                st[1] += plen
            if self._finish_prefill(slot, handle, int(tok), plen):  # repro: noqa[RA001] tok is already a host int (prefill_slot owns the admission sync)
                return True
        return False

    # -- chunked-prefill admission (claim, then chunk-by-chunk) ------------
    def _is_prefilling(self, slot) -> bool:
        return self._prefill_prompt[slot] is not None

    def _begin_prefill(self, slot, handle, prompt: np.ndarray, start: int):
        """Occupy ``slot`` for a claimed request whose prompt will prefill
        chunk-by-chunk from absolute position ``start``. Host-only: no
        device work happens until :meth:`_run_prefill_chunk`."""
        handle.status = RequestStatus.RUNNING
        self._slot_handle[slot] = handle
        self._done[slot] = True       # not decoding until the final chunk
        self._prefill_prompt[slot] = prompt
        self._prefill_pos[slot] = start
        self._prefill_len[slot] = prompt.size
        if handle.timing.admitted_at is None:
            handle.timing.admitted_at = self._clock()
        self._admitted_this_step += 1

    def _claim_contiguous(self, slot) -> bool:
        """Chunked-mode contiguous admission: claim the slot (and adapter
        ref) for the head-of-queue request; its prefill runs in chunks."""
        if not self._queue:
            return False
        handle = self._queue[0]
        aslot = self._acquire_adapter(handle.request.adapter_id)
        if aslot is None:
            return False         # adapter pool pinned solid: stop admitting
        self._queue.popleft()
        self._aslot[slot] = aslot
        self._begin_prefill(slot, handle, self._effective_prompt(handle), 0)
        return True

    def _claim_paged(self, slot) -> bool:
        """Chunked-mode paged admission: allocate the full page chain (and
        COW a fully-cached prompt's last shared page) up front — identical
        accounting to one-shot ``_admit_paged`` — but run no prefill yet.
        Prefix *registration* is deferred to prefill completion: the chain
        holds garbage beyond the shared prefix until the last chunk lands,
        and a partial chain must never be a prefix hit."""
        if not self._queue:
            return False
        handle = self._queue[0]
        aid = handle.request.adapter_id
        prompt = self._effective_prompt(handle)
        plen = prompt.size
        aslot = self._acquire_adapter(aid)
        if aslot is None:
            return False
        salt = self._salt(aid)
        shared_ids, shared_tok = (self.pool.match_prefix(prompt, salt)
                                  if self.prefix_reuse else ([], 0))
        cow_src = shared_ids[-1] if shared_tok == plen else None
        need = -(-(plen + 1) // self._bs) - len(shared_ids) \
            + (1 if cow_src is not None else 0)
        fresh = self.pool.alloc(need)
        if fresh is None:
            self.pool.free(shared_ids)
            self._release_adapter(aid)
            return False
        self._queue.popleft()
        self._aslot[slot] = aslot
        blocks = list(shared_ids)
        if cow_src is not None:
            cow_dst = fresh[0]
            self._caches = self.engine.copy_blocks(
                self._caches, [cow_src], [cow_dst])
            self.pool.free([cow_src])
            blocks[-1] = cow_dst
            fresh = fresh[1:]
            self.cow_copies += 1
        blocks += fresh
        start = plen - 1 if cow_src is not None else shared_tok
        table = np.full((self._nbr,), self.pool.sentinel, np.int32)
        table[:len(blocks)] = blocks
        self._slot_blocks[slot] = blocks
        self._tables[slot] = table
        self._seq_counter += 1
        self._admit_seq[slot] = self._seq_counter
        if self.prefix_reuse:
            self._prefill_register[slot] = (prompt, blocks, salt)
        if not handle.tokens:
            # fresh admissions only (see _admit_paged): resumed requests
            # re-matching their own pages must not inflate the hit rate
            self.prefix_queries += 1
            self.prefix_hits += bool(start)
            self.prompt_tokens += plen
            self.shared_tokens += start
            st = self._adapter_prefix.setdefault(aid, [0, 0])
            st[0] += start
            st[1] += plen
        self._begin_prefill(slot, handle, prompt, start)
        return True

    def _quarantine_partial_prefill(self, slot, reason: str, *,
                                    fallback: bool):
        """Tear down a mid-prefill slot after a fault at a chunk boundary:
        the partial page chain (which was never prefix-registered) is
        invalidated, scrubbed and freed, and the request retries from
        scratch under the bounded-retry accounting — resuming
        token-exactly, since no token was sampled yet. ``fallback=True``
        (non-finite logits on the final chunk) additionally one-shot
        falls back the engine to the reference path."""
        handle = self._slot_handle[slot]
        if fallback:
            self._note_fallback()
        self.quarantines += 1
        self._release_adapter(handle.request.adapter_id)
        self._slot_handle[slot] = None
        self._done[slot] = True
        self._aslot[slot] = BASE_SLOT
        self._prefill_prompt[slot] = None
        self._prefill_pos[slot] = 0
        self._prefill_len[slot] = 0
        self._prefill_register[slot] = None
        if self.paged:
            blocks = self._slot_blocks[slot]
            if blocks:
                self.pool.invalidate(blocks)
                self.pool.free(blocks)
                scrub = [b for b in blocks if self.pool.ref[b] == 0]
                self._caches = self.engine.fill_blocks(self._caches, scrub,
                                                       0.0)
            self._slot_blocks[slot] = []
            self._tables[slot] = self.pool.sentinel
        self._requeue_or_fail(handle, reason)

    def _run_prefill_chunk(self, slot) -> int:
        """Advance ``slot``'s pending prefill by one chunk. Returns the
        device tokens spent (0 when the request was torn down at the
        boundary instead of dispatched)."""
        handle = self._slot_handle[slot]
        now = self._clock()
        # lifecycle between chunks: with one-shot prefill a long prompt
        # could sail past cancel() or its ttft_ms inside one admission
        # call — here every chunk boundary is an enforcement point
        if handle._cancel_requested:
            self._release_slot(slot)
            self._finish(handle, RequestStatus.CANCELLED)
            return 0
        why = self._expiry(handle, now)
        if why is not None:
            self._release_slot(slot)
            self._finish(handle, RequestStatus.TIMED_OUT, why)
            return 0
        prompt = self._prefill_prompt[slot]
        ppos = int(self._prefill_pos[slot])
        plen = int(self._prefill_len[slot])
        n = min(self.prefill_chunk, plen - ppos)
        final = ppos + n >= plen
        width = _bucket(n, self.max_len)
        padded = np.zeros((1, width), np.int32)
        padded[0, :n] = prompt[ppos:ppos + n]
        aslot = self._aslot[slot] if self.apool is not None else None
        call = lambda: self.engine.prefill_slot_chunk(
            jnp.asarray(padded), n, self._caches, slot, start=ppos,
            block_table=self._tables[slot] if self.paged else None,
            adapter_slot=aslot, final=final)
        try:
            if self._faults is not None:
                tok, self._caches, bad = \
                    self._faults.around_prefill_chunk(self, slot, call)
            else:
                tok, self._caches, bad = call()
        except DeviceStepFault as err:
            # the injector raises before the dispatch (caches untouched);
            # a real fault invalidates the partial chain wholesale — drop
            # it either way and retry from a clean re-prefill
            self.device_faults += 1
            self._quarantine_partial_prefill(
                slot, f"prefill-chunk device fault: {err}", fallback=False)
            return 0
        self.prefill_chunks_run += 1
        self._prefill_pos[slot] = ppos + n
        handle.timing.prefill_chunks.append(self._clock())
        if not final:
            return n
        if bad:
            self._quarantine_partial_prefill(
                slot, "non-finite logits at prefill", fallback=True)
            return n
        # prefill complete: the chain now holds the whole prompt's real
        # KV — register it for prefix reuse, then hand off to decode
        reg = self._prefill_register[slot]
        if reg is not None:
            self.pool.register_prefix(*reg)
            self._prefill_register[slot] = None
        self._prefill_prompt[slot] = None
        self._prefill_pos[slot] = 0
        self._prefill_len[slot] = 0
        if not self._finish_prefill(slot, handle, int(tok), plen):  # repro: noqa[RA001] tok is already a host int (prefill_slot_chunk owns the final-chunk sync)
            # one-token request: completed at prefill. _finish_prefill
            # released its pages/adapter but the slot handle was set at
            # claim time — clear it so the slot is free again
            self._slot_handle[slot] = None
        return n

    def _advance_prefills(self) -> int:
        """Spend this step's prefill token budget on pending chunks.

        Round-robin in admission order, one chunk per slot per pass, so a
        short prompt finishing in one chunk never waits for a long
        prompt's full prefill — the head-of-line-blocking fix. With a
        finite ``step_token_budget`` the decode chunk's cost
        (``chunk_size × n_decoding``) is reserved *first* (decode is never
        starved), and passes repeat until the remainder is spent; with no
        budget, exactly one pass runs per step (pure interleaving).
        Returns the tokens spent."""
        budget_left = None
        if self.step_token_budget:
            n_decoding = sum(
                1 for s in range(self.slots)
                if self._slot_handle[s] is not None
                and not self._is_prefilling(s))
            budget_left = max(0, self.step_token_budget
                              - self.chunk_size * n_decoding)
        spent = 0
        while True:
            order = sorted(
                (s for s in range(self.slots) if self._is_prefilling(s)),
                key=lambda s: self._admit_seq[s] if self.paged else s)
            if not order:
                break
            progressed = False
            for slot in order:
                if not self._is_prefilling(slot):
                    continue            # torn down earlier in this pass
                rem = int(self._prefill_len[slot]  # repro: noqa[RA001] host numpy bookkeeping, not a device value
                          - self._prefill_pos[slot])
                n = min(self.prefill_chunk, rem)
                final = n >= rem
                # a FINAL chunk's slot joins this same step's decode
                # chunk, so its decode cost must be reserved with it —
                # otherwise the join overdraws the step's hard cap
                cost = n + (self.chunk_size if final else 0)
                if budget_left is not None and cost > budget_left:
                    continue            # a smaller chunk later may still fit
                used = self._run_prefill_chunk(slot)
                spent += used
                if budget_left is not None:
                    budget_left -= used
                    if final and used and not self._is_prefilling(slot):
                        budget_left -= self.chunk_size
                # a boundary teardown (cancel/timeout/fault) spends no
                # tokens but IS progress — the slot left the prefill set
                progressed = True
            if budget_left is None or not progressed:
                break
        return spent

    def _admit(self):
        """Fill free slots from the queue — per-slot one-shot prefill, or
        (chunked mode) host-only claims whose prefill runs in chunks."""
        for slot in range(self.slots):
            if self._slot_handle[slot] is not None:
                continue
            if self.prefill_chunk:
                ok = (self._claim_paged(slot) if self.paged
                      else self._claim_contiguous(slot))
            else:
                ok = (self._admit_paged(slot) if self.paged
                      else self._admit_contiguous(slot))
            if not ok:
                if not self._queue:
                    continue
                break                     # paged pool exhausted: stop here

    # -- paged page management ---------------------------------------------
    def _release_slot(self, slot):
        handle = self._slot_handle[slot]
        if handle is not None:
            self._release_adapter(handle.request.adapter_id)
        self._slot_handle[slot] = None
        self._done[slot] = True
        self._aslot[slot] = BASE_SLOT
        # a mid-prefill slot releases like any other: the partial KV is
        # simply abandoned (contiguous) or freed with the pages (paged) —
        # it was never prefix-registered, so nothing can ever read it
        self._prefill_prompt[slot] = None
        self._prefill_pos[slot] = 0
        self._prefill_len[slot] = 0
        self._prefill_register[slot] = None
        if self.paged:
            self.pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._tables[slot] = self.pool.sentinel

    def _preempt(self, slot):
        """Free a slot's pages and push its request back to the queue
        front; it resumes later by re-prefilling prompt + generation."""
        handle = self._slot_handle[slot]
        self._release_slot(slot)
        handle.status = RequestStatus.QUEUED
        self._queue.appendleft(handle)
        self.preemptions += 1

    def _quarantine_slot(self, slot, reason: str):
        """Non-finite logits escaped in this slot's chunk: its tokens are
        garbage. Tear down *only this slot* — invalidate its pages from
        the prefix index (corrupted KV must never be a prefix hit), scrub
        them to zero before they return to the free list (a recycled NaN
        poisons the next owner through masked-lane ``0 * NaN``), and retry
        the request from its last good token."""
        handle = self._slot_handle[slot]
        self._note_fallback()
        self.quarantines += 1
        self._release_adapter(handle.request.adapter_id)
        self._slot_handle[slot] = None
        self._done[slot] = True
        self._aslot[slot] = BASE_SLOT
        if self.paged:
            blocks = self._slot_blocks[slot]
            self.pool.invalidate(blocks)
            self.pool.free(blocks)
            scrub = [b for b in blocks if self.pool.ref[b] == 0]
            self._caches = self.engine.fill_blocks(self._caches, scrub, 0.0)
            self._slot_blocks[slot] = []
            self._tables[slot] = self.pool.sentinel
        self._requeue_or_fail(handle, reason)

    def _on_device_fault(self, err: Exception):
        """A decode dispatch failed. Per-slot KV can no longer be trusted
        to advance, so every active request is preempted back to the queue
        (bounded per-request retry accounting) and resumes token-exactly
        by re-prefilling — the same machinery page exhaustion uses."""
        self.device_faults += 1
        order = sorted((s for s in range(self.slots)
                        if self._slot_handle[s] is not None),
                       key=lambda s: self._admit_seq[s] if self.paged else s)
        for slot in reversed(order):       # newest first back onto the queue
            handle = self._slot_handle[slot]
            self._release_slot(slot)
            self._requeue_or_fail(handle, f"decode device fault: {err}")

    def _ensure_pages(self):
        """Grow each active slot's table to cover the next chunk,
        preempting the newest request(s) when the pool runs dry."""
        order = sorted((s for s in range(self.slots)
                        if self._slot_handle[s] is not None
                        and not self._is_prefilling(s)),
                       key=lambda s: self._admit_seq[s])
        for slot in order:
            if self._slot_handle[slot] is None:
                continue                      # preempted below, skip
            while True:
                target = min(int(self._pos[slot]) + self.chunk_size,  # repro: noqa[RA001] host numpy bookkeeping, not a device value
                             self.max_len)
                need = -(-target // self._bs) - len(self._slot_blocks[slot])
                if need <= 0:
                    break
                got = self.pool.alloc(need)
                if got is not None:
                    row = self._slot_blocks[slot]
                    self._tables[slot, len(row):len(row) + len(got)] = got
                    row.extend(got)
                    break
                active = [s for s in range(self.slots)
                          if self._slot_handle[s] is not None]
                victim = max(active, key=lambda s: self._admit_seq[s])
                self._preempt(victim)
                if victim == slot:
                    break                     # this slot itself went back

    # -- lifecycle ---------------------------------------------------------
    def _retire_or_keep(self, slot: int, chunk_toks: np.ndarray):
        handle = self._slot_handle[slot]
        req = handle.request
        finished = False
        appended = 0
        for t in chunk_toks:
            t = int(t)
            handle.tokens.append(t)
            appended += 1
            if self.eos_id >= 0 and t == self.eos_id:
                finished = True
                break
            if len(handle.tokens) >= req.max_new_tokens:
                finished = True
                break
        if appended:
            handle.timing.token_events.append(
                (self._clock(), len(handle.tokens)))
        if finished:
            self._release_slot(slot)
            self._finish(handle, RequestStatus.COMPLETED)

    def _decode_active(self):
        """One decode chunk through the (possibly fault-wrapped) engine."""
        pos = self._pos
        tables = self._tables if self.paged else None
        if self.prefill_chunk:
            pre = [s for s in range(self.slots) if self._is_prefilling(s)]
            if pre:
                # mid-prefill slots ride the decode chunk as done rows, but
                # a done row still *writes* KV at its position every step —
                # park those writes where they drop (beyond max_len /
                # through a sentinel table row) so they can never land
                # inside the slot's partial prefill. Host-side copies of
                # two small numpy arrays; the device shapes are unchanged.
                pos = self._pos.copy()
                pos[pre] = self.max_len
                if self.paged:
                    tables = self._tables.copy()
                    tables[pre] = self.pool.sentinel
        call = lambda: self.engine.decode_chunk(
            jnp.asarray(self._tok), self._caches, self._key,
            jnp.asarray(self._done), jnp.asarray(pos),
            n_steps=self.chunk_size,
            block_tables=tables,
            adapter_slots=self._aslot if self.apool is not None else None)
        if self._faults is not None:
            return self._faults.around_decode(self, call)
        return call()

    def step(self) -> bool:
        """Sweep lifecycle, admit, run one decode chunk, distribute tokens,
        quarantine/retire.

        Returns False once nothing is queued or in flight (the scheduler is
        drained); True means there is more work.
        """
        self.steps_run += 1
        self.last_step_tokens = 0
        if self._faults is not None:
            self._faults.on_step(self)
        self._sweep()
        self._admitted_this_step = 0
        self._admit()
        prefill_spent = 0
        if self.prefill_chunk:
            prefill_spent = self._advance_prefills()
            if self._queue:
                # final chunks may have retired one-token requests or
                # re-queued faulted ones — backfill the freed slots so
                # their first chunks run next step
                self._admit()
        if self.paged:
            self._ensure_pages()
        # decoding set: occupied slots past their prefill (mid-prefill
        # slots keep done=True so the decode chunk ignores their rows)
        active = [s for s in range(self.slots)
                  if self._slot_handle[s] is not None
                  and not self._is_prefilling(s)]
        if not active:
            self.last_step_tokens = prefill_spent
            self.tokens_spent += prefill_spent
            # no-progress detector: a queue nothing can ever be admitted
            # from must not spin run() forever — fail the head-of-queue
            # request once the stall budget is spent. Prefill-chunk
            # progress counts as progress.
            if self._queue and self._admitted_this_step == 0 \
                    and prefill_spent == 0:
                self._stall_steps += 1
                if self._stall_steps >= self.stall_limit:
                    head = self._queue.popleft()
                    self._finish(
                        head, RequestStatus.FAILED,
                        f"scheduler stalled: request unadmittable for "
                        f"{self._stall_steps} consecutive steps")
                    self._stall_steps = 0
            else:
                self._stall_steps = 0
            return self.pending > 0
        self._stall_steps = 0
        try:
            out = self._decode_active()
        except DeviceStepFault as err:
            # the injector raises *before* the dispatch touches the donated
            # caches, and a real device fault invalidates them wholesale
            # either way: recover by preempt-all + re-prefill
            self._on_device_fault(err)
            self.last_step_tokens = prefill_spent
            self.tokens_spent += prefill_spent
            return self.pending > 0
        toks, self._caches, self._key, done, pos, bad = out
        self.chunks_run += 1
        self.last_step_tokens = prefill_spent + self.chunk_size * len(active)
        self.tokens_spent += self.last_step_tokens
        # The designed once-per-chunk host readback: chunk tokens, done
        # mask, KV frontiers and the finite-guard bits cross to the host
        # in ONE explicit transfer. pos is each slot's true KV frontier
        # (the all-done early-exit can freeze it mid-chunk). Explicit
        # device_get keeps the steady-state path legal under
        # jax.transfer_guard("disallow") — anything else syncing in this
        # loop is a bug the transfer sanitizer catches.
        toks, done, pos, bad = jax.device_get((toks, done, pos, bad))  # repro: noqa[RA001] the per-chunk readback: one explicit transfer per decode chunk by design
        toks = np.asarray(toks)                       # [slots, chunk]
        # np.array: writable copies (device_get may return read-only
        # zero-copy views on CPU backends)
        self._done = np.array(done)
        self._pos = np.array(pos)
        self._tok = toks[:, -1].astype(np.int32)
        bad = np.array(bad)
        for slot in active:
            if bad[slot]:
                self._quarantine_slot(
                    slot, "non-finite logits in decode chunk")
            else:
                self._retire_or_keep(slot, toks[slot])
        return self.pending > 0

    def run(self, max_steps: Optional[int] = None):
        """Drive until every submitted request reaches a terminal status.

        ``max_steps`` is a test/ops guard: exceed it and ``run`` raises
        RuntimeError instead of looping (the no-progress detector should
        fire long before any sane limit)."""
        n = 0
        while self.step():
            n += 1
            if max_steps is not None and n >= max_steps:
                raise RuntimeError(
                    f"Scheduler.run exceeded max_steps={max_steps} with "
                    f"{self.pending} requests still pending")
        return self

    # -- snapshot / restore ------------------------------------------------
    SNAPSHOT_FORMAT = 1

    def snapshot(self) -> dict:
        """Crash-consistent host snapshot of every non-terminal request.

        Device state (KV pages, adapter pools) is deliberately **not**
        serialized: in-flight requests are snapshotted *as preempted* —
        prompt plus tokens generated so far — so :meth:`restore` replays
        the existing preempt/re-prefill machinery and the restored run
        continues token-exactly (greedy decoding re-derives the same
        continuation from the re-prefilled KV). Active requests come
        first (admission order), then the queue, so restore preserves
        scheduling fairness.

        The returned tree is plain dicts of numpy scalars/arrays — it
        round-trips through
        :meth:`repro.checkpoint.manager.CheckpointManager.save` /
        ``restore_pytree`` unchanged. Deadlines are serialized as their
        original budgets; the deadline clock restarts at restore (a
        restored server should not mass-expire everything it recovered).
        """
        order: List[RequestHandle] = []
        slots = sorted((s for s in range(self.slots)
                        if self._slot_handle[s] is not None),
                       key=lambda s: self._admit_seq[s] if self.paged else s)
        order += [self._slot_handle[s] for s in slots]
        order += [h for h in self._queue]
        entries = {}
        for i, handle in enumerate(order):
            req = handle.request
            entries[f"{i:05d}"] = {
                "rid": np.int64(req.rid),
                "prompt": np.asarray(req.prompt, np.int32),
                "tokens": np.asarray(handle.tokens, np.int32),
                "max_new_tokens": np.int64(req.max_new_tokens),
                "adapter_id": np.str_(req.adapter_id or ""),
                "ttft_ms": np.float64(-1.0 if req.ttft_ms is None
                                      else req.ttft_ms),
                "deadline_ms": np.float64(-1.0 if req.deadline_ms is None
                                          else req.deadline_ms),
                "fault_retries": np.int64(handle.fault_retries),
            }
        return {"format": np.int64(self.SNAPSHOT_FORMAT),
                "next_rid": np.int64(self._next_rid),
                "requests": entries}

    def restore(self, snapshot: dict) -> Dict[int, RequestHandle]:
        """Rebuild a :meth:`snapshot` into this (fresh) scheduler.

        Every snapshotted request re-enters the queue with its partial
        generation; draining the scheduler finishes them token-exactly.
        Returns ``{rid: handle}`` so callers can re-attach streams.
        Raises ``ValueError`` on a non-empty scheduler, an unknown
        snapshot format, or adapter traffic this scheduler can't route.
        """
        if self.pending:
            raise ValueError(
                f"restore into a scheduler with {self.pending} pending "
                f"requests — restore only into a fresh one")
        fmt = int(np.asarray(snapshot.get("format", -1)))
        if fmt != self.SNAPSHOT_FORMAT:
            raise ValueError(f"unknown scheduler snapshot format {fmt}")
        now = self._clock()
        out: Dict[int, RequestHandle] = {}
        entries = snapshot.get("requests") or {}
        for key in sorted(entries):
            e = entries[key]
            aid = str(np.asarray(e["adapter_id"])) or None
            if aid is not None and (self._adapters is None
                                    or aid not in self._adapters.ids()):
                raise ValueError(
                    f"snapshot routes adapter {aid!r} but this scheduler "
                    f"cannot serve it")
            ttft = float(np.asarray(e["ttft_ms"]))
            deadline = float(np.asarray(e["deadline_ms"]))
            req = Request(
                int(np.asarray(e["rid"])),
                np.asarray(e["prompt"], np.int32).reshape(-1),
                int(np.asarray(e["max_new_tokens"])), aid,
                None if ttft < 0 else ttft,
                None if deadline < 0 else deadline)
            handle = RequestHandle(req)
            handle.tokens = [int(t) for t in
                             np.asarray(e["tokens"]).reshape(-1)]
            handle.fault_retries = int(np.asarray(e["fault_retries"]))
            handle.submitted_at = now          # deadline clock restarts
            handle.timing.submitted_at = now   # latency clock too
            handle._stats_fn = lambda a=aid: self._request_stats(a)
            self._live_handles.add(handle)
            self._queue.append(handle)
            out[req.rid] = handle
        self._next_rid = max(self._next_rid,
                             int(np.asarray(snapshot["next_rid"])))
        return out
