"""Continuous-batching scheduler over :class:`repro.serve.engine.Engine`.

The engine owns the compiled programs; the scheduler owns the ``batch_slots``
ring. Requests queue up via :meth:`Scheduler.submit` and are admitted into
free slots with a **per-slot prefill** (``Engine.prefill_slot`` scatters one
request's KV into one row of the live batch cache), so admitting a new
request never disturbs the slots that are mid-generation. Decode then runs
in fixed-size chunks through the engine's donated ragged ``lax.scan``
(``Engine.decode_chunk``), carrying per-slot ``done``/``pos`` across chunks.
Between chunks the scheduler retires slots that hit EOS or their
``max_new_tokens`` budget and immediately backfills them from the queue —
one long request no longer holds ``batch_slots - 1`` finished neighbours
hostage, which is where the goodput win over static batching comes from
(``benchmarks/serve_bench.py --mode continuous``).

Results stream: ``submit`` returns a :class:`RequestHandle` whose ``poll()``
yields the token delta generated since the last poll, so callers can
stream partial generations while the batch keeps running.

Chunk-size tradeoff: each chunk is one device dispatch, so large chunks
amortize dispatch overhead, but a slot can only be retired/backfilled at a
chunk boundary — up to ``chunk_size - 1`` wasted slot-steps per retirement.
Small chunks react faster at more dispatches. The default (8) favors
responsiveness at smoke scales; production TPU deployments want it nearer
the dispatch/step-cost break-even from ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Engine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [len] int32 token ids
    max_new_tokens: int


class RequestHandle:
    """Streaming view of one request's generation.

    ``poll()`` returns the tokens generated since the last ``poll()`` (empty
    list while the request is queued or between chunks); ``done`` flips once
    the request emitted EOS or exhausted its budget; ``tokens`` is the full
    generation so far (EOS included when one was emitted).
    """

    def __init__(self, request: Request):
        self.request = request
        self.tokens: List[int] = []
        self.done = False
        self._cursor = 0

    def poll(self) -> List[int]:
        delta = self.tokens[self._cursor:]
        self._cursor = len(self.tokens)
        return delta


def _bucket(n: int, cap: int, lo: int = 8) -> int:
    """Next power-of-two width ≥ n (≥ lo, ≤ cap): bounds slot-prefill
    recompiles to log2(max_len) buckets."""
    w = lo
    while w < n:
        w *= 2
    return min(w, cap)


class Scheduler:
    """Admit → decode-in-chunks → retire → backfill, over the engine's slots.

    Host-side state is numpy (`tok`/`pos`/`done` per slot, a few dozen
    bytes); the KV cache tree stays device-resident and is donated through
    every prefill/chunk, so the scheduler adds one small host transfer per
    chunk (the sampled tokens) and nothing per token.
    """

    def __init__(self, engine: Engine, chunk_size: int = 8, seed: int = 0):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
        engine._check_ragged_supported()
        self.engine = engine
        self.chunk_size = chunk_size
        self.slots = engine.scfg.batch_slots
        self.max_len = engine.scfg.max_len
        self.eos_id = engine.scfg.eos_id
        self._caches = engine.new_caches()
        self._key = jax.random.PRNGKey(seed)
        self._queue: Deque[RequestHandle] = deque()
        self._slot_handle: List[Optional[RequestHandle]] = [None] * self.slots
        self._tok = np.zeros((self.slots,), np.int32)
        self._pos = np.zeros((self.slots,), np.int32)
        self._done = np.ones((self.slots,), bool)      # free slots are "done"
        self._next_rid = 0
        self.chunks_run = 0

    # -- submission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int
               ) -> RequestHandle:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1: {max_new_tokens}")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len ({self.max_len})")
        handle = RequestHandle(Request(self._next_rid, prompt,
                                       max_new_tokens))
        self._next_rid += 1
        self._queue.append(handle)
        return handle

    @property
    def pending(self) -> int:
        """Requests queued or occupying a slot."""
        return len(self._queue) + sum(h is not None
                                      for h in self._slot_handle)

    # -- lifecycle ---------------------------------------------------------
    def _admit(self):
        """Fill free slots from the queue via per-slot prefill."""
        for slot in range(self.slots):
            if self._slot_handle[slot] is not None:
                continue
            while self._queue:
                handle = self._queue.popleft()
                req = handle.request
                width = _bucket(req.prompt.size, self.max_len)
                padded = np.zeros((1, width), np.int32)
                padded[0, :req.prompt.size] = req.prompt
                tok, self._caches = self.engine.prefill_slot(
                    jnp.asarray(padded), req.prompt.size, self._caches, slot)
                first = int(tok)
                handle.tokens.append(first)
                if ((self.eos_id >= 0 and first == self.eos_id)
                        or req.max_new_tokens == 1):
                    handle.done = True   # one-token request: slot stays free
                    continue
                self._slot_handle[slot] = handle
                self._tok[slot] = first
                self._pos[slot] = req.prompt.size
                self._done[slot] = False
                break

    def _retire_or_keep(self, slot: int, chunk_toks: np.ndarray):
        handle = self._slot_handle[slot]
        req = handle.request
        for t in chunk_toks:
            t = int(t)
            handle.tokens.append(t)
            if self.eos_id >= 0 and t == self.eos_id:
                handle.done = True
                break
            if len(handle.tokens) >= req.max_new_tokens:
                handle.done = True
                break
        if handle.done:
            self._slot_handle[slot] = None
            self._done[slot] = True

    def step(self) -> bool:
        """Admit, run one decode chunk, distribute tokens, retire.

        Returns False once nothing is queued or in flight (the scheduler is
        drained); True means there is more work.
        """
        self._admit()
        active = [s for s in range(self.slots)
                  if self._slot_handle[s] is not None]
        if not active:
            return bool(self._queue)
        toks, self._caches, self._key, done, pos = self.engine.decode_chunk(
            jnp.asarray(self._tok), self._caches, self._key,
            jnp.asarray(self._done), jnp.asarray(self._pos),
            n_steps=self.chunk_size)
        self.chunks_run += 1
        toks = np.asarray(toks)                       # [slots, chunk]
        # adopt the device carry: pos is each slot's true KV frontier (the
        # all-done early-exit can freeze it mid-chunk). np.array: writable
        # copies (np.asarray of a jax array is a read-only view).
        self._done = np.array(done)
        self._pos = np.array(pos)
        self._tok = toks[:, -1].astype(np.int32)
        for slot in active:
            self._retire_or_keep(slot, toks[slot])
        return self.pending > 0

    def run(self):
        """Drive until every submitted request is done."""
        while self.step():
            pass
        return self
