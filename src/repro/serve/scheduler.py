"""Continuous-batching scheduler over :class:`repro.serve.engine.Engine`.

The engine owns the compiled programs; the scheduler owns request
lifecycle. Requests queue up via :meth:`Scheduler.submit` and are admitted
into free slots with a **per-slot prefill**, so admitting a new request
never disturbs the slots that are mid-generation. Decode then runs in
fixed-size chunks through the engine's donated ragged ``lax.scan``
(``Engine.decode_chunk``), carrying per-slot ``done``/``pos`` across chunks.
Between chunks the scheduler retires slots that hit EOS or their
``max_new_tokens`` budget and immediately backfills them from the queue —
one long request no longer holds ``batch_slots - 1`` finished neighbours
hostage, which is where the goodput win over static batching comes from
(``benchmarks/serve_bench.py --mode continuous``).

With a **paged** engine (``ServeConfig(kv_layout="paged")``) the fixed
per-slot cache lanes disappear: the scheduler owns a
:class:`repro.serve.paged_cache.BlockPool` and admits on *pages*, not
slots —

* **admission** allocates exactly the pages a prompt needs (instead of
  reserving a worst-case ``max_len`` lane), and stops only when the pool
  (minus what the prefix cache can evict) is exhausted;
* **prefix reuse**: prompts are matched block-wise against the ref-counted
  prefix index, so requests sharing a system prompt / few-shot header map
  to the *same* physical pages and skip re-prefilling them (the
  ``prefix_hit_rate`` the benchmark reports); a fully-cached prompt
  copy-on-writes its last shared page before re-prefilling just the final
  token for its logits;
* **decode** allocates pages lazily, one chunk ahead; on exhaustion the
  newest active request is **preempted to the queue** (its pages freed,
  its prompt + generated tokens re-queued at the front) rather than
  wedging the batch;
* **retire** frees pages immediately; pages the prefix index knows stay
  resident as evictable cache, so a retired prompt's prefix is still a hit
  for the next request.

Results stream: ``submit`` returns a :class:`RequestHandle` whose ``poll()``
yields the token delta generated since the last poll, so callers can
stream partial generations while the batch keeps running.

Chunk-size tradeoff: each chunk is one device dispatch, so large chunks
amortize dispatch overhead, but a slot can only be retired/backfilled at a
chunk boundary — up to ``chunk_size - 1`` wasted slot-steps per retirement.
Small chunks react faster at more dispatches. The default (8) favors
responsiveness at smoke scales; production TPU deployments want it nearer
the dispatch/step-cost break-even from ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .adapters import BASE_SLOT, AdapterPool
from .engine import Engine
from .paged_cache import BlockPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [len] int32 token ids
    max_new_tokens: int
    adapter_id: Optional[str] = None   # None = serve the quantized base


class RequestHandle:
    """Streaming view of one request's generation.

    Attributes:
      tokens: the full generation so far — plain python ints (EOS included
        when one was emitted). Grows between ``Scheduler.step()`` calls.
      done: True once the request emitted EOS or exhausted
        ``max_new_tokens``. A done handle is no longer occupying a slot or
        any cache pages.
    """

    def __init__(self, request: Request):
        self.request = request
        self.tokens: List[int] = []
        self.done = False
        self._cursor = 0
        self._stats_fn = None         # set by the scheduler at submit

    def poll(self, with_stats: bool = False):
        """Tokens generated since the last ``poll()``.

        Returns a (possibly empty) list of int token ids. Empty while the
        request is queued or between chunks; after the handle retires
        (``done``), the first ``poll()`` drains the remaining delta and
        subsequent calls return ``[]`` forever — polling a retired handle
        is safe and idempotent.

        With ``with_stats=True`` returns ``(delta, stats)`` where ``stats``
        is a telemetry snapshot for this request's adapter: its id, its
        per-adapter ``prefix_hit_rate``, and the scheduler's adapter-pool
        counters (occupancy / hits / misses / evictions / loads). Requests
        without an adapter (and adapter-free schedulers) report the base
        view — ``adapter_id`` None and zeroed pool counters.
        """
        delta = self.tokens[self._cursor:]
        self._cursor = len(self.tokens)
        if not with_stats:
            return delta
        stats = self._stats_fn() if self._stats_fn is not None else {
            "adapter_id": None, "adapter_prefix_hit_rate": 0.0,
            "adapter_loads": 0, "capacity": 0, "resident": 0, "live": 0,
            "occupancy": 0.0, "hits": 0, "misses": 0, "evictions": 0}
        return delta, stats


def _bucket(n: int, cap: int, lo: int = 8) -> int:
    """Next power-of-two width ≥ n (≥ lo, ≤ cap): bounds slot-prefill
    recompiles to log2(max_len) buckets."""
    w = lo
    while w < n:
        w *= 2
    return min(w, cap)


class Scheduler:
    """Admit → decode-in-chunks → retire → backfill, over the engine's slots.

    Host-side state is numpy (`tok`/`pos`/`done` per slot plus, in paged
    mode, the block tables and pool refcounts — a few hundred bytes); the
    KV cache tree stays device-resident and is donated through every
    prefill/chunk, so the scheduler adds one small host transfer per chunk
    (the sampled tokens) and nothing per token.

    ``prefix_reuse`` (paged engines only) enables the block-granular
    prefix cache; it changes which pages hold a prompt's KV but never the
    tokens generated.

    ``adapters`` (an :class:`repro.serve.adapters.AdapterRegistry`, against
    an engine whose params carry installed factor pools) turns on
    multi-tenant LoRA serving: ``submit(..., adapter_id=...)`` routes a
    request through its adapter's factors. Admission then accounts adapter
    pool slots alongside KV pages — an :class:`AdapterPool` ref-counts
    residency, loads factors on a miss (LRU-evicting an idle adapter), and
    a request whose adapter cannot get a slot waits in the queue exactly
    like one the KV pool cannot admit. Prefix caching stays correct across
    tenants because each adapter salts its hash chains (an adapter rewrites
    the K/V projections, so identical tokens do *not* share KV across
    adapters).
    """

    def __init__(self, engine: Engine, chunk_size: int = 8, seed: int = 0,
                 prefix_reuse: bool = True, adapters=None,
                 adapter_pool: Optional[AdapterPool] = None):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
        engine._check_ragged_supported()
        self.engine = engine
        self.chunk_size = chunk_size
        self.slots = engine.scfg.batch_slots
        self.max_len = engine.scfg.max_len
        self.eos_id = engine.scfg.eos_id
        self.paged = engine.scfg.kv_layout == "paged"
        self._caches = engine.new_caches()
        self._key = jax.random.PRNGKey(seed)
        self._queue: Deque[RequestHandle] = deque()
        self._slot_handle: List[Optional[RequestHandle]] = [None] * self.slots
        self._tok = np.zeros((self.slots,), np.int32)
        self._pos = np.zeros((self.slots,), np.int32)
        self._done = np.ones((self.slots,), bool)      # free slots are "done"
        self._next_rid = 0
        self.chunks_run = 0
        # -- paged state ----------------------------------------------------
        self.prefix_reuse = prefix_reuse and self.paged
        if self.paged:
            scfg = engine.scfg
            self.pool = BlockPool(scfg.pool_blocks, scfg.block_size)
            self._bs = scfg.block_size
            self._nbr = scfg.blocks_per_seq
            self._tables = np.full((self.slots, self._nbr),
                                   self.pool.sentinel, np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in range(self.slots)]
            self._admit_seq = np.zeros((self.slots,), np.int64)
            self._seq_counter = 0
        # -- adapter state --------------------------------------------------
        self._adapters = adapters           # AdapterRegistry or None
        self.apool: Optional[AdapterPool] = None
        self._aslot = np.zeros((self.slots,), np.int32)   # BASE_SLOT lanes
        self.adapter_loads = 0
        # per-adapter prefix telemetry: id -> [shared_tokens, prompt_tokens]
        self._adapter_prefix: Dict[Optional[str], List[int]] = {}
        if adapter_pool is not None and adapters is None:
            raise ValueError("adapter_pool without an adapter registry")
        if adapters is not None:
            n = engine.adapter_slots
            if n < 2:
                raise ValueError(
                    "adapter registry given but the engine's params carry "
                    "no factor pools — quantize with install_pools first")
            if adapter_pool is not None and adapter_pool.num_slots != n:
                raise ValueError(
                    f"adapter_pool has {adapter_pool.num_slots} slots but "
                    f"the engine's params carry {n}")
            # a shared pool outlives this scheduler: its residency map
            # mirrors the *engine's* device pools, so a restarted scheduler
            # (or several schedulers over one engine) skips reloading
            # factors that are already resident
            self.apool = adapter_pool or AdapterPool(n)
        # prefix-cache telemetry (all zeros for contiguous engines)
        self.prompt_tokens = 0      # Σ effective prompt lengths admitted
        self.shared_tokens = 0      # Σ prompt tokens served from cached pages
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.preemptions = 0
        self.cow_copies = 0

    # -- submission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               adapter_id: Optional[str] = None) -> RequestHandle:
        """Queue one generation request.

        Args:
          prompt: non-empty 1-D sequence of int token ids (any integer
            array-like; stored as int32). Not padded — the scheduler
            buckets it internally.
          max_new_tokens: generation budget, ``>= 1``. The request retires
            at EOS (when the engine's ``eos_id >= 0``) or after exactly
            this many tokens, whichever comes first. ``len(prompt) +
            max_new_tokens`` must fit the engine's ``max_len``.
          adapter_id: route this request through a registered adapter's
            factors (requires the scheduler's ``adapters`` registry); None
            serves the quantized base model.

        Returns a :class:`RequestHandle` immediately — generation happens
        during subsequent :meth:`step` / :meth:`run` calls; stream tokens
        off the handle with ``poll()``.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1: {max_new_tokens}")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len ({self.max_len})")
        if adapter_id is not None:
            if self._adapters is None:
                raise ValueError(
                    f"adapter_id {adapter_id!r} but this scheduler has no "
                    f"adapter registry")
            if adapter_id not in self._adapters.ids():
                raise ValueError(f"unknown adapter {adapter_id!r}")
        handle = RequestHandle(Request(self._next_rid, prompt,
                                       max_new_tokens, adapter_id))
        handle._stats_fn = lambda aid=adapter_id: self._request_stats(aid)
        self._next_rid += 1
        self._queue.append(handle)
        return handle

    @property
    def pending(self) -> int:
        """Requests queued or occupying a slot."""
        return len(self._queue) + sum(h is not None
                                      for h in self._slot_handle)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cached pages."""
        return self.shared_tokens / self.prompt_tokens \
            if self.prompt_tokens else 0.0

    def adapter_prefix_hit_rate(self, adapter_id: Optional[str] = None
                                ) -> float:
        """Per-adapter prefix hit rate (None = base traffic). Adapters only
        ever share prefixes with themselves (salted hash chains), so this
        is the number the benchmark reports per tenant."""
        st = self._adapter_prefix.get(adapter_id)
        return st[0] / st[1] if st and st[1] else 0.0

    def adapter_stats(self) -> dict:
        """Adapter-pool telemetry snapshot (zeros when adapter-free)."""
        out = {"adapter_loads": self.adapter_loads}
        if self.apool is not None:
            out.update(self.apool.stats())
        else:
            out.update({"capacity": 0, "resident": 0, "live": 0,
                        "occupancy": 0.0, "hits": 0, "misses": 0,
                        "evictions": 0})
        return out

    def _request_stats(self, adapter_id: Optional[str]) -> dict:
        stats = {"adapter_id": adapter_id,
                 "adapter_prefix_hit_rate":
                     self.adapter_prefix_hit_rate(adapter_id)}
        stats.update(self.adapter_stats())
        return stats

    # -- adapter residency -------------------------------------------------
    @staticmethod
    def _salt(adapter_id: Optional[str]) -> bytes:
        """Prefix-hash salt: adapters never share KV with each other or
        with the base (their K/V projections differ)."""
        return f"adapter:{adapter_id}".encode() \
            if adapter_id is not None else b""

    def _acquire_adapter(self, adapter_id: Optional[str]) -> Optional[int]:
        """Resolve a request's adapter to a pool slot, loading factors on a
        miss. Returns the slot (``BASE_SLOT`` for base requests), or None
        when every slot is pinned by live requests — the caller leaves the
        request queued, exactly like KV-page exhaustion."""
        if adapter_id is None:
            return BASE_SLOT
        got = self.apool.acquire(adapter_id)
        if got is None:
            return None
        aslot, needs_load = got
        if needs_load:
            self.engine.load_adapter(self._adapters.folded(adapter_id),
                                     aslot)
            self.adapter_loads += 1
        return aslot

    def _release_adapter(self, adapter_id: Optional[str]):
        if adapter_id is not None and self.apool is not None:
            self.apool.release(adapter_id)

    # -- admission ---------------------------------------------------------
    def _effective_prompt(self, handle: RequestHandle) -> np.ndarray:
        """Prompt plus tokens already generated (preempted requests resume
        by re-prefilling their own partial generation)."""
        if not handle.tokens:
            return handle.request.prompt
        return np.concatenate([handle.request.prompt,
                               np.asarray(handle.tokens, np.int32)])

    def _finish_prefill(self, slot, handle, first: int, plen: int) -> bool:
        """Shared admit tail: returns True if the slot is now occupied."""
        handle.tokens.append(first)
        if ((self.eos_id >= 0 and first == self.eos_id)
                or len(handle.tokens) >= handle.request.max_new_tokens):
            handle.done = True           # one-token request: slot stays free
            self._release_adapter(handle.request.adapter_id)
            self._aslot[slot] = BASE_SLOT
            if self.paged:
                self.pool.free(self._slot_blocks[slot])
                self._slot_blocks[slot] = []
                self._tables[slot] = self.pool.sentinel
            return False
        self._slot_handle[slot] = handle
        self._tok[slot] = first
        self._pos[slot] = plen
        self._done[slot] = False
        return True

    def _admit_contiguous(self, slot) -> bool:
        while self._queue:
            handle = self._queue[0]
            req = handle.request
            aslot = self._acquire_adapter(req.adapter_id)
            if aslot is None:
                return False     # adapter pool pinned solid: stop admitting
            self._queue.popleft()
            self._aslot[slot] = aslot
            width = _bucket(req.prompt.size, self.max_len)
            padded = np.zeros((1, width), np.int32)
            padded[0, :req.prompt.size] = req.prompt
            tok, self._caches = self.engine.prefill_slot(
                jnp.asarray(padded), req.prompt.size, self._caches, slot,
                adapter_slot=aslot if self.apool is not None else None)
            if self._finish_prefill(slot, handle, int(tok), req.prompt.size):
                return True
        return False

    def _admit_paged(self, slot) -> bool:
        while self._queue:
            handle = self._queue[0]
            aid = handle.request.adapter_id
            prompt = self._effective_prompt(handle)
            plen = prompt.size
            aslot = self._acquire_adapter(aid)
            if aslot is None:
                return False     # adapter pool pinned solid: stop admitting
            salt = self._salt(aid)
            shared_ids, shared_tok = (self.pool.match_prefix(prompt, salt)
                                      if self.prefix_reuse else ([], 0))
            cow_src = shared_ids[-1] if shared_tok == plen else None
            need = -(-(plen + 1) // self._bs) - len(shared_ids) \
                + (1 if cow_src is not None else 0)
            fresh = self.pool.alloc(need)
            if fresh is None:
                # page-aware admission: pool (incl. evictable prefix cache)
                # is exhausted — leave the request queued, stop admitting
                self.pool.free(shared_ids)
                self._release_adapter(aid)
                return False
            self._queue.popleft()
            self._aslot[slot] = aslot
            blocks = list(shared_ids)
            if cow_src is not None:
                # whole prompt cached: take a private copy of the last
                # shared page, then re-prefill only the final token (its
                # logits seed sampling; its KV write must not land in a
                # page other requests hold)
                cow_dst = fresh[0]
                self._caches = self.engine.copy_blocks(
                    self._caches, [cow_src], [cow_dst])
                self.pool.free([cow_src])      # drop our ref on the original
                blocks[-1] = cow_dst
                fresh = fresh[1:]
                self.cow_copies += 1
            blocks += fresh
            start = plen - 1 if cow_src is not None else shared_tok

            table = np.full((self._nbr,), self.pool.sentinel, np.int32)
            table[:len(blocks)] = blocks
            suffix = prompt[start:]
            width = _bucket(suffix.size, self.max_len)
            padded = np.zeros((1, width), np.int32)
            padded[0, :suffix.size] = suffix
            tok, self._caches = self.engine.prefill_slot(
                jnp.asarray(padded), suffix.size, self._caches, slot,
                block_table=table, start=start,
                adapter_slot=aslot if self.apool is not None else None)

            self._slot_blocks[slot] = blocks
            self._tables[slot] = table
            self._seq_counter += 1
            self._admit_seq[slot] = self._seq_counter
            if self.prefix_reuse:
                self.pool.register_prefix(prompt, blocks, salt)
            if not handle.tokens:
                # telemetry counts fresh admissions only: a preempted
                # request re-matching its own still-cached pages on resume
                # is not cross-request sharing and must not inflate the
                # hit rate the benchmark reports
                self.prefix_queries += 1
                self.prefix_hits += bool(start)
                self.prompt_tokens += plen
                self.shared_tokens += start
                st = self._adapter_prefix.setdefault(aid, [0, 0])
                st[0] += start
                st[1] += plen
            if self._finish_prefill(slot, handle, int(tok), plen):
                return True
        return False

    def _admit(self):
        """Fill free slots from the queue via per-slot prefill."""
        for slot in range(self.slots):
            if self._slot_handle[slot] is not None:
                continue
            if not (self._admit_paged(slot) if self.paged
                    else self._admit_contiguous(slot)):
                if not self._queue:
                    continue
                break                     # paged pool exhausted: stop here

    # -- paged page management ---------------------------------------------
    def _release_slot(self, slot):
        handle = self._slot_handle[slot]
        if handle is not None:
            self._release_adapter(handle.request.adapter_id)
        self._slot_handle[slot] = None
        self._done[slot] = True
        self._aslot[slot] = BASE_SLOT
        if self.paged:
            self.pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._tables[slot] = self.pool.sentinel

    def _preempt(self, slot):
        """Free a slot's pages and push its request back to the queue
        front; it resumes later by re-prefilling prompt + generation."""
        handle = self._slot_handle[slot]
        self._release_slot(slot)
        self._queue.appendleft(handle)
        self.preemptions += 1

    def _ensure_pages(self):
        """Grow each active slot's table to cover the next chunk,
        preempting the newest request(s) when the pool runs dry."""
        order = sorted((s for s in range(self.slots)
                        if self._slot_handle[s] is not None),
                       key=lambda s: self._admit_seq[s])
        for slot in order:
            if self._slot_handle[slot] is None:
                continue                      # preempted below, skip
            while True:
                target = min(int(self._pos[slot]) + self.chunk_size,
                             self.max_len)
                need = -(-target // self._bs) - len(self._slot_blocks[slot])
                if need <= 0:
                    break
                got = self.pool.alloc(need)
                if got is not None:
                    row = self._slot_blocks[slot]
                    self._tables[slot, len(row):len(row) + len(got)] = got
                    row.extend(got)
                    break
                active = [s for s in range(self.slots)
                          if self._slot_handle[s] is not None]
                victim = max(active, key=lambda s: self._admit_seq[s])
                self._preempt(victim)
                if victim == slot:
                    break                     # this slot itself went back

    # -- lifecycle ---------------------------------------------------------
    def _retire_or_keep(self, slot: int, chunk_toks: np.ndarray):
        handle = self._slot_handle[slot]
        req = handle.request
        for t in chunk_toks:
            t = int(t)
            handle.tokens.append(t)
            if self.eos_id >= 0 and t == self.eos_id:
                handle.done = True
                break
            if len(handle.tokens) >= req.max_new_tokens:
                handle.done = True
                break
        if handle.done:
            self._release_slot(slot)

    def step(self) -> bool:
        """Admit, run one decode chunk, distribute tokens, retire.

        Returns False once nothing is queued or in flight (the scheduler is
        drained); True means there is more work.
        """
        self._admit()
        if self.paged:
            self._ensure_pages()
        active = [s for s in range(self.slots)
                  if self._slot_handle[s] is not None]
        if not active:
            return bool(self._queue)
        toks, self._caches, self._key, done, pos = self.engine.decode_chunk(
            jnp.asarray(self._tok), self._caches, self._key,
            jnp.asarray(self._done), jnp.asarray(self._pos),
            n_steps=self.chunk_size,
            block_tables=self._tables if self.paged else None,
            adapter_slots=self._aslot if self.apool is not None else None)
        self.chunks_run += 1
        toks = np.asarray(toks)                       # [slots, chunk]
        # adopt the device carry: pos is each slot's true KV frontier (the
        # all-done early-exit can freeze it mid-chunk). np.array: writable
        # copies (np.asarray of a jax array is a read-only view).
        self._done = np.array(done)
        self._pos = np.array(pos)
        self._tok = toks[:, -1].astype(np.int32)
        for slot in active:
            self._retire_or_keep(slot, toks[slot])
        return self.pending > 0

    def run(self):
        """Drive until every submitted request is done."""
        while self.step():
            pass
        return self
