"""Multi-tenant LoRA adapter serving on the quantized low-rank epilogue.

ASER's error reconstruction already gives every quantized linear a low-rank
epilogue (``y += (x_s @ lb) @ la``). This module turns that structure into
S-LoRA-style multi-tenant serving: many per-user adapters riding on one
quantized base model, with per-request routing down to the kernel.

Three pieces:

* :class:`AdapterPool` — the host-side slot manager for the device factor
  pools: ref-counted residency, LRU eviction of unreferenced adapters,
  mirroring :class:`repro.serve.paged_cache.BlockPool`. Slot 0 is reserved
  for the all-zero **base adapter** (rows without an adapter route there
  and their epilogue contribution is exactly 0.0) and is never allocated
  or evicted.

* :class:`AdapterRegistry` — knows the base model's quantized linears
  (paths, shapes, smoothing diagonals) and owns the per-adapter factors.
  An adapter is a dict ``path -> (A [.., k, r], B [.., r, n])`` of raw
  (unsmoothed) LoRA factors; loading folds the layer's ASER smoothing
  diagonal into A (``A_s = m ⊙ A``, so ``x_s @ A_s == x @ A``) and
  zero-pads the rank to the kernel lane multiple. ``merged_params`` builds
  the per-request merged-weight reference (factors concatenated onto
  ``lb``/``la``) that parity tests and benchmarks check against.

* :func:`install_pools` — grows every quantized leaf with device factor
  pools ``alb [.., P, k, ra]`` / ``ala [.., P, ra, n]`` (zeros);
  :func:`load_adapter` writes one adapter's folded factors into slot
  ``s`` of every pool. Routing happens per forward call via
  ``forward(..., adapter_idx=...)`` → ``layers.route_adapters``.

Memory math: one adapter costs ``Σ_linears (k + n) · ra · 4`` bytes of
pool — for rank 8 on a 4k-d model that is ~100× smaller than the W4 base
weights, which is why pools hold P adapters resident and page the rest.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict, deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import LOWRANK_MULTIPLE

BASE_SLOT = 0


def padded_rank(r: int, multiple: int = LOWRANK_MULTIPLE) -> int:
    """Rank padded up to the kernel lane multiple (min one full multiple)."""
    if r <= 0:
        raise ValueError(f"adapter rank must be >= 1, got {r}")
    return -(-r // multiple) * multiple


# ---------------------------------------------------------------------------
# Host-side slot manager
# ---------------------------------------------------------------------------

class AdapterPool:
    """Ref-counted, LRU-evicted slot manager for the device factor pools.

    The device arrays hold ``num_slots`` adapters; slot 0 is the pinned
    all-zero base adapter. ``acquire`` returns a slot for an adapter id —
    a **hit** (already resident: incref, no load needed), a **miss** (a
    free or LRU-evicted slot; caller must load the factors), or ``None``
    when every slot is referenced by a live request (caller waits).
    ``release`` drops a reference; unreferenced adapters stay resident as
    evictable cache so a returning tenant hits warm.
    """

    def __init__(self, num_slots: int):
        if num_slots < 2:
            raise ValueError(f"AdapterPool needs >= 2 slots (slot 0 is the "
                             f"base adapter), got {num_slots}")
        self.num_slots = num_slots
        self.ref = np.zeros(num_slots, np.int32)       # ref[0] stays 0
        self._free = deque(range(1, num_slots))
        self._by_id = OrderedDict()                    # adapter_id -> slot
        self._id_of: dict[int, object] = {}            # slot -> adapter_id
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def capacity(self) -> int:
        """Adapter-holding slots (excludes the pinned base slot)."""
        return self.num_slots - 1

    def resident(self) -> int:
        return len(self._by_id)

    def live(self) -> int:
        return int((self.ref > 0).sum())

    def cached(self) -> int:
        """Resident but unreferenced (evictable) adapters."""
        return sum(1 for s in self._id_of if self.ref[s] == 0)

    def available(self) -> int:
        """Slots an acquire-miss could claim right now."""
        return len(self._free) + self.cached()

    def occupancy(self) -> float:
        return self.resident() / self.capacity

    def slot_of(self, adapter_id):
        """Resident slot of ``adapter_id`` or None (no ref taken)."""
        return self._by_id.get(adapter_id)

    def acquire(self, adapter_id):
        """Take a reference. Returns ``(slot, needs_load)`` or ``None``
        when all slots are pinned by live requests (state unchanged)."""
        slot = self._by_id.get(adapter_id)
        if slot is not None:
            self.ref[slot] += 1
            self._by_id.move_to_end(adapter_id)        # LRU touch
            self.hits += 1
            return slot, False
        if self._free:
            slot = self._free.popleft()
        else:
            slot = self._evict_one()
            if slot is None:
                return None
        self.misses += 1
        self.ref[slot] = 1
        self._by_id[adapter_id] = slot
        self._id_of[slot] = adapter_id
        return slot, True

    def _evict_one(self):
        """Reclaim the least-recently-used unreferenced adapter's slot."""
        for aid, slot in self._by_id.items():
            if self.ref[slot] == 0:
                del self._by_id[aid]
                del self._id_of[slot]
                self.evictions += 1
                return slot
        return None

    def release(self, adapter_id):
        """Drop one reference; the adapter stays resident (evictable)."""
        slot = self._by_id.get(adapter_id)
        if slot is None:
            raise KeyError(f"release of non-resident adapter {adapter_id!r}")
        if self.ref[slot] <= 0:
            raise ValueError(f"release of unreferenced adapter "
                             f"{adapter_id!r} (double release)")
        self.ref[slot] -= 1

    def stats(self) -> dict:
        return {"capacity": self.capacity, "resident": self.resident(),
                "live": self.live(), "occupancy": self.occupancy(),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}

    def verify(self) -> list:
        """Internal-consistency audit (used by the drain leak checks).

        Returns human-readable violations: negative refcounts, a
        referenced base slot, broken ``_by_id``/``_id_of`` bijection, or a
        slot that is neither free nor resident (stranded). Empty = clean.
        """
        out = []
        if (self.ref < 0).any():
            out.append(f"negative adapter refcounts at slots "
                       f"{np.flatnonzero(self.ref < 0).tolist()}")
        if self.ref[BASE_SLOT] != 0:
            out.append(f"base slot holds {self.ref[BASE_SLOT]} refs")
        for aid, slot in self._by_id.items():
            if self._id_of.get(slot) != aid:
                out.append(f"bijection broken: {aid!r} -> slot {slot} -> "
                           f"{self._id_of.get(slot)!r}")
        accounted = set(self._free) | set(self._id_of) | {BASE_SLOT}
        stranded = set(range(self.num_slots)) - accounted
        if stranded:
            out.append(f"stranded slots (neither free nor resident): "
                       f"{sorted(stranded)}")
        return out


# ---------------------------------------------------------------------------
# Quantized-leaf walking
# ---------------------------------------------------------------------------

def _is_quant_leaf(tree) -> bool:
    return isinstance(tree, dict) and "qw" in tree and "m" in tree


def iter_quant_leaves(tree, prefix: str = ""):
    """Yield ``(path, leaf)`` for every adapter-targetable quantized leaf.

    MoE expert leaves are skipped: their activations are dispatch-permuted,
    so a per-sequence row index cannot address them."""
    if _is_quant_leaf(tree):
        if "/experts" not in prefix:
            yield prefix, tree
        return
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from iter_quant_leaves(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_quant_leaves(v, f"{prefix}/{i}")


def _map_quant_leaves(tree, fn, prefix: str = ""):
    """Rebuild ``tree`` with ``fn(path, leaf)`` applied to each target."""
    if _is_quant_leaf(tree):
        if "/experts" in prefix:
            return tree
        return fn(prefix, tree)
    if isinstance(tree, dict):
        return {k: _map_quant_leaves(v, fn, f"{prefix}/{k}")
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        mapped = [_map_quant_leaves(v, fn, f"{prefix}/{i}")
                  for i, v in enumerate(tree)]
        return type(tree)(mapped) if isinstance(tree, tuple) else mapped
    return tree


def adapter_slot_count(params) -> int:
    """Number of pool slots installed in ``params`` (0 = no pools)."""
    for _, leaf in iter_quant_leaves(params):
        if "alb" in leaf:
            return leaf["alb"].shape[-3]
    return 0


# ---------------------------------------------------------------------------
# Device pools
# ---------------------------------------------------------------------------

def install_pools(params, *, slots: int, rank: int):
    """Grow every quantized leaf with zeroed device factor pools.

    ``alb``: [lead.., slots, k, ra]; ``ala``: [lead.., slots, ra, n] with
    ``ra = padded_rank(rank)``. Slot 0 stays all-zero forever (the base
    adapter). Returns a new params tree; fp leaves are untouched."""
    if slots < 2:
        raise ValueError(f"install_pools needs slots >= 2, got {slots}")
    ra = padded_rank(rank)

    def add(path, leaf):
        lead = leaf["qw"].shape[:-2]
        k = leaf["m"].shape[-1]
        n = leaf["sw"].shape[-1]
        leaf = dict(leaf)
        leaf["alb"] = jnp.zeros(lead + (slots, k, ra), jnp.float32)
        leaf["ala"] = jnp.zeros(lead + (slots, ra, n), jnp.float32)
        return leaf

    return _map_quant_leaves(params, add)


def load_adapter(params, factors, slot: int):
    """Write one adapter's folded factors into pool slot ``slot``.

    ``factors``: dict path -> (a_s [lead.., k, ra], b [lead.., ra, n]) as
    produced by :meth:`AdapterRegistry.folded`. Per-leaf functional updates
    (``.at[...].set``) — the pools are tiny next to the base weights, and
    updating leaf-by-leaf never donates or invalidates the shared ``qw``
    buffers other engines may hold. Returns a new params tree."""
    if slot == BASE_SLOT:
        raise ValueError("slot 0 is the pinned all-zero base adapter")

    def write(path, leaf):
        if "alb" not in leaf:
            return leaf
        if path not in factors:
            raise KeyError(f"adapter factors missing for {path}")
        a_s, b = factors[path]
        a_s = jax.device_put(np.asarray(a_s, np.float32))
        b = jax.device_put(np.asarray(b, np.float32))
        leaf = dict(leaf)
        leaf["alb"], leaf["ala"] = _pool_write(
            leaf["alb"], leaf["ala"], a_s, b, slot=slot)
        return leaf

    return _map_quant_leaves(params, write)


@partial(jax.jit, static_argnames=("slot",))
def _pool_write(alb, ala, a_s, b, *, slot):
    # Jitted so the slot index is a static constant: an eager
    # ``.at[..., slot, :, :].set`` would upload the index (and the axis
    # bound from index normalization) as implicit h2d scalar transfers on
    # the serving loop, tripping the steady-state transfer guard. Inside
    # jit the scatter is baked at compile time; slot swaps at steady state
    # reuse the cached executable (slots are few and shapes fixed).
    return (alb.at[..., slot, :, :].set(a_s),
            ala.at[..., slot, :, :].set(b))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class AdapterRegistry:
    """Loads/quantizes adapters against a quantized base model.

    Records every adapter-targetable quantized linear of ``params`` (path,
    shapes, smoothing diagonal). ``add`` registers an adapter's raw factors
    (or synthesizes deterministic ones — the test/benchmark tenant
    generator); ``folded`` returns the serving form with the recipe's
    smoothing folded in and the rank padded; ``merged_params`` builds the
    merged-weight reference model for exactness checks.
    """

    def __init__(self, params, *, rank: int = 8, seed: int = 0):
        self.rank = int(rank)
        self.ra = padded_rank(self.rank)
        self.seed = int(seed)
        self._targets = []            # (path, lead, k, n, m_diag f32)
        for path, leaf in iter_quant_leaves(params):
            lead = leaf["qw"].shape[:-2]
            self._targets.append(
                (path, lead, leaf["m"].shape[-1], leaf["sw"].shape[-1],
                 np.asarray(leaf["m"], np.float32)))
        if not self._targets:
            raise ValueError("no quantized linears to adapt — "
                             "AdapterRegistry needs a quantized base model")
        self._raw = {}                # adapter_id -> {path: (A, B)}
        self._folded = {}             # adapter_id -> {path: (a_s, b)}

    @classmethod
    def from_recipe(cls, params, recipe, *, seed: int = 0):
        """Rank from the recipe's :class:`repro.quant.recipe.AdapterSpec`."""
        return cls(params, rank=recipe.adapter.rank or 8, seed=seed)

    def ids(self):
        return list(self._raw)

    def paths(self):
        return [t[0] for t in self._targets]

    def add(self, adapter_id, factors=None):
        """Register an adapter. ``factors``: dict path -> (A [lead.., k, r],
        B [lead.., r, n]) raw LoRA factors; None synthesizes deterministic
        random factors (seeded by (seed, adapter_id, path))."""
        if adapter_id in self._raw:
            raise ValueError(f"adapter {adapter_id!r} already registered")
        if factors is None:
            factors = {path: self._synth(adapter_id, path, lead, k, n)
                       for path, lead, k, n, _ in self._targets}
        for path, lead, k, n, _ in self._targets:
            if path not in factors:
                raise KeyError(f"adapter {adapter_id!r} missing factors "
                               f"for {path}")
            a, b = factors[path]
            if a.shape != lead + (k, a.shape[-1]) or \
                    b.shape != lead + (b.shape[-2], n) or \
                    a.shape[-1] != b.shape[-2]:
                raise ValueError(
                    f"adapter {adapter_id!r} factor shapes {a.shape} / "
                    f"{b.shape} do not match target {path} "
                    f"(lead={lead}, k={k}, n={n})")
        self._raw[adapter_id] = factors
        return adapter_id

    def _synth(self, adapter_id, path, lead, k, n, amp: float = 0.25):
        rng = np.random.default_rng(
            zlib.crc32(f"{self.seed}/{adapter_id}/{path}".encode()))
        a = rng.standard_normal(lead + (k, self.rank)).astype(np.float32)
        b = rng.standard_normal(lead + (self.rank, n)).astype(np.float32)
        return a * k ** -0.5, b * (amp * self.rank ** -0.5)

    def folded(self, adapter_id):
        """Serving factors: smoothing folded into A, rank zero-padded.

        dict path -> (a_s [lead.., k, ra], b [lead.., ra, n]); with
        ``x_s = x / m`` the routed epilogue ``(x_s @ a_s) @ b`` equals the
        adapter's raw ``(x @ A) @ B``."""
        if adapter_id not in self._folded:
            raw = self._raw[adapter_id]
            out = {}
            for path, lead, k, n, m_diag in self._targets:
                a, b = raw[path]
                r = a.shape[-1]
                a_s = m_diag[..., :, None] * np.asarray(a, np.float32)
                pad = self.ra - r
                if pad:
                    a_s = np.pad(a_s, [(0, 0)] * (a_s.ndim - 1) + [(0, pad)])
                    b = np.pad(np.asarray(b, np.float32),
                               [(0, 0)] * (b.ndim - 2) + [(0, pad), (0, 0)])
                out[path] = (jnp.asarray(a_s), jnp.asarray(b, jnp.float32))
            self._folded[adapter_id] = out
        return self._folded[adapter_id]

    def merged_params(self, params, adapter_id):
        """Merged-weight reference: factors concatenated onto ``lb``/``la``.

        The returned params serve the adapter through the plain base path
        (no pools, no routing) — the token-exactness oracle for routed
        serving. Installed pools are dropped from the copy."""
        folded = self.folded(adapter_id)

        def merge(path, leaf):
            leaf = {k: v for k, v in leaf.items()
                    if k not in ("alb", "ala", "aidx")}
            a_s, b = folded[path]
            leaf["lb"] = jnp.concatenate(
                [leaf["lb"].astype(jnp.float32), a_s], axis=-1)
            leaf["la"] = jnp.concatenate(
                [leaf["la"].astype(jnp.float32), b], axis=-2)
            return leaf

        return _map_quant_leaves(params, merge)

    def pool_bytes_per_adapter(self) -> int:
        """Device bytes one pool slot costs across all target linears."""
        total = 0
        for _, lead, k, n, _ in self._targets:
            stack = int(np.prod(lead)) if lead else 1
            total += stack * (k + n) * self.ra * 4
        return total
