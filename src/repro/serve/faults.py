"""Deterministic fault injection for the serving stack (chaos testing).

The scheduler exposes four seams where real production failures enter —
the per-step hook (``on_step``), the decode dispatch (``around_decode``),
the prefill-chunk dispatch (``around_prefill_chunk``) and the checkpoint
writer (``wrap_checkpoint``) — and
:class:`FaultInjector` drives all of them from one seeded
``numpy.random.Generator``, so a failing chaos run is **replayable from
its seed alone**. The injectable faults, and the recovery path each one
exercises:

=====================  =============================  =====================
fault                  injected as                    recovery under test
=====================  =============================  =====================
device step failure    :class:`DeviceStepFault`       preempt-all + re-
                       raised *before* the decode     prefill resume
                       dispatch
prefill-chunk fault    :class:`DeviceStepFault`       partial-prefill
                       raised *before* a prefill      quarantine: page
                       chunk's dispatch               chain freed, bounded
                       (``around_prefill_chunk``)     retry, token-exact
                                                      re-prefill
NaN logits             per-slot taint of the chunk's  slot quarantine +
                       ``bad`` mask                   bounded retry +
                                                      kernel fallback
corrupted KV page      ``nan`` written into one live  on-device finite
                       page via ``Engine.fill_blocks``  guard → quarantine,
                       (scale tensors for int KV)     page scrub, prefix
                                                      invalidation
page-pool pressure     injector holds page refs for   page-aware admission,
                       a few steps                    preempt-to-queue
adapter-pool pressure  injector pins adapter slots    admission waits, no-
                       for a few steps                progress detector
checkpoint write fail  patched ``CheckpointManager._  async error surfaces
                       write`` raises                 on wait()/next save()
=====================  =============================  =====================

Every injection appends a structured record to :attr:`FaultInjector.trace`
(``save_trace`` writes it with a replay command line), which is the
artifact CI uploads when a chaos seed fails.

Injection contracts the recovery code relies on:

* device faults raise **before** the dispatch runs, so the donated cache
  tree is untouched — matching a real dispatch failure, where the caches
  are invalid wholesale and recovery must not trust any of them;
* KV corruption targets a **live** page through the same device op a real
  scrub uses, so the NaN genuinely propagates through attention into the
  logits and trips the same on-device finite guard a hardware flip would;
* pool/adapter hogs acquire through the pools' public refcounting, so
  releasing them can never unbalance accounting the leak auditor checks.
"""
from __future__ import annotations

import json
from typing import Callable, List, Optional

import numpy as np


class FaultError(RuntimeError):
    """Base class for injected faults."""


class DeviceStepFault(FaultError):
    """Injected decode-dispatch failure (device lost / launch error)."""


class CheckpointWriteFault(FaultError, OSError):
    """Injected checkpoint write failure (disk full / volume gone)."""


class FaultInjector:
    """Seeded chaos driver over a :class:`~repro.serve.scheduler.Scheduler`.

    Construct with per-step probabilities (all default 0 = inert) and pass
    as ``Scheduler(..., faults=injector)``; the scheduler calls
    :meth:`on_step` at the top of every step and routes its decode
    dispatch through :meth:`around_decode`. ``wrap_checkpoint`` is opt-in
    for checkpoint chaos.

    Args:
      seed: seeds the private RNG — equal seeds replay identical fault
        schedules against a deterministic workload.
      p_device: probability a step's decode dispatch raises
        :class:`DeviceStepFault` (before running).
      p_prefill_fault: probability any given prefill *chunk* dispatch
        raises :class:`DeviceStepFault` (before running) — the fault that
        lands on a chunk boundary mid-prefill, exercising the
        partial-prefill quarantine (chunked-prefill schedulers only;
        inert when ``ServeConfig.prefill_chunk == 0``).
      p_nan: probability one active slot's chunk is tainted non-finite
        (its ``bad`` bit set after a successful dispatch).
      p_kv_corrupt: probability a ``nan`` is written into one live KV page
        (paged engines only; no-op otherwise).
      p_pool_hog: probability the injector grabs page refs this step,
        holding them for ``1..max_hog_steps`` steps (transient memory
        pressure).
      p_adapter_hog: probability the injector pins a resident adapter slot
        for ``1..max_hog_steps`` steps (tenant burst).
      p_ckpt_fail: probability a wrapped checkpoint save's write raises
        :class:`CheckpointWriteFault`.
      max_hog_steps: upper bound on hog holding time, so injected pressure
        is always transient and a chaos run always drains.
    """

    def __init__(self, seed: int = 0, *, p_device: float = 0.0,
                 p_prefill_fault: float = 0.0,
                 p_nan: float = 0.0, p_kv_corrupt: float = 0.0,
                 p_pool_hog: float = 0.0, p_adapter_hog: float = 0.0,
                 p_ckpt_fail: float = 0.0, max_hog_steps: int = 3):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.p_device = p_device
        self.p_prefill_fault = p_prefill_fault
        self.p_nan = p_nan
        self.p_kv_corrupt = p_kv_corrupt
        self.p_pool_hog = p_pool_hog
        self.p_adapter_hog = p_adapter_hog
        self.p_ckpt_fail = p_ckpt_fail
        self.max_hog_steps = max_hog_steps
        self.trace: List[dict] = []
        self._sched = None
        # held resources: (kind, payload, steps_left)
        self._page_hogs: List[List] = []      # [ids, steps_left]
        self._adapter_hogs: List[List] = []   # [adapter_id, steps_left]
        self._armed_device = False

    # -- wiring -------------------------------------------------------------
    def attach(self, scheduler):
        """Called by the Scheduler ctor; one injector drives one scheduler."""
        self._sched = scheduler

    def _record(self, kind: str, **detail):
        self.trace.append({"step": self._sched.steps_run if self._sched
                           else -1, "fault": kind, **detail})

    # -- per-step hook ------------------------------------------------------
    def on_step(self, sched):
        """Top-of-step chaos: release expired hogs, maybe grab new ones,
        maybe corrupt a live KV page, arm a device fault for this step's
        dispatch."""
        self._tick_hogs(sched)
        if self.p_pool_hog and sched.paged \
                and self.rng.random() < self.p_pool_hog:
            self._hog_pages(sched)
        if self.p_adapter_hog and sched.apool is not None \
                and self.rng.random() < self.p_adapter_hog:
            self._hog_adapter(sched)
        if self.p_kv_corrupt and sched.paged \
                and self.rng.random() < self.p_kv_corrupt:
            self._corrupt_page(sched)
        self._armed_device = bool(self.p_device
                                  and self.rng.random() < self.p_device)

    def _tick_hogs(self, sched):
        for hog in self._page_hogs[:]:
            hog[1] -= 1
            if hog[1] <= 0:
                sched.pool.free(hog[0])
                self._record("pool_hog_release", ids=list(map(int, hog[0])))
                self._page_hogs.remove(hog)
        for hog in self._adapter_hogs[:]:
            hog[1] -= 1
            if hog[1] <= 0:
                sched.apool.release(hog[0])
                self._record("adapter_hog_release", adapter=hog[0])
                self._adapter_hogs.remove(hog)

    def _hog_pages(self, sched):
        """Grab up to half the currently-free pages for a few steps."""
        n_free = sched.pool.available()
        if n_free < 2:
            return
        n = int(self.rng.integers(1, max(2, n_free // 2 + 1)))
        ids = sched.pool.alloc(n)
        if ids is None:                      # pragma: no cover - raced
            return
        steps = int(self.rng.integers(1, self.max_hog_steps + 1))
        self._page_hogs.append([ids, steps])
        self._record("pool_hog", ids=list(map(int, ids)), steps=steps)

    def _hog_adapter(self, sched):
        """Pin one registered adapter's slot for a few steps (tenant
        burst holding residency against eviction)."""
        reg = sched._adapters
        ids = sorted(reg.ids()) if reg is not None else []
        if not ids:
            return
        aid = ids[int(self.rng.integers(0, len(ids)))]
        got = sched.apool.acquire(aid)
        if got is None:
            self._record("adapter_hog_denied", adapter=aid)
            return
        aslot, needs_load = got
        if needs_load:
            sched.engine.load_adapter(reg.folded(aid), aslot)
            sched.adapter_loads += 1
        steps = int(self.rng.integers(1, self.max_hog_steps + 1))
        self._adapter_hogs.append([aid, steps])
        self._record("adapter_hog", adapter=aid, slot=int(aslot),
                     steps=steps)

    def _corrupt_page(self, sched):
        """Write nan into one page a live request owns — the bit flip the
        on-device finite guard exists to catch."""
        live = [bid for slot in range(sched.slots)
                for bid in sched._slot_blocks[slot]]
        if not live:
            return
        bid = live[int(self.rng.integers(0, len(live)))]
        sched._caches = sched.engine.fill_blocks(
            sched._caches, [bid], float("nan"))
        self._record("kv_corrupt", block=int(bid))

    # -- decode seam --------------------------------------------------------
    def around_decode(self, sched, call: Callable):
        """Decode dispatch wrapper: raise an armed device fault *before*
        the dispatch (caches untouched), or taint one active slot's
        ``bad`` bit after a successful one."""
        if self._armed_device:
            self._armed_device = False
            self._record("device_fault")
            raise DeviceStepFault("injected device failure at decode step")
        out = call()
        if self.p_nan and self.rng.random() < self.p_nan:
            active = [s for s in range(sched.slots)
                      if sched._slot_handle[s] is not None]
            if active:
                slot = active[int(self.rng.integers(0, len(active)))]
                toks, caches, key, done, pos, bad = out
                bad = np.array(bad)
                bad[slot] = True
                self._record("nan_logits", slot=int(slot))
                out = (toks, caches, key, done, pos, bad)
        return out

    # -- prefill-chunk seam -------------------------------------------------
    def around_prefill_chunk(self, sched, slot: int, call: Callable):
        """Prefill-chunk dispatch wrapper: maybe raise a device fault
        *before* the chunk runs (caches untouched — the partial page chain
        is still wholesale suspect and must be quarantined, which is
        exactly the recovery path under test). Drawn per chunk, so a
        multi-chunk prompt faces the fault at every boundary."""
        if self.p_prefill_fault and self.rng.random() < self.p_prefill_fault:
            self._record("prefill_chunk_fault", slot=int(slot))
            raise DeviceStepFault(
                "injected device failure at prefill chunk")
        return call()

    # -- checkpoint seam ----------------------------------------------------
    def wrap_checkpoint(self, manager):
        """Patch ``manager._write`` so each save's write may raise
        :class:`CheckpointWriteFault`. Returns the manager. The patch
        composes with the manager's own cleanup/error-capture paths — a
        failed write must leave no partial step directory and must surface
        on ``wait()`` / the next ``save()``."""
        inner = manager._write

        def chaotic_write(*args, **kwargs):
            if self.rng.random() < self.p_ckpt_fail:
                self._record("ckpt_write_fail")
                raise CheckpointWriteFault(
                    "injected checkpoint write failure")
            return inner(*args, **kwargs)

        manager._write = chaotic_write
        return manager

    # -- teardown / reporting ----------------------------------------------
    def release_all(self):
        """Drop every held hog (end-of-run teardown before leak audits)."""
        sched = self._sched
        for ids, _ in self._page_hogs:
            sched.pool.free(ids)
        self._page_hogs.clear()
        for aid, _ in self._adapter_hogs:
            sched.apool.release(aid)
        self._adapter_hogs.clear()

    def quiesce(self):
        """Stop injecting entirely and release every held resource — the
        end-of-run teardown before a final drain + leak audit (an injector
        left armed would re-acquire hogs during the drain itself)."""
        self.p_device = self.p_nan = self.p_kv_corrupt = 0.0
        self.p_pool_hog = self.p_adapter_hog = self.p_ckpt_fail = 0.0
        self.p_prefill_fault = 0.0
        self._armed_device = False
        self.release_all()

    def save_trace(self, path, note: str = ""):
        """Write the fault trace as JSON with a replay command — the
        artifact CI uploads for a failing chaos seed."""
        payload = {
            "seed": self.seed,
            "replay": f"CHAOS_SEED={self.seed} python -m pytest "
                      f"tests/test_chaos.py -m slow -x -q",
            "note": note,
            "events": self.trace,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        return path
