"""Host-side memory manager for the paged KV cache.

The device side is dumb on purpose: per-layer pools of
``num_blocks × block_size`` token slots (:class:`repro.models.PagedKVCache`)
plus per-request block tables threaded through ``forward``. Everything
stateful lives here, in plain numpy/python on the host:

* **BlockPool** — allocator over physical block ids with per-block
  reference counts. A block is *in use* (ref > 0: owned by one or more
  live requests and/or the prefix index), *cached* (ref == 0 but still
  registered under a prefix hash — reusable, evicted LRU when the free
  list runs dry), or *free*.
* **Prefix index** — chained hashes of full prompt blocks → physical block
  id. Two requests whose prompts share a prefix resolve to the *same*
  physical blocks (each holding a reference), so the shared prefix is
  prefilled once and never re-computed: that is the prefix-cache hit the
  scheduler reports.
* **Copy-on-write** — :meth:`BlockPool.cow` gives a request a private copy
  of a shared block the moment it needs to write inside one (first
  divergent token landing in a block with other holders); the device-side
  block copy is issued by the engine (``Engine.copy_blocks``).

The scheduler composes these: admission allocates pages (not a fixed
per-slot lane), retirement releases them, and exhaustion preempts.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def block_hashes(tokens: np.ndarray, block_size: int,
                 salt: bytes = b"") -> List[bytes]:
    """Chained content hashes, one per *full* block of ``tokens``.

    Hash i commits to tokens[0 : (i+1) * block_size] — chaining via the
    previous digest, so a block only ever matches behind its exact prefix.
    ``salt`` seeds the chain: contexts whose KV is *not* interchangeable
    for identical tokens (different LoRA adapters rewrite the K/V
    projections) must salt with their identity, or a prefix hit would
    serve another adapter's KV.
    """
    out: List[bytes] = []
    prev = salt
    for i in range(len(tokens) // block_size):
        h = hashlib.sha1()
        h.update(prev)
        h.update(np.ascontiguousarray(
            tokens[i * block_size:(i + 1) * block_size], np.int32).tobytes())
        prev = h.digest()
        out.append(prev)
    return out


class BlockPool:
    """Ref-counted allocator + prefix index over ``num_blocks`` physical ids.

    Valid ids are ``0 .. num_blocks - 1``; ``num_blocks`` itself is the
    device-side sentinel for unmapped block-table entries (its writes drop,
    its reads clamp and are masked).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"bad pool geometry: {num_blocks}x{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.sentinel = num_blocks
        self.ref = np.zeros(num_blocks, np.int32)
        self._free: deque = deque(range(num_blocks))
        # hash -> block id (live or cached); insertion order = LRU for the
        # cached subset
        self._by_hash: "OrderedDict[bytes, int]" = OrderedDict()
        self._hash_of: Dict[int, bytes] = {}
        self.evictions = 0

    # -- accounting --------------------------------------------------------
    @property
    def cached(self) -> int:
        """Blocks held only by the prefix index (evictable)."""
        return sum(1 for bid in self._by_hash.values() if self.ref[bid] == 0)

    def available(self) -> int:
        """Blocks an ``alloc`` could hand out right now (free + evictable)."""
        return len(self._free) + self.cached

    def live(self) -> int:
        return int((self.ref > 0).sum())

    # -- alloc / free ------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh blocks with ref = 1, or None (atomic: all or none).

        Prefers the free list; evicts least-recently-registered cached
        prefix blocks when it runs dry.
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        if self.available() < n:
            return None
        out: List[int] = []
        while len(out) < n:
            if self._free:
                bid = self._free.popleft()
            else:
                bid = self._evict_one()
            self.ref[bid] = 1
            out.append(bid)
        return out

    def _evict_one(self) -> int:
        for h, bid in self._by_hash.items():     # insertion order = LRU
            if self.ref[bid] == 0:
                del self._by_hash[h]
                del self._hash_of[bid]
                self.evictions += 1
                return bid
        raise RuntimeError("evict with no cached blocks")   # pragma: no cover

    def incref(self, ids: Sequence[int]):
        for bid in ids:
            if self.ref[bid] == 0 and bid not in self._hash_of:
                raise ValueError(f"incref of free block {bid}")
            self.ref[bid] += 1

    def free(self, ids: Sequence[int]):
        """Drop one reference per id. A block at ref 0 returns to the free
        list unless the prefix index still knows it — then it lingers as an
        evictable cache entry (that's what makes retire-then-resubmit of
        the same prompt a prefix hit)."""
        for bid in ids:
            if self.ref[bid] <= 0:
                raise ValueError(f"double free of block {bid}")
            self.ref[bid] -= 1
            if self.ref[bid] == 0 and bid not in self._hash_of:
                self._free.append(bid)

    # -- prefix cache ------------------------------------------------------
    def match_prefix(self, tokens: np.ndarray,
                     salt: bytes = b"") -> Tuple[List[int], int]:
        """Longest chain of cached full blocks matching ``tokens``.

        Returns (physical ids with a reference taken per id, tokens
        covered). May cover the *whole* prompt when its length is
        block-aligned and fully cached — the scheduler then still has to
        re-prefill the final token for its logits, copy-on-writing the last
        shared block before that write (see ``Scheduler._admit``).
        ``salt`` isolates hash chains whose KV is not interchangeable
        (per-adapter prefixes).
        """
        ids: List[int] = []
        for h in block_hashes(tokens, self.block_size, salt):
            bid = self._by_hash.get(h)
            if bid is None:
                break
            self._by_hash.move_to_end(h)         # LRU touch
            self.ref[bid] += 1
            ids.append(bid)
        return ids, len(ids) * self.block_size

    def register_prefix(self, tokens: np.ndarray, table: Sequence[int],
                        salt: bytes = b""):
        """Index ``tokens``' full blocks (backed by ``table``'s physical
        ids) for future sharing. Idempotent per content hash; the index
        holds no reference of its own — a block becomes evictable once its
        holders free it. A block re-registered under *new* content (its
        holder rewrote it) is re-pointed: the stale hash entry is dropped
        so the hash↔block mapping stays a bijection — otherwise eviction
        through the stale entry could hand the block out while the fresh
        entry still resolves to it."""
        for i, h in enumerate(block_hashes(tokens, self.block_size, salt)):
            bid = int(table[i])
            if bid >= self.num_blocks:           # sentinel: nothing mapped
                break
            stale = self._hash_of.get(bid)
            if h in self._by_hash:
                if self._by_hash[h] != bid and stale is not None \
                        and stale != h:
                    # this block's content changed AND the new content is
                    # already indexed via another block: drop this block's
                    # stale alias too (it would serve the wrong KV)
                    self._unindex(bid, stale)
                continue                          # content already indexed
            if stale is not None:
                del self._by_hash[stale]
            self._by_hash[h] = bid
            self._hash_of[bid] = h

    def invalidate(self, ids: Sequence[int]):
        """Drop the prefix-index entries of ``ids`` (quarantine).

        A corrupted page must never be served as a prefix hit: the
        scheduler invalidates a poisoned request's chain *before* freeing
        its pages, so the content hashes stop resolving and the blocks go
        back to the free list instead of lingering as evictable cache.
        Ids that aren't indexed are ignored; refcounts are untouched."""
        for bid in ids:
            h = self._hash_of.get(bid)
            if h is not None:
                self._unindex(bid, h)

    def _unindex(self, bid: int, h: bytes):
        """Drop ``bid``'s index entry; an unreferenced block must not be
        stranded (neither free nor cached), so it returns to the free
        list."""
        del self._by_hash[h]
        del self._hash_of[bid]
        if self.ref[bid] == 0:
            self._free.append(bid)

    # -- copy-on-write -----------------------------------------------------
    def cow(self, bid: int) -> Optional[int]:
        """A privately-owned id for writing "into" shared block ``bid``.

        If the caller is the only holder and the block isn't indexed, the
        block is already private: returns ``bid``. Otherwise allocates a
        fresh block (caller must issue the device copy src → dst and drop
        its reference on ``bid``). None when the pool can't supply one.
        """
        if self.ref[bid] == 1 and bid not in self._hash_of:
            return bid
        got = self.alloc(1)
        return got[0] if got is not None else None
