"""Request lifecycle: terminal status machine, deadlines, leak checks.

Production serving is judged on what happens when things go wrong. Before
this module a request had exactly two observable states (``done`` or not),
no way to be given up on, and the scheduler had no vocabulary for "this
request was shed / timed out / hit a device fault". Now every submitted
request walks an explicit state machine and **always** reaches a terminal
status — the invariant the chaos suite (``tests/test_chaos.py``) pins:

::

                 submit()
                    │ (capacity / queue_cap shed)──────────► REJECTED
                    ▼
                 QUEUED ──(cancel)────────────────────────► CANCELLED
                 │  ▲ │ ──(ttft / total deadline)─────────► TIMED_OUT
        admitted │  │ │ ──(no-progress detector)──────────► FAILED
                 ▼  │ preempt / quarantine / device fault
                RUNNING ──(cancel)────────────────────────► CANCELLED
         (incl.     │ ──(total / ttft deadline)───────────► TIMED_OUT
          mid-      │ ──(fault retries exhausted)─────────► FAILED
          prefill)  ▼ (EOS / budget)
                COMPLETED

Preemption (page exhaustion), quarantine (non-finite logits) and device
faults bounce a RUNNING request back to QUEUED — those are *recoverable*
and resume token-exactly through the re-prefill machinery; only the five
states on the right are terminal.

With chunked prefill (``ServeConfig(prefill_chunk > 0)``) RUNNING covers
a **mid-prefill** sub-state: the request holds a slot (and its pages) but
has emitted no token yet while its prompt prefills chunk-by-chunk. Every
transition out of RUNNING applies between chunks too — cancellation and
the **TTFT deadline** are checked at each chunk boundary (a long prompt
can no longer sail past ``ttft_ms`` inside one admission call), an
injected/real device fault at a chunk boundary quarantines the partial
page chain and re-queues the request (bounded retries, token-exact
resume), and ``snapshot()`` serializes a half-prefilled request exactly
like a preempted one (no tokens yet ⇒ restore simply re-prefills).

:class:`RequestHandle` (moved here from ``serve.scheduler``) is the
caller's view: ``poll()`` streams deltas, ``status`` / ``error`` report
the outcome, ``cancel()`` requests teardown at the next chunk boundary.

:func:`check_drained` / :func:`assert_drained` are the leak auditors —
after any drain (including chaos runs) the scheduler must hold zero
pages, zero adapter references and zero occupied slots. They are part of
the library, not the tests, so operators can assert them in production
drains too.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from .telemetry import RequestTiming


class RequestStatus(enum.Enum):
    """Lifecycle states. The five right-column states are terminal."""

    QUEUED = "queued"          # submitted, waiting for slot/pages/adapter
    RUNNING = "running"        # admitted: occupies a slot + cache pages
    COMPLETED = "completed"    # emitted EOS or exhausted max_new_tokens
    CANCELLED = "cancelled"    # caller cancel()ed before completion
    TIMED_OUT = "timed_out"    # missed its TTFT or total deadline
    REJECTED = "rejected"      # shed at submit (capacity / queue bound)
    FAILED = "failed"          # unrecoverable fault (retries exhausted /
    #                            scheduler stalled)


TERMINAL_STATUSES = frozenset({
    RequestStatus.COMPLETED, RequestStatus.CANCELLED,
    RequestStatus.TIMED_OUT, RequestStatus.REJECTED, RequestStatus.FAILED,
})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [len] int32 token ids
    max_new_tokens: int
    adapter_id: Optional[str] = None   # None = serve the quantized base
    ttft_ms: Optional[float] = None    # deadline to FIRST token (queued)
    deadline_ms: Optional[float] = None  # total deadline (queued + running)


class RequestHandle:
    """Streaming view of one request's generation.

    Attributes:
      tokens: the full generation so far — plain python ints (EOS included
        when one was emitted). Grows between ``Scheduler.step()`` calls.
      status: the :class:`RequestStatus` lifecycle state. Every handle
        eventually reaches a terminal status — including rejected, timed
        out and cancelled ones.
      error: human-readable reason for REJECTED / TIMED_OUT / FAILED
        terminals (None otherwise).
      done: True once ``status`` is terminal. A done handle no longer
        occupies a slot, cache pages or an adapter reference. Partial
        tokens of a cancelled/timed-out request stay readable.
    """

    def __init__(self, request: Request):
        self.request = request
        self.tokens: List[int] = []
        self.status = RequestStatus.QUEUED
        self.error: Optional[str] = None
        self.fault_retries = 0        # quarantines + device faults survived
        self.submitted_at: float = 0.0  # scheduler clock at submit/restore
        self.timing = RequestTiming()   # latency trace (scheduler-stamped)
        self._cursor = 0
        self._cancel_requested = False
        self._stats_fn = None         # set by the scheduler at submit

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    def cancel(self):
        """Request cancellation. Takes effect at the next scheduler step
        (chunk boundary): a queued request leaves the queue, a running one
        releases its slot/pages; either way the handle terminates as
        CANCELLED with its partial tokens intact. No-op on a handle that
        already reached a terminal status. Safe to call repeatedly."""
        if not self.done:
            self._cancel_requested = True

    def _finish(self, status: RequestStatus, error: Optional[str] = None):
        """Terminal transition (scheduler-internal). Idempotent guard: a
        handle never leaves a terminal status."""
        assert status in TERMINAL_STATUSES, status
        if self.done:                 # pragma: no cover - defensive
            return
        self.status = status
        self.error = error

    def poll(self, with_stats: bool = False):
        """Tokens generated since the last ``poll()``.

        Returns a (possibly empty) list of int token ids. Empty while the
        request is queued or between chunks; after the handle reaches a
        terminal status, the first ``poll()`` drains the remaining delta
        and subsequent calls return ``[]`` forever — polling a finished
        handle is safe and idempotent.

        With ``with_stats=True`` returns ``(delta, stats)`` where ``stats``
        is a telemetry snapshot for this request's adapter: its id, its
        per-adapter ``prefix_hit_rate``, and the scheduler's adapter-pool
        counters (occupancy / hits / misses / evictions / loads). Requests
        without an adapter (and adapter-free schedulers) report the base
        view — ``adapter_id`` None and zeroed pool counters.
        """
        delta = self.tokens[self._cursor:]
        self._cursor = len(self.tokens)
        if not with_stats:
            return delta
        stats = self._stats_fn() if self._stats_fn is not None else {
            "adapter_id": None, "adapter_prefix_hit_rate": 0.0,
            "adapter_loads": 0, "capacity": 0, "resident": 0, "live": 0,
            "occupancy": 0.0, "hits": 0, "misses": 0, "evictions": 0}
        return delta, stats


# ---------------------------------------------------------------------------
# Leak auditing
# ---------------------------------------------------------------------------

def check_drained(scheduler) -> List[str]:
    """Audit a drained scheduler for leaked resources.

    Returns a list of human-readable violations (empty = clean). To be
    called once ``scheduler.pending == 0`` — after any drain, including
    one that suffered cancellations, timeouts, preemptions, quarantines
    and injected faults, the scheduler must be back at baseline:

    * no occupied batch slots, every slot marked free (``done``);
    * no queued handles, and every handle ever submitted terminal;
    * paged: zero live pages, every page free or evictable
      (``available() == num_blocks``), no negative refcounts, block
      tables all-sentinel;
    * adapters: zero live adapter references.
    """
    out: List[str] = []
    if scheduler._queue:
        out.append(f"queue not drained: {len(scheduler._queue)} handles")
    occupied = [s for s, h in enumerate(scheduler._slot_handle)
                if h is not None]
    if occupied:
        out.append(f"slots still occupied: {occupied}")
    free_mask = np.asarray(scheduler._done)
    if not bool(free_mask.all()):
        out.append(f"slot done-mask not all free: {free_mask.tolist()}")
    prefilling = [s for s, p in enumerate(
        getattr(scheduler, "_prefill_prompt", ())) if p is not None]
    if prefilling:
        out.append(f"slots still mid-prefill: {prefilling}")
    for h in getattr(scheduler, "_live_handles", ()):
        out.append(f"request {h.request.rid} non-terminal: {h.status}")
    if scheduler.paged:
        pool = scheduler.pool
        if pool.live() != 0:
            out.append(f"leaked pages: {pool.live()} live "
                       f"(refs {np.flatnonzero(pool.ref > 0).tolist()})")
        if (pool.ref < 0).any():
            out.append(f"negative page refcounts: "
                       f"{np.flatnonzero(pool.ref < 0).tolist()}")
        if pool.available() != pool.num_blocks:
            out.append(f"pool not at baseline: {pool.available()} of "
                       f"{pool.num_blocks} blocks available")
        tables = np.asarray(scheduler._tables)
        if not bool((tables == pool.sentinel).all()):
            out.append("block tables not all-sentinel after drain")
        if any(scheduler._slot_blocks[s] for s in range(scheduler.slots)):
            out.append("slot block lists not empty after drain")
    if scheduler.apool is not None:
        ap = scheduler.apool
        if ap.live() != 0:
            out.append(f"leaked adapter refs: {ap.live()} live")
        issues = ap.verify()
        out.extend(f"adapter pool: {msg}" for msg in issues)
    return out


def assert_drained(scheduler):
    """Raise AssertionError listing every leak ``check_drained`` found."""
    issues = check_drained(scheduler)
    assert not issues, "scheduler drain leaked resources:\n  " + \
        "\n  ".join(issues)
