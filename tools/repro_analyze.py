#!/usr/bin/env python
"""repro-analyze: JAX/Pallas hazard lint + static kernel-contract checks.

Runs two stdlib-only passes over the tree (no device, no third-party
deps — ruff covers generic Python hygiene in CI):

1. the AST lint of ``repro.analysis.rules`` (RA001–RA005: hot-path host
   syncs, traced side effects, donation hazards, retrace bombs,
   unordered-set pytrees), with ``# repro: noqa[RULE]`` suppression;
2. the kernel-contract checker of ``repro.analysis.contracts``
   (KC001–KC005: VMEM budgets, divisibility, dtype contracts, pallas_call
   registry, cost-model consistency) over the full tuning candidate
   cross-product.

Exit status: 0 when clean, 1 when any finding survives (``--strict`` is
the default and is accepted for CI-readability). ``--json`` emits a
machine-readable report. Rule catalogue: docs/static_analysis.md.

Usage::

    PYTHONPATH=src python tools/repro_analyze.py --strict
    PYTHONPATH=src python tools/repro_analyze.py --json out.json src/repro
"""
from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis.contracts import CONTRACT_RULES, check_kernel_contracts  # noqa: E402
from repro.analysis.findings import findings_to_json  # noqa: E402
from repro.analysis.lint import lint_tree  # noqa: E402
from repro.analysis.rules import RULES  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("root", nargs="?",
                        default=os.path.join(REPO_ROOT, "src", "repro"),
                        help="tree to lint (default: src/repro)")
    parser.add_argument("--strict", action="store_true",
                        help="exit nonzero on any finding (the default; "
                             "kept explicit for CI readability)")
    parser.add_argument("--json", metavar="PATH",
                        help="write findings as JSON ('-' for stdout)")
    parser.add_argument("--no-lint", action="store_true",
                        help="skip the AST lint pass")
    parser.add_argument("--no-contracts", action="store_true",
                        help="skip the kernel-contract pass")
    parser.add_argument("--rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.rules:
        for rule, desc in sorted({**RULES, **CONTRACT_RULES}.items()):
            print(f"{rule}  {desc}")
        return 0

    findings = []
    if not args.no_lint:
        findings += lint_tree(args.root)
    if not args.no_contracts:
        kernels_dir = os.path.join(args.root, "kernels")
        if os.path.isdir(kernels_dir):
            findings += check_kernel_contracts(kernels_dir)

    if args.json:
        doc = findings_to_json(findings, root=os.path.relpath(
            args.root, REPO_ROOT))
        if args.json == "-":
            print(doc)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(doc + "\n")

    for f in findings:
        print(f.format())
    n = len(findings)
    print(f"repro-analyze: {n} finding{'s' if n != 1 else ''}"
          f"{'' if n else ' — clean'}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
