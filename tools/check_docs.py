#!/usr/bin/env python
"""Docs CI gate: internal links must resolve, quickstart commands must run.

Two checks, so the docs cannot silently rot as the code moves:

1. **Link check** (always): every markdown link and bare file reference in
   ``README.md`` and ``docs/*.md`` that points inside the repo must exist;
   ``#anchor`` fragments must match a heading (GitHub slug rules) in the
   target file. External (http/https/mailto) links are skipped — CI has no
   business depending on the network.
2. **Rule-catalogue check** (always): every analyzer rule ID declared in
   ``repro.analysis`` (``RA…``/``KC…``) must have a matching heading in
   ``docs/static_analysis.md``, and every documented rule must exist in
   the code — findings point users at the catalogue, so it cannot rot.
3. **Quickstart smoke** (``--run-quickstart``): every ``PYTHONPATH=src
   python …`` command inside the README's ```bash fences is executed from
   the repo root and must exit 0. The README is written so each command is
   seconds-to-a-minute scale (``--smoke`` flags, synthetic data); a
   command that regenerates the checked-in baseline is redirected to a
   scratch path first.

Usage:
    python tools/check_docs.py [--run-quickstart]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)


def _doc_files():
    docs = [os.path.join(ROOT, "README.md")]
    ddir = os.path.join(ROOT, "docs")
    docs += sorted(os.path.join(ddir, f) for f in os.listdir(ddir)
                   if f.endswith(".md"))
    return [d for d in docs if os.path.exists(d)]


def _strip_fences(text: str) -> str:
    return FENCE_RE.sub("", text)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path) as f:
        return {_slug(m.group(1))
                for m in HEADING_RE.finditer(_strip_fences(f.read()))}


def check_links() -> list:
    errors = []
    for doc in _doc_files():
        with open(doc) as f:
            body = _strip_fences(f.read())
        rel = os.path.relpath(doc, ROOT)
        for m in LINK_RE.finditer(body):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, frag = target.partition("#")
            if path:
                full = os.path.normpath(
                    os.path.join(os.path.dirname(doc), path))
                if not os.path.exists(full):
                    errors.append(f"{rel}: broken link → {target}")
                    continue
            else:
                full = doc
            if frag and full.endswith(".md"):
                if _slug(frag) not in _anchors(full):
                    errors.append(f"{rel}: missing anchor → {target}")
        # bare inline-code references to repo paths (`src/…`, `docs/…`,
        # `benchmarks/…`, `tests/…`, `examples/…`) must exist too
        for m in re.finditer(
                r"`((?:src|docs|benchmarks|tests|examples|tools)/"
                r"[\w\-./]+?\.(?:py|md|json))`", body):
            if not os.path.exists(os.path.join(ROOT, m.group(1))):
                errors.append(f"{rel}: dangling path reference "
                              f"`{m.group(1)}`")
    return errors


def check_rule_anchors() -> list:
    """Every analyzer rule ID (RA…/KC… in repro.analysis) must have its
    own heading in docs/static_analysis.md — the catalogue the findings
    point users at cannot silently fall behind the code."""
    errors = []
    rule_ids = set()
    for mod in ("rules.py", "contracts.py"):
        path = os.path.join(ROOT, "src", "repro", "analysis", mod)
        with open(path) as f:
            # only catalogue keys ("RA001": …), not IDs in prose
            rule_ids |= set(re.findall(r'"([A-Z]{2}\d{3})":', f.read()))
    if not rule_ids:
        return ["repro.analysis: no rule IDs found — catalogue check "
                "would be vacuous"]
    doc = os.path.join(ROOT, "docs", "static_analysis.md")
    if not os.path.exists(doc):
        return ["docs/static_analysis.md missing (the rule catalogue)"]
    with open(doc) as f:
        headed = {m.group(1) for m in re.finditer(
            r"^#{1,6}\s+([A-Z]{2}\d{3})\b", _strip_fences(f.read()),
            re.MULTILINE)}
    for rule in sorted(rule_ids - headed):
        errors.append(f"docs/static_analysis.md: rule {rule} has no "
                      f"'### {rule} — …' heading")
    for rule in sorted(headed - rule_ids):
        errors.append(f"docs/static_analysis.md: heading for {rule} but "
                      f"no such rule in repro.analysis")
    return errors


def quickstart_commands() -> list:
    with open(os.path.join(ROOT, "README.md")) as f:
        text = f.read()
    cmds = []
    for lang, body in FENCE_RE.findall(text):
        if lang != "bash":
            continue
        for line in body.splitlines():
            line = line.strip()
            if line.startswith("PYTHONPATH=src python"):
                cmds.append(line)
    return cmds


def run_quickstart() -> list:
    errors = []
    scratch = tempfile.mkdtemp(prefix="check_docs_")
    for cmd in quickstart_commands():
        runnable = cmd
        if "-m pytest" in cmd:
            # the tier-1 suite is the tests job's 20-minute gate; the docs
            # job only verifies the command is documented, not rerun
            print(f"[check_docs] skip (tests job): {cmd}", flush=True)
            continue
        # never let a documented command clobber the checked-in baseline:
        # full-bench invocations are exercised against a scratch output
        if ("serve_bench" in cmd or "kernels_bench" in cmd) \
                and "--validate" not in cmd:
            if "--smoke" not in cmd:
                runnable = cmd + " --smoke"
            if "--out" not in cmd:
                runnable = runnable + f" --out {scratch}/bench.json"
            else:
                runnable = re.sub(r"(--out)\s+(\S+)",
                                  rf"\1 {scratch}/\2", runnable)
        print(f"[check_docs] $ {runnable}", flush=True)
        proc = subprocess.run(runnable, shell=True, cwd=ROOT)
        if proc.returncode != 0:
            errors.append(f"quickstart command failed "
                          f"(exit {proc.returncode}): {cmd}")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="also execute the README quickstart commands "
                         "(smoke-scale) from the repo root")
    args = ap.parse_args()
    errors = check_links() + check_rule_anchors()
    n_cmds = len(quickstart_commands())
    if n_cmds == 0:
        errors.append("README.md: no PYTHONPATH=src quickstart commands "
                      "found — the smoke gate would be vacuous")
    if args.run_quickstart and not errors:
        errors += run_quickstart()
    if errors:
        print("\n".join(f"ERROR: {e}" for e in errors), file=sys.stderr)
        sys.exit(1)
    docs = ", ".join(os.path.relpath(d, ROOT) for d in _doc_files())
    print(f"docs OK: links resolve in {docs}; "
          f"{n_cmds} quickstart commands"
          + (" ran clean" if args.run_quickstart else " found"))


if __name__ == "__main__":
    main()
