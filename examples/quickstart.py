"""Quickstart: quantize one linear layer with ASER and inspect the error.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (AserConfig, gram, layer_forward, lorc, l2qer,
                        quantize_layer)
from repro.core.metrics import relative_output_error
from repro.core.quantizers import A8, W4, fake_quant_activation, fake_quant_weight


def main():
    rng = np.random.default_rng(0)
    d_in, d_out, tokens = 512, 384, 4096

    # a weight matrix and activations with outlier channels (LLM-like)
    w = jnp.asarray(rng.normal(size=(d_out, d_in)).astype(np.float32))
    x = rng.normal(size=(d_in, tokens)).astype(np.float32)
    x[rng.choice(d_in, 8, replace=False)] *= 15.0
    x = jnp.asarray(x)

    g = gram(x)
    xbar = jnp.mean(jnp.abs(x), axis=1)

    print("=== W4A8 per-channel quantization of one linear layer ===")
    wq = fake_quant_weight(w, W4)
    print(f"RTN            rel output error: "
          f"{float(relative_output_error(w, wq, x)):.4f}")

    c = lorc(w - wq, 32)
    print(f"LoRC  (r=32)   rel output error: "
          f"{float(relative_output_error(w, wq + c.l_a @ c.l_b, x)):.4f}")

    c = l2qer(w - wq, xbar, 32)
    print(f"L²QER (r=32)   rel output error: "
          f"{float(relative_output_error(w, wq + c.l_a @ c.l_b, x)):.4f}")

    for smooth in (False, True):
        layer = quantize_layer(w, g, xbar, AserConfig(rank=32, smooth=smooth,
                                                      outlier_f=16))
        y = layer_forward(layer, x,
                          act_fake_quant=lambda t: fake_quant_activation(t, A8))
        err = float(jnp.linalg.norm(y - w @ x) / jnp.linalg.norm(w @ x))
        tag = "w/ A.S." if smooth else "w/o A.S."
        print(f"ASER {tag} (r=32, W4A8) rel output error: {err:.4f}")


if __name__ == "__main__":
    main()
