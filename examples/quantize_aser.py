"""Quantize a trained model with ASER and every baseline; print the Table-1
style comparison.

Demonstrates the recipe API end to end: resolve every legacy method name to
a QuantRecipe, quantize once per recipe, then evaluate under explicit
per-deployment RuntimeConfigs (no process-global state).

    PYTHONPATH=src python examples/quantize_aser.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (eval_acc, eval_ppl, get_tape,
                               get_trained_model)
from repro.quant import quantize_model, registry
from repro.runtime import RuntimeConfig


def main():
    cfg, params, corpus = get_trained_model("llama", steps=300)
    tape = get_tape(cfg, params, corpus)
    print(f"{'method':14s} {'W':>3s} {'A':>3s} {'ppl':>9s} {'acc%':>7s}")
    ppl = eval_ppl(cfg, params, corpus)
    acc = eval_acc(cfg, params, corpus)
    print(f"{'fp16':14s} {16:3d} {16:3d} {ppl:9.3f} {acc:7.2f}")
    for method in ("llmint4", "smoothquant", "gptq", "awq",
                   "lorc", "l2qer", "aser", "aser_as"):
        recipe = registry.resolve(method, rank=16, outlier_f=16)
        qp = quantize_model(params, tape, recipe)
        for a_bits in (8, 6):
            rt = RuntimeConfig(a_bits=a_bits)
            ppl = eval_ppl(cfg, qp, corpus, rt=rt)
            acc = eval_acc(cfg, qp, corpus, rt=rt)
            print(f"{method:14s} {4:3d} {a_bits:3d} {ppl:9.3f} {acc:7.2f}")


if __name__ == "__main__":
    main()
