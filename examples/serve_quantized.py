"""Serve a W4A8+ASER-quantized model with batched requests (KV-cache engine),
comparing generations against the fp reference.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from benchmarks.common import get_tape, get_trained_model
from repro.kernels import ops
from repro.quant import PTQConfig, quantize_model
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg, params, corpus = get_trained_model("llama", steps=300)
    tape = get_tape(cfg, params, corpus)
    qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=16,
                                                outlier_f=16))

    prompts = corpus.sample(jnp.asarray(31337), 4, 12)
    scfg = ServeConfig(max_len=64)

    fp_engine = Engine(params, cfg, scfg)
    fp_out = fp_engine.generate(prompts, n_steps=16)

    ops.set_act_bits(8)
    q_engine = Engine(qp, cfg, scfg)
    q_out = q_engine.generate(prompts, n_steps=16)

    match = float(jnp.mean((fp_out == q_out).astype(jnp.float32)))
    print("fp16 generations:\n", fp_out)
    print("W4A8+ASER generations:\n", q_out)
    print(f"token agreement: {100*match:.1f}%")

    # optional: exercise the Pallas kernel path (interpret mode on CPU)
    ops.use_pallas(True)
    q_out_pl = Engine(qp, cfg, scfg).generate(prompts[:1], n_steps=4)
    ops.use_pallas(False)
    print("pallas-path sample:", q_out_pl)


if __name__ == "__main__":
    main()
