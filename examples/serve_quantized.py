"""Serve a W4A8+ASER-quantized model with batched requests (KV-cache engine),
comparing generations against the fp reference.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from benchmarks.common import get_tape, get_trained_model
from repro.quant import quantize_model, registry
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg, params, corpus = get_trained_model("llama", steps=300)
    tape = get_tape(cfg, params, corpus)
    recipe = registry.resolve("aser_as", rank=16, outlier_f=16)
    qp = quantize_model(params, tape, recipe)

    prompts = corpus.sample(jnp.asarray(31337), 4, 12)
    scfg = ServeConfig(max_len=64)

    fp_engine = Engine(params, cfg, scfg)
    fp_out = fp_engine.generate(prompts, n_steps=16)

    # the recipe records its serving setup: act.runtime() → RuntimeConfig
    q_engine = Engine(qp, cfg, scfg, rt=recipe.act.runtime())
    q_out = q_engine.generate(prompts, n_steps=16)

    match = float(jnp.mean((fp_out == q_out).astype(jnp.float32)))
    print("fp16 generations:\n", fp_out)
    print("W4A8+ASER generations:\n", q_out)
    print(f"token agreement: {100*match:.1f}%")

    # exercise the Pallas kernel path (interpret mode on CPU) — just another
    # engine with its own RuntimeConfig, no process-global toggles
    rt_pl = recipe.act.runtime(use_pallas=True)
    q_out_pl = Engine(qp, cfg, scfg, rt=rt_pl).generate(prompts[:1], n_steps=4)
    print("pallas-path sample:", q_out_pl)


if __name__ == "__main__":
    main()
