"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic corpus with checkpointing, then report eval PPL.

    PYTHONPATH=src python examples/train_small.py --steps 200 --d-model 768

The default configuration (768 × 12L) is ~100M params; on CPU use
``--d-model 256 --layers 4 --steps 100`` for a quick run.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.core.metrics import perplexity
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import forward, init_params, param_count
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="results/train_small")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3_8b").reduced(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1), n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=args.d_model * 3, vocab_size=args.vocab,
        dtype="float32")
    import dataclasses
    cfg = dataclasses.replace(cfg, remat=False)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {param_count(params)/1e6:.1f}M params "
          f"(entropy floor ppl ≈ {corpus.entropy_floor():.2f})")
    opt = init_opt_state(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)

    # fault tolerance: auto-resume from the latest checkpoint
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        start, st = mgr.restore_latest({"params": params, "opt": opt})
        params, opt = st["params"], st["opt"]
        print(f"resumed from step {start}")

    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=args.steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {"tokens": corpus.sample(jnp.asarray(i), args.batch,
                                         args.seq + 1)}
        params, opt, m = step_fn(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} "
                  f"({(time.time()-t0):.0f}s)")
        if i and i % args.ckpt_every == 0:
            mgr.save(i, {"params": params, "opt": opt})
    mgr.save(args.steps, {"params": params, "opt": opt})
    mgr.wait()

    toks = corpus.sample(jnp.asarray(10_000), args.batch, args.seq)
    lg, _, _ = forward(params, cfg, toks)
    print(f"eval ppl: {float(perplexity(lg[:, :-1], toks[:, 1:])):.3f}")


if __name__ == "__main__":
    main()
