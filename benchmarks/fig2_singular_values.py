"""Fig. 2: singular value distribution of E_q vs E_q·X per linear layer.

Validates the paper's core observation: the *activation-weighted* error
E_q·X is markedly lower-rank than the raw weight error E_q.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.quantizers import W4, fake_quant_weight
from repro.core.whitening import effective_rank
from .common import get_tape, get_trained_model, save_json


def run(verbose=True):
    cfg, params, corpus = get_trained_model("llama")
    toks = corpus.sample(jnp.asarray(5000), 8, 64)
    from repro.models import forward
    tape = {}
    forward(params, cfg, toks, tape=tape)

    out = {}
    gidx = cfg.n_layers // 2  # a middle layer, like the paper's layer 30
    block_tape = tape["groups"]["b0"]
    names = {"qkv_proj": ("attn", "wq"), "out_proj": ("attn", "wo"),
             "fc1": ("mlp", "gate"), "fc2": ("mlp", "down")}
    for label, (mod, leaf) in names.items():
        st = block_tape[mod][leaf]
        g = np.asarray(st.gram)[gidx]
        blk = params["groups"][0]
        w = np.asarray(blk[mod][leaf]["w"])[gidx].T       # [out, in]
        wq = np.asarray(fake_quant_weight(jnp.asarray(w), W4))
        e = w - wq
        sig_w = np.linalg.svd(e, compute_uv=False)
        # E_q X singular values via E G Eᵀ eigenvalues (X up to rotation)
        m = e @ g @ e.T
        eig = np.sqrt(np.maximum(np.linalg.eigvalsh(m), 0))[::-1]
        topk = 128
        out[label] = {
            "sv_weight_error": (sig_w[:topk] / sig_w[0]).tolist(),
            "sv_actweighted_error": (eig[:topk] / eig[0]).tolist(),
            "eff_rank_weight": float(effective_rank(jnp.asarray(sig_w))),
            "eff_rank_actweighted": float(effective_rank(jnp.asarray(eig))),
        }
        if verbose:
            print(f"  {label:10s} eff_rank(E_q)={out[label]['eff_rank_weight']:.1f} "
                  f"eff_rank(E_qX)={out[label]['eff_rank_actweighted']:.1f}")
    # the paper's claim: activation-weighted error is lower-rank
    lower = sum(out[k]["eff_rank_actweighted"] < out[k]["eff_rank_weight"]
                for k in out)
    out["claim_lower_rank_count"] = lower
    save_json("fig2_singular_values", out)
    assert lower >= 3, "E_qX should be lower-rank than E_q for most layers"
    return out


if __name__ == "__main__":
    run()
