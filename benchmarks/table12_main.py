"""Tables 1-2: main W4A8 / W4A6 comparison across PTQ methods on the two
paper model families (llama-like, qwen-like).

Models get the adapted-outlier treatment (see fig5_w8ax.outlier_model):
briefly-trained synthetic models have no LLM-style activation outliers, and
without them every compensation method ties within ~0.005 PPL — the paper's
separations only exist in the outlier regime its LLaMA/Qwen checkpoints
inhabit."""
import jax.numpy as jnp

from repro.models import forward
from repro.quant import quantize_model, registry
from repro.runtime import RuntimeConfig
from .common import (eval_acc, eval_ppl, get_tape, get_trained_model,
                     save_json)
from .fig5_w8ax import outlier_model

METHODS = ["llmint4", "smoothquant", "lorc", "l2qer", "aser", "aser_as"]


def run_model(name: str, verbose=True):
    cfg, params, corpus = get_trained_model(name)
    params = outlier_model(cfg, params, corpus, seed=hash(name) % 1000)
    tape = get_tape(cfg, params, corpus)
    rt16 = RuntimeConfig(a_bits=16)
    rows = [{"model": name, "method": "fp16", "w": 16, "a": 16,
             "ppl": eval_ppl(cfg, params, corpus, rt=rt16),
             "acc": eval_acc(cfg, params, corpus, rt=rt16)}]
    if verbose:
        print(f"  {name} fp16 ppl={rows[0]['ppl']:8.3f} acc={rows[0]['acc']:6.2f}")
    cache = {m: quantize_model(params, tape,
                               registry.resolve(m, rank=48, outlier_f=16))
             for m in METHODS}
    for a_bits in (8, 6):
        rt = RuntimeConfig(a_bits=a_bits)
        for method in METHODS:
            qp = cache[method]
            ppl = eval_ppl(cfg, qp, corpus, rt=rt)
            acc = eval_acc(cfg, qp, corpus, rt=rt)
            rows.append({"model": name, "method": method, "w": 4,
                         "a": a_bits, "ppl": ppl, "acc": acc})
            if verbose:
                print(f"  {name} W4A{a_bits} {method:12s} "
                      f"ppl={ppl:8.3f} acc={acc:6.2f}")
    return rows


def run(verbose=True):
    rows = run_model("llama", verbose) + run_model("qwen", verbose)
    save_json("table12_main", rows)

    # paper-claim checks: ASER best PPL among quantized; A.S. helps at A6
    for model in ("llama", "qwen"):
        for a in (8, 6):
            sub = {r["method"]: r for r in rows
                   if r["model"] == model and r["a"] == a}
            if not sub:
                continue
            q = {k: v["ppl"] for k, v in sub.items() if k != "fp16"}
            best = min(q, key=q.get)
            assert best in ("aser_as", "aser"), (model, a, q)
    return rows


if __name__ == "__main__":
    run()
