"""Table 4 + Overhead analysis: rank threshold α → mean rank, extra FLOPs %,
downstream quality."""
import numpy as np
import jax.numpy as jnp

from repro.quant import quantize_model, registry
from .common import eval_acc, eval_ppl, get_tape, get_trained_model, save_json


def run(verbose=True):
    cfg, params, corpus = get_trained_model("qwen")
    tape = get_tape(cfg, params, corpus)
    d = cfg.d_model
    rows = []
    for alpha in (0.1, 0.075, 0.05, 0.03, 0.015):
        qp = quantize_model(params, tape,
                            registry.resolve("aser_as", rank=d // 2,
                                             alpha=alpha, outlier_f=16))
        # measure selected ranks: count nonzero columns of la per linear
        ranks = []
        def walk(node):
            if isinstance(node, dict):
                if "la" in node:
                    la = np.asarray(node["la"], np.float32)
                    nz = (np.abs(la).sum(axis=-1) > 0).sum(axis=-1)
                    ranks.extend(np.atleast_1d(nz).reshape(-1).tolist())
                else:
                    for v in node.values():
                        walk(v)
            elif isinstance(node, (list, tuple)):
                for v in node:
                    walk(v)
        walk(qp)
        mean_rank = float(np.mean(ranks))
        # overhead: 2·s·r·d extra FLOPs vs s·d_in·d_out per layer ≈ 2r/d_out
        flops_overhead = 100.0 * 2 * mean_rank / d
        ppl = eval_ppl(cfg, qp, corpus)
        acc = eval_acc(cfg, qp, corpus)
        rows.append({"alpha": alpha, "mean_rank": mean_rank,
                     "flops_overhead_pct": flops_overhead,
                     "ppl": ppl, "acc": acc})
        if verbose:
            print(f"  α={alpha:<6} r̄={mean_rank:6.1f} "
                  f"+FLOPs={flops_overhead:5.2f}% ppl={ppl:8.3f} acc={acc:5.2f}")
    save_json("table4_rank", rows)
    # claim: mean selected rank decreases with α
    mr = [r["mean_rank"] for r in rows]
    assert all(a >= b for a, b in zip(mr, mr[1:])), mr
    return rows


if __name__ == "__main__":
    run()
