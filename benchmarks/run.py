"""Run every paper-table/figure benchmark. ``python -m benchmarks.run``."""
import argparse
import sys
import time
import traceback

from . import (fig2_singular_values, fig3_effective_rank, fig4_outliers,
               fig5_w8ax, fig6_compensation, fig7_smoothing,
               fig8_rank_selection, kernels_bench, roofline_report,
               serve_bench, table3_scale, table4_rank, table12_main,
               table56_weight_only)

BENCHES = [
    ("fig2_singular_values", fig2_singular_values),
    ("fig3_effective_rank", fig3_effective_rank),
    ("fig4_outliers", fig4_outliers),
    ("table12_main", table12_main),
    ("table3_scale", table3_scale),
    ("fig5_w8ax", fig5_w8ax),
    ("fig6_compensation", fig6_compensation),
    ("table4_rank", table4_rank),
    ("table56_weight_only", table56_weight_only),
    ("fig7_smoothing", fig7_smoothing),
    ("fig8_rank_selection", fig8_rank_selection),
    ("kernels_bench", kernels_bench),
    ("roofline_report", roofline_report),
    ("serve_bench", serve_bench),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--isolate", action="store_true",
                    help="run each benchmark in a fresh subprocess (XLA's "
                         "CPU JIT can exhaust dylib slots after ~1e3 "
                         "compilations in one process)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.isolate:
        import os
        import subprocess
        import sys as _sys
        failures = []
        for name, _ in BENCHES:
            if only and name not in only:
                continue
            r = subprocess.run(
                [_sys.executable, "-m", "benchmarks.run", "--only", name],
                env=dict(os.environ))
            if r.returncode:
                failures.append(name)
        if failures:
            print("FAILURES:", failures)
            _sys.exit(1)
        print("\nAll benchmarks passed (isolated).")
        return
    failures = []
    for name, mod in BENCHES:
        if only and name not in only:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            mod.run()
            print(f"=== {name} OK ({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"=== {name} FAILED: {e}", flush=True)
    if failures:
        print("\nFAILURES:", failures)
        sys.exit(1)
    print("\nAll benchmarks passed.")


if __name__ == "__main__":
    main()
