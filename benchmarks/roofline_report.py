"""Aggregate results/dryrun/*.json into the §Roofline table (markdown)."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load_records(tag=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "dryrun", "*.json"))):
        base = os.path.basename(p)[:-5]
        parts = base.split("_")
        with open(p) as f:
            r = json.load(f)
        r["_file"] = base
        recs.append(r)
    return recs


def markdown_table(recs, mesh="single"):
    rows = [r for r in recs if r["mesh"] == mesh and "_" not in r["_file"].split(mesh)[-1]]
    rows = [r for r in recs if r["mesh"] == mesh and r["_file"].endswith(mesh)]
    lines = [
        "| arch | cell | t_compute(s) | t_memory(s) | t_coll(s) | bottleneck "
        "| MODEL_FLOPS/HLO | roofline frac | mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["cell"])):
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['peak_mem_per_dev_gb']:.1f} |")
    return "\n".join(lines)


def run(verbose=True):
    recs = load_records()
    if not recs:
        print("  (no dry-run records yet — run repro.launch.dryrun first)")
        return []
    if verbose:
        print(markdown_table(recs, "single"))
        print()
        multi = [r for r in recs if r["mesh"] == "multi"]
        print(f"  multi-pod cells passed: {len(multi)}")
    return recs


if __name__ == "__main__":
    run()
