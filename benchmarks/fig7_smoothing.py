"""Fig. 7 (appendix): numerical effect of activation smoothing — activation
dynamic range before/after, and the W_o outlier mass.

The paper shows Qwen1.5-7B layer 1, which has extreme channel outliers.
Our small trained models have milder outliers, so we scan all captured
linears and demonstrate on the one with the widest per-channel range.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.smoothing import aser_smoothing
from .common import get_tape, get_trained_model, save_json


def _iter_stats(tape, params):
    bt, blk = tape["groups"]["b0"], params["groups"][0]
    for mod, leaf in [("attn", "wq"), ("attn", "wo"),
                      ("mlp", "gate"), ("mlp", "down")]:
        st = bt[mod][leaf]
        n_g = np.asarray(st.count).shape[0]
        for g in range(n_g):
            yield (f"{mod}.{leaf}[{g}]",
                   np.asarray(st.abssum)[g] / max(float(np.asarray(st.count)[g]), 1),
                   np.asarray(st.absmax)[g],
                   np.asarray(blk[mod][leaf]["w"])[g])


def run(verbose=True):
    cfg, params, corpus = get_trained_model("qwen")
    tape = get_tape(cfg, params, corpus)

    # scan all linears: report the layer where smoothing helps most.
    # (ASER's X̄⊙W̄ score deliberately targets channels that are outliers in
    # the PRODUCT; pure-X outliers with tiny weights are SmoothQuant's
    # territory — see fig5 docstring / EXPERIMENTS.md.)
    results = []
    for name, xbar, xmax, w in _iter_stats(tape, params):
        w = jnp.asarray(w).T
        sm = aser_smoothing(w, jnp.asarray(xbar), f=16)
        m = np.asarray(sm.m)
        rb = float(xmax.max() / np.median(xmax))
        ra = float((xmax / m).max() / np.median(xmax / m))
        results.append({
            "layer": name,
            "act_absmax_before": float(xmax.max()),
            "act_absmax_after": float((xmax / m).max()),
            "act_range_ratio_before": rb,
            "act_range_ratio_after": ra,
            "w_outlier_frac_cols": float(np.asarray(sm.outlier_mask).mean()),
            "w_outlier_mass": float(np.linalg.norm(np.asarray(sm.w_outlier))
                                    / np.linalg.norm(np.asarray(sm.w_scaled))),
        })
    best = max(results, key=lambda r: r["act_range_ratio_before"]
               - r["act_range_ratio_after"])
    if verbose:
        print(f"  best-improved {best['layer']}: absmax "
              f"{best['act_absmax_before']:.2f} → "
              f"{best['act_absmax_after']:.2f}; range ratio "
              f"{best['act_range_ratio_before']:.2f} → "
              f"{best['act_range_ratio_after']:.2f}")
    save_json("fig7_smoothing", {"best": best, "all": results})
    # at least one layer genuinely smooths; none get dramatically worse
    assert best["act_range_ratio_after"] < best["act_range_ratio_before"], best
    for r in results:
        assert r["act_range_ratio_after"] <= r["act_range_ratio_before"] * 1.5 + 1e-6, r
    return results


if __name__ == "__main__":
    run()
