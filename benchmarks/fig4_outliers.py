"""Fig. 4: outlier channels (sorted by X̄⊙W̄) dominate the quantization error."""
import numpy as np
import jax.numpy as jnp

from repro.core.quantizers import W4, fake_quant_weight
from .common import get_trained_model, get_tape, save_json


def run(verbose=True):
    cfg, params, corpus = get_trained_model("llama")
    tape = get_tape(cfg, params, corpus)
    bt = tape["groups"]["b0"]
    blk = params["groups"][0]
    g = cfg.n_layers // 2
    st = bt["mlp"]["gate"]
    gram = np.asarray(st.gram)[g]
    xbar = np.asarray(st.abssum)[g] / max(float(np.asarray(st.count)[g]), 1)
    w = np.asarray(blk["mlp"]["gate"]["w"])[g].T           # [out, in]
    wbar = np.abs(w).mean(axis=0)
    e = w - np.asarray(fake_quant_weight(jnp.asarray(w), W4))
    # per-channel contribution to ‖E_q X‖²: e_j² · G_jj summed over out dim
    contrib = (e ** 2).sum(axis=0) * np.diag(gram)
    score = xbar * wbar
    order = np.argsort(-score)
    sorted_contrib = contrib[order]
    total = contrib.sum()
    frac_top1pct = float(sorted_contrib[:max(len(order) // 100, 1)].sum() / total)
    frac_top32 = float(sorted_contrib[:32].sum() / total)
    corr = float(np.corrcoef(score, contrib)[0, 1])
    out = {"corr_score_vs_error": corr,
           "frac_error_top1pct_channels": frac_top1pct,
           "frac_error_top32_channels": frac_top32,
           "channels_sorted_contrib": sorted_contrib[:512].tolist()}
    if verbose:
        print(f"  corr(X̄W̄, channel error) = {corr:.3f}; "
              f"top-32 channels carry {100*frac_top32:.1f}% of error")
    save_json("fig4_outliers", out)
    assert corr > 0.1, "outlier score should correlate with channel error"
    return out


if __name__ == "__main__":
    run()
