"""Fig. 8 (appendix): per-layer rank selected by the α threshold."""
import numpy as np
import jax.numpy as jnp

from repro.core.quantizers import W4, fake_quant_weight
from repro.core.whitening import cholesky_whitener, rank_from_alpha, whiten_svd
from .common import get_tape, get_trained_model, save_json


def run(verbose=True):
    cfg, params, corpus = get_trained_model("llama")
    tape = get_tape(cfg, params, corpus)
    bt, blk = tape["groups"]["b0"], params["groups"][0]
    alphas = (0.015, 0.03, 0.05, 0.1)
    rows = []
    for g in range(cfg.n_layers):
        row = {"layer": g}
        st = bt["mlp"]["gate"]
        gram = jnp.asarray(np.asarray(st.gram)[g])
        w = jnp.asarray(np.asarray(blk["mlp"]["gate"]["w"])[g]).T
        e = w - fake_quant_weight(w, W4)
        s = cholesky_whitener(gram, damp=1e-3)
        _, sig, _ = whiten_svd(e, s)
        for a in alphas:
            row[f"alpha_{a}"] = int(rank_from_alpha(sig, a))
        rows.append(row)
        if verbose:
            print("  ", row)
    save_json("fig8_rank_selection", rows)
    for r in rows:   # rank monotone in alpha per layer
        vals = [r[f"alpha_{a}"] for a in alphas]
        assert vals == sorted(vals), r
    return rows


if __name__ == "__main__":
    run()
