"""Table 3: larger-model W4A8 evaluation (scaled-up bench model)."""
from repro.quant import quantize_model, registry
from repro.runtime import RuntimeConfig
from .common import eval_acc, eval_ppl, get_tape, get_trained_model, save_json

METHODS = ["llmint4", "smoothquant", "lorc", "l2qer", "aser", "aser_as"]


def run(verbose=True):
    cfg, params, corpus = get_trained_model("qwen", scale="large", steps=300)
    tape = get_tape(cfg, params, corpus)
    rows = [{"method": "fp16", "ppl": eval_ppl(cfg, params, corpus),
             "acc": eval_acc(cfg, params, corpus)}]
    rt = RuntimeConfig(a_bits=8)
    for method in METHODS:
        qp = quantize_model(params, tape,
                            registry.resolve(method, rank=32, outlier_f=16))
        rows.append({"method": method, "ppl": eval_ppl(cfg, qp, corpus, rt=rt),
                     "acc": eval_acc(cfg, qp, corpus, rt=rt)})
        if verbose:
            r = rows[-1]
            print(f"  large W4A8 {method:12s} ppl={r['ppl']:8.3f} "
                  f"acc={r['acc']:5.2f}")
    save_json("table3_scale", rows)
    q = {r["method"]: r["ppl"] for r in rows if r["method"] != "fp16"}
    # at this scale W4A8 degradation is small and compensation methods tie
    # within noise; assert the paper's robust ordering: ASER ≤ the
    # no-compensation baselines, and within epsilon of the best.
    assert q["aser_as"] <= q["smoothquant"] + 1e-6, q
    assert q["aser"] <= min(q.values()) + 0.02, q
    return rows


if __name__ == "__main__":
    run()
