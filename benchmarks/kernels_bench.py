"""Kernel benchmark: correctness sweep + modeled TPU tile economics.

Wall-clock on CPU interpret mode is meaningless; instead we verify
allclose across serving shapes and report the modeled VMEM footprint and
arithmetic intensity per BlockSpec choice (what the TPU scheduler sees).
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import W4, pack_int4, quantize_weight
from repro.kernels import act_quant, w4a8_gemm
from repro.kernels import ref as kref
from .common import save_json


def vmem_bytes(bm, bn, bk, r):
    """Per-step VMEM working set of the w4a8 kernel."""
    return (bm * bk                    # xq int8
            + bk // 2 * bn             # packed weights
            + bm * bn * 4              # int32 accumulator
            + bm * 4 + bn * 4          # scales
            + bm * r * 4 + r * bn * 4  # low-rank epilogue
            )


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = []
    for (m, k, n, r) in [(128, 2048, 2048, 64), (256, 4096, 4096, 64),
                         (512, 2048, 8192, 64)]:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        codes, sw = quantize_weight(w, W4)
        qw = pack_int4(codes).T
        mdiag = jnp.ones((k,), jnp.float32)
        lb = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32) * 0.01)
        la = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32) * 0.01)
        y_ref = kref.w4a8_linear_ref(x, qw, sw[:, 0], mdiag, lb, la)
        xq, sx, xlr = act_quant(x, mdiag, lb)
        y = w4a8_gemm(xq, sx, qw, sw[:, 0], xlr, la)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        for (bm, bn, bk) in [(256, 256, 512), (128, 512, 512), (256, 128, 1024)]:
            vm = vmem_bytes(min(bm, m), min(bn, n), min(bk, k), r)
            flops = 2 * min(bm, m) * min(bn, n) * min(bk, k)
            ai = flops / vm
            rows.append({"m": m, "k": k, "n": n, "r": r, "bm": bm, "bn": bn,
                         "bk": bk, "vmem_kb": vm / 1024,
                         "arith_intensity": ai, "max_rel_err": err})
        if verbose:
            print(f"  w4a8 {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"vmem {vmem_bytes(256,256,512,r)/1e6:.2f}MB @ (256,256,512)")
        assert err < 1e-4
    save_json("kernels_bench", rows)
    return rows


if __name__ == "__main__":
    run()
