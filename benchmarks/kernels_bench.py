"""Kernel benchmark: correctness sweep, tile economics, autotune refresh.

Three jobs, one report (``BENCH_kernels.json`` at the repo root, schema
``kernels_bench/v1``):

1. **Correctness sweep** — allclose of every kernel route (tiled GEMM,
   fused decode, tiled-m fused prefill) against the XLA reference across
   serving shapes, plus the modeled VMEM footprint and arithmetic
   intensity per BlockSpec choice (what the TPU scheduler sees).
2. **Autotune cache refresh** (``--refresh-cache``) — (re)populate the
   measured autotune cache (``repro.kernels.autotune``) for the swept
   shapes. On backends that compile Pallas the BlockSpec winners are
   wall-clocked over the candidate lattices; on interpret-only backends
   (CPU) wall-clock measures the interpreter, not the kernel, so the
   entries carry the modeled winner labeled ``source: "model"``. The
   ``decode_plan`` entries are genuinely **measured on every backend**
   (the candidates are end-to-end XLA formulations, not Pallas kernels —
   see ``autotune.measure_decode_plan``).
3. **Measured-vs-modeled report** — every cache entry is emitted next to
   the modeled decision for its key, with an ``agrees_with_model`` bit,
   so a reader can see exactly where measurement overruled the cost
   model. The validator (``--validate``) re-checks every entry against
   the exported candidate lattices and the VMEM budget — the same
   ``validate_entry`` the KC005 contract check and consult-time lookups
   apply.

The cost model itself lives in ``repro.kernels.tuning`` — the same one
the serving dispatch uses for block selection and fused-decode routing —
so the numbers reported here are the numbers the router acts on.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import W4, pack_int4, quantize_weight
from repro.kernels import act_quant, w4a8_fused, w4a8_gemm
from repro.kernels import autotune
from repro.kernels import ref as kref
from repro.kernels import tuning
from repro.kernels.tuning import (fused_bn, fused_tiles, fused_vmem_bytes,
                                  select_gemm_blocks, use_fused_decode,
                                  use_fused_prefill, vmem_bytes)
from .common import save_json

SCHEMA = "kernels_bench/v1"
ROOT_OUT = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_kernels.json")

# Serving shapes swept (and, under --refresh-cache, recorded): the classic
# large-model projections plus the serve_bench offline config's leaf
# shapes — the entries quantized decode actually consults.
GEMM_SHAPES = [(128, 2048, 2048, 64), (256, 4096, 4096, 64),
               (512, 2048, 8192, 64)]
FUSED_SHAPES = [(1, 2048, 2048, 64), (4, 4096, 4096, 64),
                (8, 2048, 8192, 64), (1, 4096, 11008, 64)]
PREFILL_SHAPES = [(64, 2048, 2048, 64), (128, 4096, 4096, 64)]
# decode_plan: (m, d_model, d_ff, r, n_groups) — serve_bench's non-smoke
# offline config at its static decode batches
PLAN_SHAPES = [(1, 256, 512, 64, 4), (4, 256, 512, 64, 4),
               (8, 256, 512, 64, 4)]

GEMM_SHAPES_SMOKE = [(128, 2048, 2048, 64)]
FUSED_SHAPES_SMOKE = [(1, 2048, 2048, 64), (8, 2048, 8192, 64)]
PREFILL_SHAPES_SMOKE = [(64, 2048, 2048, 64)]
PLAN_SHAPES_SMOKE = [(1, 64, 128, 8, 2)]


def _setup(rng, m, k, n, r):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    codes, sw = quantize_weight(w, W4)
    qw = pack_int4(codes).T
    mdiag = jnp.ones((k,), jnp.float32)
    lb = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32) * 0.01)
    la = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32) * 0.01)
    return x, qw, sw[:, 0], mdiag, lb, la


def _modeled_gemm_lattice(m, k, n, r):
    """The modeled search of ``tuning.select_gemm_blocks`` in *lattice*
    coordinates (unclamped) — cache entries must name lattice members so
    the KC005 cross-product covers them."""
    best, best_ai = None, -1.0
    for bm in tuning.GEMM_BM_CANDIDATES:
        for bn in tuning.GEMM_BN_CANDIDATES:
            for bk in tuning.GEMM_BK_CANDIDATES:
                bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
                vm = vmem_bytes(bm_, bn_, bk_, r)
                if vm > tuning.VMEM_BUDGET:
                    continue
                ai = (2 * bm_ * bn_ * bk_) / vm
                if ai > best_ai:
                    best, best_ai = (bm, bn, bk), ai
    return best


def _modeled_choice(key):
    """The modeled (autotune-off) decision for one cache key, for the
    measured-vs-modeled report."""
    ks = autotune._parse_key(key)
    if ks is None:
        return None
    kern = ks["kernel"]
    if kern == "w4a8_gemm":
        return _modeled_gemm_lattice(ks["m"], ks["k"], ks["n"], ks["r"])
    if kern == "w4a8_fused":
        return fused_bn(ks["m"], ks["k"], ks["n"], ks["r"])
    if kern == "fused_tiles":
        return fused_tiles(ks["m"], ks["k"], ks["n"], ks["r"])
    if kern == "paged_attention":
        return tuning.paged_vmem_bytes(ks["b"], ks["g"], ks["h"],
                                       bool(ks["q"])) <= tuning.VMEM_BUDGET
    if kern == "decode_plan":
        return "default"        # the model has no better idea than today's
    return None


def refresh_cache(smoke: bool = False, verbose: bool = True):
    """(Re)populate the autotune cache for the swept shapes; returns the
    saved cache. BlockSpec winners are measured on compiled-Pallas
    backends and recorded from the model (``source: "model"``) on
    interpret-only ones; decode_plan entries are measured everywhere."""
    backend = jax.default_backend()
    on_device = backend != "cpu"
    cache = autotune.get_cache(backend)
    gemm = GEMM_SHAPES_SMOKE if smoke else GEMM_SHAPES
    fused = FUSED_SHAPES_SMOKE if smoke else FUSED_SHAPES
    prefill = PREFILL_SHAPES_SMOKE if smoke else PREFILL_SHAPES
    plans = PLAN_SHAPES_SMOKE if smoke else PLAN_SHAPES

    for (m, k, n, r) in gemm:
        if on_device:
            choice, us = autotune.measure_gemm_blocks(m, k, n, r)
            src = "measured"
        else:
            choice, us, src = _modeled_gemm_lattice(m, k, n, r), None, "model"
        cache.put(autotune.gemm_key(m, k, n, r), list(choice), us, src)
    for (m, k, n, r) in fused:
        if on_device:
            choice, us = autotune.measure_fused_bn(m, k, n, r)
            src = "measured"
        else:
            choice, us, src = fused_bn(m, k, n, r), None, "model"
        cache.put(autotune.fused_key(m, k, n, r), choice, us, src)
    for (m, k, n, r) in prefill:
        if on_device:
            choice, us = autotune.measure_fused_tiles(m, k, n, r)
            src = "measured"
        else:
            choice, us, src = fused_tiles(m, k, n, r), None, "model"
        cache.put(autotune.fused_tiles_key(m, k, n, r), list(choice), us,
                  src)
    for (m, d, ff, r, L) in plans:
        winner, results = autotune.measure_decode_plan(
            m, d, ff, r, L, n_steps=8 if smoke else 24)
        cache.put(autotune.decode_plan_key(m, d, ff, r, L), winner,
                  results[winner])
        if verbose:
            us = {p: f"{v:.0f}us" for p, v in results.items()}
            print(f"  decode_plan m={m} d={d} ff={ff} r={r} L={L}: "
                  f"{winner} wins ({us})", flush=True)
    path = cache.save()
    if verbose:
        print(f"  autotune cache ({len(cache.entries)} entries) -> {path}")
    return cache


def _autotune_report(cache):
    entries = []
    for key, e in sorted(cache.entries.items()):
        modeled = _modeled_choice(key)
        choice = e.get("choice")
        norm = (list(choice) if isinstance(choice, (list, tuple))
                else choice)
        mnorm = (list(modeled) if isinstance(modeled, (list, tuple))
                 else modeled)
        entries.append({
            "key": key, "choice": norm, "us": e.get("us"),
            "source": e.get("source"),
            "disabled": bool(e.get("disabled", False)),
            "modeled_choice": mnorm,
            "agrees_with_model": norm == mnorm,
        })
    return {"backend": cache.backend, "cache_file": str(cache.path),
            "loaded_from": cache._loaded_from, "entries": entries}


def run(verbose=True, smoke: bool = False, refresh: bool = False,
        out_path: str = ROOT_OUT):
    rng = np.random.default_rng(0)
    rows = []
    gemm = GEMM_SHAPES_SMOKE if smoke else GEMM_SHAPES
    fused = FUSED_SHAPES_SMOKE if smoke else FUSED_SHAPES
    prefill = PREFILL_SHAPES_SMOKE if smoke else PREFILL_SHAPES

    # -- tiled GEMM path: prefill/batch shapes ------------------------------
    for (m, k, n, r) in gemm:
        x, qw, sw, mdiag, lb, la = _setup(rng, m, k, n, r)
        y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
        xq, sx, xlr = act_quant(x, mdiag, lb)
        y = w4a8_gemm(xq, sx, qw, sw, xlr, la)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        chosen = select_gemm_blocks(m, k, n, r)
        candidates = [(256, 256, 512), (128, 512, 512), (256, 128, 1024)]
        if chosen not in candidates:    # always report what the router acts on
            candidates.append(chosen)
        for (bm, bn, bk) in candidates:
            vm = vmem_bytes(min(bm, m), min(bn, n), min(bk, k), r)
            flops = 2 * min(bm, m) * min(bn, n) * min(bk, k)
            ai = flops / vm
            rows.append({"kernel": "w4a8_gemm", "m": m, "k": k, "n": n,
                         "r": r, "bm": bm, "bn": bn, "bk": bk,
                         "vmem_kb": vm / 1024, "arith_intensity": ai,
                         "chosen": list(chosen) == [min(bm, m), min(bn, n),
                                                    min(bk, k)],
                         "max_rel_err": err})
        if verbose:
            print(f"  w4a8 {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"vmem {vmem_bytes(256,256,512,r)/1e6:.2f}MB @ (256,256,512)"
                  f", router picks {chosen}")
        assert err < 1e-4

    # -- fused decode path: small-m GEMV shapes -----------------------------
    for (m, k, n, r) in fused:
        assert use_fused_decode(m, k, n, r), (m, k, n, r)
        x, qw, sw, mdiag, lb, la = _setup(rng, m, k, n, r)
        y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
        y = w4a8_fused(x, mdiag, qw, sw, lb, la)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        bn = fused_bn(m, k, n, r)
        vm = fused_vmem_bytes(m, k, bn, r)
        # HBM bytes the two-kernel pipeline round-trips between calls
        saved = m * k + m * 4 + m * r * 4
        rows.append({"kernel": "w4a8_fused", "m": m, "k": k, "n": n, "r": r,
                     "bn": bn, "vmem_kb": vm / 1024,
                     "hbm_roundtrip_saved_b": saved, "max_rel_err": err})
        if verbose:
            print(f"  fused {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"bn {bn}, vmem {vm/1e6:.2f}MB, "
                  f"saves {saved/1024:.1f}KB xq/sx/xlr round-trip")
        assert err < 1e-4

    # -- tiled-m fused prefill variant --------------------------------------
    for (m, k, n, r) in prefill:
        assert use_fused_prefill(m, k, n, r), (m, k, n, r)
        bm, bn = fused_tiles(m, k, n, r)
        x, qw, sw, mdiag, lb, la = _setup(rng, m, k, n, r)
        y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
        y = w4a8_fused(x, mdiag, qw, sw, lb, la, bn=bn, bm=bm)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        vm = fused_vmem_bytes(min(bm, m), k, min(bn, n), r)
        rows.append({"kernel": "w4a8_fused_prefill", "m": m, "k": k, "n": n,
                     "r": r, "bm": bm, "bn": bn, "vmem_kb": vm / 1024,
                     "max_rel_err": err})
        if verbose:
            print(f"  fused-prefill {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"tiles ({bm},{bn}), vmem {vm/1e6:.2f}MB")
        assert err < 1e-4

    if refresh:
        cache = refresh_cache(smoke=smoke, verbose=verbose)
    else:
        cache = autotune.get_cache()

    report = {
        "schema": SCHEMA,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "rows": rows,
        "autotune": _autotune_report(cache),
    }
    save_json("kernels_bench", rows)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    if verbose:
        print(f"  wrote {os.path.abspath(out_path)}")
    return report


# -- schema validation (CI smoke gate) ---------------------------------------

def validate(report: dict):
    """Raise ValueError unless ``report`` is a valid kernels_bench file:
    correct schema, a non-empty correctness sweep with every route inside
    tolerance, and every autotune entry passing the same lattice + VMEM
    validation consult-time lookups and the KC005 contract check apply."""
    if report.get("schema") != SCHEMA:
        raise ValueError(f"schema mismatch: {report.get('schema')!r}")
    rows = report.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("no kernel rows")
    kernels = set()
    for row in rows:
        err = row.get("max_rel_err")
        if not isinstance(err, (int, float)) or not err == err:
            raise ValueError(f"non-finite max_rel_err in {row}")
        if err >= 1e-4:
            raise ValueError(f"kernel route out of tolerance: {row}")
        kernels.add(row.get("kernel"))
    if not {"w4a8_gemm", "w4a8_fused"} <= kernels:
        raise ValueError(f"need w4a8_gemm and w4a8_fused rows, "
                         f"got {kernels}")
    at = report.get("autotune")
    if not isinstance(at, dict) or not isinstance(at.get("entries"), list):
        raise ValueError("missing autotune section")
    for e in at["entries"]:
        key, choice = e.get("key"), e.get("choice")
        if e.get("source") not in ("model", "measured"):
            raise ValueError(f"bad entry source: {e}")
        if not isinstance(e.get("agrees_with_model"), bool):
            raise ValueError(f"missing agrees_with_model bit: {e}")
        reason = autotune.validate_entry(
            key, {"choice": tuple(choice) if isinstance(choice, list)
                  else choice})
        if reason is not None:
            raise ValueError(f"invalid autotune entry: {reason}")
    return True


def validate_file(path: str = ROOT_OUT):
    with open(path) as f:
        validate(json.load(f))
    print(f"{path}: kernels_bench schema OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (same schema)")
    ap.add_argument("--refresh-cache", action="store_true",
                    help="(re)measure and persist the autotune cache for "
                         "the swept shapes")
    ap.add_argument("--out", default=ROOT_OUT)
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="validate an existing BENCH_kernels.json and exit")
    args = ap.parse_args()
    if args.validate:
        validate_file(args.validate)
        return
    report = run(smoke=args.smoke, refresh=args.refresh_cache,
                 out_path=args.out)
    validate(report)


if __name__ == "__main__":
    main()
