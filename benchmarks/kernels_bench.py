"""Kernel benchmark: correctness sweep + modeled TPU tile economics.

Wall-clock on CPU interpret mode is meaningless; instead we verify
allclose across serving shapes and report the modeled VMEM footprint and
arithmetic intensity per BlockSpec choice (what the TPU scheduler sees).
The cost model itself lives in ``repro.kernels.tuning`` — the same one the
serving dispatch uses for block selection and fused-decode routing — so
the numbers reported here are the numbers the router acts on.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import W4, pack_int4, quantize_weight
from repro.kernels import act_quant, w4a8_fused, w4a8_gemm
from repro.kernels import ref as kref
from repro.kernels.tuning import (fused_bn, fused_vmem_bytes,
                                  select_gemm_blocks, use_fused_decode,
                                  vmem_bytes)
from .common import save_json


def _setup(rng, m, k, n, r):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    codes, sw = quantize_weight(w, W4)
    qw = pack_int4(codes).T
    mdiag = jnp.ones((k,), jnp.float32)
    lb = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32) * 0.01)
    la = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32) * 0.01)
    return x, qw, sw[:, 0], mdiag, lb, la


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = []

    # -- tiled GEMM path: prefill/batch shapes ------------------------------
    for (m, k, n, r) in [(128, 2048, 2048, 64), (256, 4096, 4096, 64),
                         (512, 2048, 8192, 64)]:
        x, qw, sw, mdiag, lb, la = _setup(rng, m, k, n, r)
        y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
        xq, sx, xlr = act_quant(x, mdiag, lb)
        y = w4a8_gemm(xq, sx, qw, sw, xlr, la)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        chosen = select_gemm_blocks(m, k, n, r)
        candidates = [(256, 256, 512), (128, 512, 512), (256, 128, 1024)]
        if chosen not in candidates:    # always report what the router acts on
            candidates.append(chosen)
        for (bm, bn, bk) in candidates:
            vm = vmem_bytes(min(bm, m), min(bn, n), min(bk, k), r)
            flops = 2 * min(bm, m) * min(bn, n) * min(bk, k)
            ai = flops / vm
            rows.append({"kernel": "w4a8_gemm", "m": m, "k": k, "n": n,
                         "r": r, "bm": bm, "bn": bn, "bk": bk,
                         "vmem_kb": vm / 1024, "arith_intensity": ai,
                         "chosen": list(chosen) == [min(bm, m), min(bn, n),
                                                    min(bk, k)],
                         "max_rel_err": err})
        if verbose:
            print(f"  w4a8 {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"vmem {vmem_bytes(256,256,512,r)/1e6:.2f}MB @ (256,256,512)"
                  f", router picks {chosen}")
        assert err < 1e-4

    # -- fused decode path: small-m GEMV shapes -----------------------------
    for (m, k, n, r) in [(1, 2048, 2048, 64), (4, 4096, 4096, 64),
                         (8, 2048, 8192, 64), (1, 4096, 11008, 64)]:
        assert use_fused_decode(m, k, n, r), (m, k, n, r)
        x, qw, sw, mdiag, lb, la = _setup(rng, m, k, n, r)
        y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
        y = w4a8_fused(x, mdiag, qw, sw, lb, la)
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        bn = fused_bn(m, k, n, r)
        vm = fused_vmem_bytes(m, k, bn, r)
        # HBM bytes the two-kernel pipeline round-trips between calls
        saved = m * k + m * 4 + m * r * 4
        rows.append({"kernel": "w4a8_fused", "m": m, "k": k, "n": n, "r": r,
                     "bn": bn, "vmem_kb": vm / 1024,
                     "hbm_roundtrip_saved_b": saved, "max_rel_err": err})
        if verbose:
            print(f"  fused {m}x{k}x{n} r{r}: rel err {err:.2e}, "
                  f"bn {bn}, vmem {vm/1e6:.2f}MB, "
                  f"saves {saved/1024:.1f}KB xq/sx/xlr round-trip")
        assert err < 1e-4
    save_json("kernels_bench", rows)
    return rows


if __name__ == "__main__":
    run()
