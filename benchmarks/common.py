"""Shared benchmark harness: train small LMs on the synthetic corpus once,
cache them, and expose calibration tapes + eval sets.

The paper evaluates PTQ on pretrained LLaMA/Qwen checkpoints; offline we
train small same-family models to convergence-ish on a deterministic corpus
so that quantization-induced PPL degradation is meaningful and method
orderings can be validated (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.core.metrics import perplexity
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import ModelConfig, forward, init_params
from repro.quant import calibrate, reduce_shared
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
CKPT_DIR = os.path.join(RESULTS, "bench_models")

VOCAB = 512


def bench_config(name: str = "llama", scale: str = "small") -> ModelConfig:
    """Small trainable analogues of the paper's eval models."""
    base = {"llama": get_smoke_config("llama3_8b"),
            "qwen": get_smoke_config("qwen15_7b")}[name]
    dims = {"small": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=VOCAB),
            "large": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                          head_dim=64, d_ff=1024, vocab_size=VOCAB)}[scale]
    return base.reduced(**dims, dtype="float32")


def get_trained_model(name: str = "llama", scale: str = "small",
                      steps: int = 300, batch: int = 16, seq: int = 64):
    """Train (or load cached) a small LM. Returns (cfg, params, corpus)."""
    cfg = dataclasses.replace(bench_config(name, scale), remat=False)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tag = f"{name}_{scale}_{steps}"
    mgr = CheckpointManager(os.path.join(CKPT_DIR, tag), keep=1)
    params = init_params(jax.random.PRNGKey(42), cfg)
    if mgr.latest_step() is not None:
        _, st = mgr.restore_latest({"params": params})
        return cfg, st["params"], corpus

    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    for i in range(steps):
        b = {"tokens": corpus.sample(jnp.asarray(i), batch, seq + 1)}
        params, opt, m = step_fn(params, opt, b)
        if i % 100 == 0:
            print(f"  [train {tag}] step {i} loss {float(m['loss']):.3f}",
                  flush=True)
    mgr.save(steps, {"params": params})
    return cfg, params, corpus


def get_tape(cfg, params, corpus, n_batches: int = 4, batch: int = 8,
             seq: int = 64):
    tape = calibrate(params, cfg, corpus.calibration_batches(n_batches, batch, seq))
    return reduce_shared(tape, cfg)


def eval_ppl(cfg, params, corpus, n_batches: int = 4, batch: int = 8,
             seq: int = 64, rt=None) -> float:
    """``rt``: RuntimeConfig for the quantized serving path (None = default)."""
    tot = 0.0
    for i in range(n_batches):
        toks = corpus.sample(jnp.asarray(10_000 + i), batch, seq)
        lg, _, _ = forward(params, cfg, toks, rt=rt)
        tot += float(perplexity(lg[:, :-1], toks[:, 1:]))
    return tot / n_batches


def eval_acc(cfg, params, corpus, n_batches: int = 4, batch: int = 8,
             seq: int = 64, rt=None) -> float:
    """Next-token top-1 accuracy — the offline stand-in for the zero-shot
    accuracy columns."""
    from repro.core.metrics import top1_accuracy
    tot = 0.0
    for i in range(n_batches):
        toks = corpus.sample(jnp.asarray(20_000 + i), batch, seq)
        lg, _, _ = forward(params, cfg, toks, rt=rt)
        tot += float(top1_accuracy(lg[:, :-1], toks[:, 1:]))
    return 100.0 * tot / n_batches


def save_json(name: str, obj):
    import json
    os.makedirs(os.path.join(RESULTS, "bench"), exist_ok=True)
    path = os.path.join(RESULTS, "bench", f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def layer_linears(params, cfg):
    """Iterate (path, W [out,in]) over the scanned-group linear leaves,
    flattened per layer. Yields numpy arrays with the group axis intact."""
    out = []
    for i, blk in enumerate(params["groups"]):
        def walk(node, path):
            if isinstance(node, dict):
                if "w" in node and node["w"].ndim == 3:
                    out.append((f"b{i}{path}", np.asarray(node["w"])))
                else:
                    for k, v in node.items():
                        walk(v, f"{path}/{k}")
        walk(blk, "")
    return out
