"""Tables 5-6: weight-only (W4A16) comparison incl. GPTQ and AWQ."""
from repro.quant import quantize_model, registry
from repro.runtime import RuntimeConfig
from .common import eval_acc, eval_ppl, get_tape, get_trained_model, save_json

METHODS = ["rtn", "gptq", "awq", "aser", "aser_as"]


def run(verbose=True):
    rows = []
    rt = RuntimeConfig(a_bits=16)       # weight-only
    for name in ("llama", "qwen"):
        cfg, params, corpus = get_trained_model(name)
        tape = get_tape(cfg, params, corpus)
        fp = eval_ppl(cfg, params, corpus, rt=rt)
        rows.append({"model": name, "method": "fp16", "ppl": fp,
                     "acc": eval_acc(cfg, params, corpus, rt=rt)})
        for method in METHODS:
            recipe = registry.resolve(method, rank=16, outlier_f=16,
                                      a_bits=16)
            qp = quantize_model(params, tape, recipe)
            ppl = eval_ppl(cfg, qp, corpus, rt=rt)
            acc = eval_acc(cfg, qp, corpus, rt=rt)
            rows.append({"model": name, "method": method, "ppl": ppl,
                         "acc": acc})
            if verbose:
                print(f"  {name} W4A16 {method:10s} ppl={ppl:8.3f} acc={acc:5.2f}")
    save_json("table56_weight_only", rows)
    for name in ("llama", "qwen"):
        sub = {r["method"]: r["ppl"] for r in rows if r["model"] == name
               and r["method"] != "fp16"}
        assert min(sub, key=sub.get) in ("aser", "aser_as", "gptq"), sub
    return rows


if __name__ == "__main__":
    run()
