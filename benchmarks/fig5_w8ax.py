"""Fig. 5: perplexity of quantized model vs activation bit-width.

Small models trained briefly on a synthetic corpus do not develop the
extreme per-channel activation outliers real LLMs have. We induce them the
way real models acquire them: multiply a few norm-scale channels (x30,
uncompensated) and fine-tune ~80 steps so the network adapts around the
amplified channels. The result is a model whose post-norm activations have
genuine 15-20x outlier channels with ordinary consuming weights — the
X̄⊙W̄ score finds them, exactly the paper's Fig 4 structure.

Negative result kept for the record: a *function-preserving* surgery
(norm ×S, weights ÷S) is invisible to the X̄⊙W̄ score because the product
is invariant — in that corner SmoothQuant's ratio-based scales win.
Real-LLM outliers are not of that type, but it is an honest boundary of
ASER's outlier heuristic, noted in EXPERIMENTS.md.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import quantize_model, registry
from repro.runtime import RuntimeConfig
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state
from .common import eval_ppl, get_tape, get_trained_model, save_json

METHODS = ["llmint4", "smoothquant", "lorc", "l2qer", "aser_as"]
SCALE = 30.0
N_OUT = 6
ADAPT_STEPS = 80


def outlier_model(cfg, params, corpus, seed=0):
    """Inject norm-scale outliers (uncompensated) + brief adaptation."""
    rng = np.random.default_rng(seed)
    new = dict(params)
    blocks = []
    for blk in params["groups"]:
        blk = dict(blk)
        for nm in ("attn_norm", "mlp_norm"):
            d = np.asarray(blk[nm]["scale"]).shape[-1]
            idx = rng.choice(d, N_OUT, replace=False)
            sv = np.ones((d,), np.float32)
            sv[idx] = SCALE
            nrm = dict(blk[nm])
            nrm["scale"] = (nrm["scale"].astype(jnp.float32)
                            * jnp.asarray(sv)).astype(jnp.float32)
            blk[nm] = nrm
        blocks.append(blk)
    new["groups"] = blocks
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5,
                                   total_steps=ADAPT_STEPS))
    step = jax.jit(make_train_step(cfg, tc))
    opt = init_opt_state(new)
    for i in range(ADAPT_STEPS):
        b = {"tokens": corpus.sample(jnp.asarray(5000 + i), 16, 65)}
        new, opt, _ = step(new, opt, b)
    return new


def run(verbose=True):
    cfg, params, corpus = get_trained_model("qwen")
    params = outlier_model(cfg, params, corpus)
    tape = get_tape(cfg, params, corpus)
    fp = eval_ppl(cfg, params, corpus, rt=RuntimeConfig(a_bits=16))
    rows = [{"method": "fp16", "w_bits": 16, "a_bits": 16, "ppl": fp}]
    if verbose:
        print(f"  fp16 ppl={fp:.3f}")
    for w_bits in (8, 4):
        for method in METHODS:
            qp = quantize_model(params, tape,
                                registry.resolve(method, w_bits=w_bits,
                                                 rank=48, outlier_f=16))
            for a_bits in (8, 6, 4):
                ppl = eval_ppl(cfg, qp, corpus,
                               rt=RuntimeConfig(a_bits=a_bits))
                rows.append({"method": method, "w_bits": w_bits,
                             "a_bits": a_bits, "ppl": ppl})
                if verbose:
                    print(f"  W{w_bits}A{a_bits:<2d} {method:12s} "
                          f"ppl={ppl:9.3f}")
    save_json("fig5_w8ax", rows)
    # paper claim: with real(istic) outliers, ASER w/ A.S. degrades least
    # at low activation bits in the W4 regime
    for bits in (8, 6):
        sub = {r["method"]: r["ppl"] for r in rows
               if r["a_bits"] == bits and r["w_bits"] == 4}
        assert min(sub, key=sub.get) == "aser_as", (bits, sub)
    return rows


if __name__ == "__main__":
    run()
