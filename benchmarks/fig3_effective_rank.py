"""Fig. 3: effective rank of E_q·X across layers, MHSA vs FFN."""
import numpy as np
import jax.numpy as jnp

from repro.core.quantizers import W4, fake_quant_weight
from repro.core.whitening import effective_rank
from .common import get_trained_model, save_json


def run(verbose=True):
    cfg, params, corpus = get_trained_model("llama")
    from repro.models import forward
    toks = corpus.sample(jnp.asarray(5001), 8, 64)
    tape = {}
    forward(params, cfg, toks, tape=tape)

    rows = []
    bt = tape["groups"]["b0"]
    blk = params["groups"][0]
    for g in range(cfg.n_layers):
        row = {"layer": g}
        for label, (mod, leaf) in {"attn.wq": ("attn", "wq"),
                                   "attn.wo": ("attn", "wo"),
                                   "mlp.gate": ("mlp", "gate"),
                                   "mlp.down": ("mlp", "down")}.items():
            gram = np.asarray(bt[mod][leaf].gram)[g]
            w = np.asarray(blk[mod][leaf]["w"])[g].T
            e = w - np.asarray(fake_quant_weight(jnp.asarray(w), W4))
            eig = np.sqrt(np.maximum(np.linalg.eigvalsh(e @ gram @ e.T), 0))
            row[label] = float(effective_rank(jnp.asarray(eig)))
        rows.append(row)
        if verbose:
            print("  ", row)
    save_json("fig3_effective_rank", rows)
    return rows


if __name__ == "__main__":
    run()
