"""Fig. 6: remaining output error ‖WX − ŴX_q‖_F per layer, by method."""
import numpy as np
import jax.numpy as jnp

from repro.kernels.ref import w4a8_linear_ref
from repro.models.layers import LinStats
from repro.quant import registry
from repro.quant.apply import _quantize_one
from .common import get_tape, get_trained_model, save_json

METHODS = ["rtn", "lorc", "l2qer", "aser", "aser_as"]


def run(verbose=True):
    cfg, params, corpus = get_trained_model("llama")
    tape = get_tape(cfg, params, corpus)
    toks = corpus.sample(jnp.asarray(7000), 8, 64)
    from repro.models import forward
    cap = {}
    forward(params, cfg, toks, tape=cap)   # fresh single-batch stats for X

    rows = []
    bt, blk = tape["groups"]["b0"], params["groups"][0]
    for g in range(cfg.n_layers):
        for mod, leaf in [("attn", "wq"), ("attn", "wo"),
                          ("mlp", "gate"), ("mlp", "down")]:
            st_full = bt[mod][leaf]
            st = LinStats(jnp.asarray(np.asarray(st_full.gram)[g]),
                          jnp.asarray(np.asarray(st_full.abssum)[g]),
                          jnp.asarray(np.asarray(st_full.absmax)[g]),
                          jnp.asarray(np.asarray(st_full.count)[g]))
            w = jnp.asarray(np.asarray(blk[mod][leaf]["w"])[g])  # [k, n]
            row = {"layer": g, "linear": f"{mod}.{leaf}"}
            gram = st.gram
            for method in METHODS:
                lf = _quantize_one(w, st, registry.resolve(method, rank=16,
                                                           outlier_f=16))
                # residual via Gram: ‖Δᵀ X‖² = Tr(Δ G Δᵀ) with Δ = w_eff - w
                from repro.core.quantizers import unpack_int4
                w_eff = (unpack_int4(lf["qw"].T).T.astype(jnp.float32)
                         * lf["sw"][None, :]) / lf["m"][:, None] \
                    + (lf["lb"] / lf["m"][:, None]) @ lf["la"]
                delta = (w_eff - w.astype(jnp.float32)).T   # [n, k]
                err = float(jnp.sqrt(jnp.abs(jnp.einsum(
                    "ok,kl,ol->", delta, gram, delta))))
                row[method] = err
            rows.append(row)
        if verbose and g == 0:
            print("  layer0:", {k: round(v, 4) for k, v in rows[0].items()
                                if k not in ("layer", "linear")})
    save_json("fig6_compensation", rows)
    # claim: ASER(w/ AS) ≤ LoRC ≤ RTN on average
    means = {m: float(np.mean([r[m] for r in rows])) for m in METHODS}
    if verbose:
        print("  mean remaining error:", {k: round(v, 4) for k, v in means.items()})
    assert means["aser_as"] < means["lorc"] < means["rtn"], means
    return rows


if __name__ == "__main__":
    run()
