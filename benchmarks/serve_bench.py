"""Serving-latency benchmark: prefill, per-token decode, tokens/sec.

Times the engine end-to-end for fp vs W4A8(+ASER) across (batch, prompt)
buckets, for both decode loops:

  * ``scan`` — the device-resident ``lax.scan`` loop with donated caches
    (one dispatch per generation), the serving hot path;
  * ``step`` — the per-token Python dispatch loop (debug mode), kept as the
    baseline that the scan loop's dispatch-overhead win is measured against.

Per-token decode latency is derived dispatch-noise-free as
``(t(n_steps) − t(1)) / (n_steps − 1)`` — a 1-step generate is exactly
prefill + first-token sampling, so the difference isolates the decode loop.

Writes ``BENCH_serve.json`` at the repo root (schema ``serve_bench/v1``)
so subsequent PRs have a perf trajectory to beat; ``--smoke`` runs a
seconds-scale variant with the same schema for CI. Latency rows use the
XLA serving path (interpret-mode Pallas wall-clock is meaningless on CPU);
kernel-level tile economics live in ``kernels_bench``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from . import common  # noqa: F401  (sys.path side effect for src/)
from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import init_params
from repro.quant import calibrate, quantize_model, reduce_shared
from repro.runtime import RuntimeConfig
from repro.serve.engine import Engine, ServeConfig

SCHEMA = "serve_bench/v1"
ROOT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ROW_FIELDS = ("mode", "batch", "prompt", "n_steps", "prefill_ms",
              "decode_ms_per_tok", "tokens_per_s", "scan_decode_ms_per_tok",
              "step_decode_ms_per_tok", "dispatch_overhead_ms_per_tok",
              "scan_speedup")


def _bench_cfg(smoke: bool):
    base = get_smoke_config("llama3_8b")
    if smoke:
        return base.reduced(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                            head_dim=32, d_ff=128, vocab_size=128,
                            dtype="float32")
    return base.reduced(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                        head_dim=64, d_ff=512, vocab_size=512,
                        dtype="float32")


def _best_time(fn, reps: int) -> float:
    """Best-of-``reps`` wall-clock seconds of ``fn()`` (one untimed
    warmup/compile rep). Min, not mean: scheduler noise only ever adds."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_engine(params, cfg, rt, b, prompt, n_steps, max_len, reps):
    corpus_key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(corpus_key, (b, prompt), 0, cfg.vocab_size)
    out = {}
    for loop in ("scan", "step"):
        eng = Engine(params, cfg, ServeConfig(max_len=max_len,
                                              decode_loop=loop), rt=rt)
        t1 = _best_time(lambda: eng.generate(prompts, 1), reps)
        tn = _best_time(lambda: eng.generate(prompts, n_steps), reps)
        out[loop] = {"prefill_s": t1,
                     "decode_s_per_tok": max(tn - t1, 1e-9) / (n_steps - 1),
                     "total_s": tn}
    return out


def run(smoke: bool = False, out_path: str = ROOT_OUT, verbose: bool = True):
    cfg = dataclasses.replace(_bench_cfg(smoke), remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 32)), cfg)
    qparams = quantize_model(params, tape, "aser_as")
    rt = RuntimeConfig(use_pallas=False)     # XLA serving path (CPU-honest)

    buckets = [(1, 16), (4, 16)] if smoke else [(1, 32), (4, 64), (8, 64)]
    n_steps = 16 if smoke else 64
    reps = 3 if smoke else 5
    max_len = 64 if smoke else 128

    rows = []
    for mode, p in (("fp", params), ("w4a8_aser", qparams)):
        for (b, prompt) in buckets:
            t = _time_engine(p, cfg, rt, b, prompt, n_steps, max_len, reps)
            scan_tok = t["scan"]["decode_s_per_tok"]
            step_tok = t["step"]["decode_s_per_tok"]
            row = {
                "mode": mode, "batch": b, "prompt": prompt,
                "n_steps": n_steps,
                "prefill_ms": 1e3 * t["scan"]["prefill_s"],
                "decode_ms_per_tok": 1e3 * scan_tok,
                "tokens_per_s": b * n_steps / t["scan"]["total_s"],
                "scan_decode_ms_per_tok": 1e3 * scan_tok,
                "step_decode_ms_per_tok": 1e3 * step_tok,
                "dispatch_overhead_ms_per_tok": 1e3 * (step_tok - scan_tok),
                "scan_speedup": step_tok / max(scan_tok, 1e-12),
            }
            rows.append(row)
            if verbose:
                print(f"  {mode:>10} b={b} s={prompt}: "
                      f"prefill {row['prefill_ms']:7.2f}ms  "
                      f"decode {row['decode_ms_per_tok']:6.2f}ms/tok "
                      f"(step {row['step_decode_ms_per_tok']:6.2f})  "
                      f"{row['tokens_per_s']:8.1f} tok/s  "
                      f"scan×{row['scan_speedup']:.2f}", flush=True)

    report = {
        "schema": SCHEMA,
        "smoke": smoke,
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "vocab_size": cfg.vocab_size},
        "decode_loop_default": "scan",
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    if verbose:
        print(f"  wrote {os.path.abspath(out_path)}")
    return report


# -- schema validation (CI smoke gate) --------------------------------------

def validate(report: dict):
    """Raise ValueError unless ``report`` matches the serve_bench/v1 schema
    and contains both fp and quantized rows with finite latencies."""
    if report.get("schema") != SCHEMA:
        raise ValueError(f"schema mismatch: {report.get('schema')!r}")
    rows = report.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("no benchmark rows")
    modes = set()
    for row in rows:
        missing = [f for f in ROW_FIELDS if f not in row]
        if missing:
            raise ValueError(f"row missing fields {missing}: {row}")
        for f in ROW_FIELDS[4:]:
            v = row[f]
            if not isinstance(v, (int, float)) or not (v == v and
                                                       abs(v) < 1e12):
                raise ValueError(f"non-finite {f}={v!r} in {row}")
        # deltas (dispatch_overhead, speedup) may dip negative/below-1 on a
        # noisy CI machine; absolute latencies must be positive
        for f in ("prefill_ms", "decode_ms_per_tok", "tokens_per_s"):
            if row[f] <= 0:
                raise ValueError(f"non-positive {f}={row[f]!r} in {row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser rows, got {modes}")
    return True


def validate_file(path: str = ROOT_OUT):
    with open(path) as f:
        validate(json.load(f))
    print(f"{path}: serve_bench schema OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (same schema)")
    ap.add_argument("--out", default=ROOT_OUT)
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="validate an existing BENCH_serve.json and exit")
    args = ap.parse_args()
    if args.validate:
        validate_file(args.validate)
        return
    report = run(smoke=args.smoke, out_path=args.out)
    validate(report)


if __name__ == "__main__":
    main()
