"""Serving-latency benchmark: prefill, per-token decode, tokens/sec, goodput.

Two workloads:

* **static** — times the engine end-to-end for fp vs W4A8(+ASER) across
  (batch, prompt) buckets, for both decode loops:

    - ``scan`` — the device-resident ``lax.scan`` loop with donated caches
      (one dispatch per generation), the serving hot path;
    - ``step`` — the per-token Python dispatch loop (debug mode), kept as
      the baseline that the scan loop's dispatch-overhead win is measured
      against.

  Per-token decode latency is derived dispatch-noise-free as
  ``(t(n_steps) − t(1)) / (n_steps − 1)`` — a 1-step generate is exactly
  prefill + first-token sampling, so the difference isolates the decode
  loop.

* **continuous** — a mixed prompt-length / mixed output-length request set
  served two ways: static batching (requests grouped into ``batch_slots``-
  sized ragged batches, every batch running ``max(max_new)`` steps) vs the
  continuous-batching :class:`repro.serve.scheduler.Scheduler` (retire on
  budget, backfill from the queue). Reported as **goodput**: requested
  tokens / wall-clock second — the static baseline burns steps on retired
  rows, the scheduler backfills them.

  The continuous mode additionally runs a **shared-prefix** workload
  (requests drawn from a few "system prompt" groups, each prefix shared by
  many requests) on the paged engine, with the block-granular prefix cache
  on vs off — the reuse leg skips re-prefilling every shared prefix and
  reports its **prefix-cache hit rate** next to the goodput win.

  Finally it runs the **KV-quant** leg (``kv_rows``, serve_bench/v4): the
  same heavy-tailed continuous workload on the paged engine twice at one
  fixed KV-cache HBM budget — native-dtype KV vs ``kv_dtype="int8"``,
  where the int8 pool's smaller pages buy proportionally more blocks
  (``repro.serve.engine.blocks_for_hbm_budget``) and therefore more
  admitted concurrency / fewer preemptions. Goodput is reported for both
  legs; the int8 leg winning is the acceptance pin for KV quantization.

  The **adapter** leg (``adapter_rows``, serve_bench/v5) serves one mixed
  request stream twice through the paged continuous scheduler: base-only
  (pool-free engine, adapter-free compiled programs) vs N distinct LoRA
  tenants multiplexed over the one quantized base (per-request routing,
  batched-gather epilogue). Reported as the mixed/base **goodput ratio**
  (acceptance pin: ≥ 0.85) plus a ``token_exact`` bool certifying one
  request per tenant against its merged-weight reference generation.

  Every continuous row also carries the two **steady-state sanitizer
  counters** (serve_bench/v6): after the warm run, the identical workload
  replays under ``jax.transfer_guard("disallow")`` with the
  backend-compile counter armed (``repro.analysis.sanitizers``), and the
  row records ``recompiles_after_warmup`` and ``h2d_transfers_per_step``.
  The validator rejects any nonzero value — a retrace bomb or implicit
  host→device upload on the decode path fails the bench outright.

  The **latency** leg (``latency_rows``, serve_bench/v7) is the
  tail-latency story chunked prefill exists for: a heavy-tailed open-loop
  arrival pattern — waves of short requests with long-prompt stragglers
  arriving on a fixed token-time cadence — served twice on the paged
  engine: one-shot prefill vs chunked + token-budgeted steps
  (``ServeConfig(prefill_chunk, step_token_budget)``). It is measured in
  deterministic **token-time**: the scheduler's injectable clock advances
  by each step's dispatched token positions (bucketed prompt widths for
  one-shot admission, ``last_step_tokens`` under a budget, decode chunks
  for occupied slots — the identical cost model the head-of-line
  regression test pins), so TTFT includes real queueing and the
  percentiles are exactly reproducible. CPU wall-clock would measure
  Python dispatch overhead, not scheduling policy — at toy scale the
  chunked leg's extra dispatches swamp the padding it saves, which is why
  the wall seconds are reported unguarded while the gates ride on
  token-time. Each row records exact nearest-rank p50/p95/p99 TTFT and
  TPOT for both legs in token units (``repro.serve.telemetry``), goodput
  as useful tokens per dispatched position (utilization — one-shot
  prefill pays power-of-two bucket padding the chunked leg avoids), the
  p95-TTFT speedup, and the chunked leg's steady-state sanitizer counters
  (the chunk loop must add zero recompiles and zero implicit transfers).
  The non-smoke acceptance gates: chunked p95 TTFT must beat one-shot
  (``ttft_p95_speedup >= 1``) at equal-or-better goodput
  (``goodput_ratio >= 1``).

  The **static quant rows** run under the measured autotune cache
  (serve_bench/v8): the ``w4a8_aser`` leg builds its engines with
  ``RuntimeConfig(autotune="force")`` — the decode-plan entry is measured
  on a miss (``repro.kernels.autotune``) and the winning plan (e.g. the
  prepared f32-code layout that sidesteps XLA's refusal to hoist sliced
  scanned weights out of the decode loop) is applied at engine build.
  Each quant bucket is ALSO timed on the modeled routing the cache
  displaced (``autotune="off"``), and the row asserts the routed path is
  never slower: if it is, the measured winner lied on this machine — the
  bench **demotes** the cache entry (a tombstone consults skip) and
  serves/reports the displaced path instead. Non-smoke baselines gate
  ``decode_vs_fp >= 1`` on every quant row: quantized decode
  tokens/sec must beat fp, which is the whole point of serving W4A8.
  (The gate rides on decode throughput, not end-to-end: quantized
  *prefill* legitimately pays the quant chain at large m, and that
  trade is visible in the prefill_ms column, not hidden in the gate.)

Writes ``BENCH_serve.json`` at the repo root (schema ``serve_bench/v8`` =
v7's rows + autotuned-vs-displaced quant static columns; the validator
still accepts v1–v7 files) so subsequent PRs have a perf trajectory to
beat; ``--smoke`` runs a seconds-scale variant with the same schema for
CI. Latency rows use the XLA serving path (interpret-mode Pallas
wall-clock is meaningless on CPU); kernel-level tile economics live in
``kernels_bench``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common  # noqa: F401  (sys.path side effect for src/)
from repro.analysis.sanitizers import audit_steady_state
from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import init_params
from repro.quant import calibrate, quantize_model, reduce_shared
from repro.runtime import RuntimeConfig
from repro.serve.engine import (Engine, ServeConfig, blocks_for_hbm_budget,
                                kv_page_bytes)
from repro.serve.lifecycle import RequestStatus
from repro.serve.scheduler import Scheduler, _bucket
from repro.serve.telemetry import latency_summary

SCHEMA = "serve_bench/v8"
SCHEMA_V7 = "serve_bench/v7"
SCHEMA_V6 = "serve_bench/v6"
SCHEMA_V5 = "serve_bench/v5"
SCHEMA_V4 = "serve_bench/v4"
SCHEMA_V3 = "serve_bench/v3"
SCHEMA_V2 = "serve_bench/v2"
SCHEMA_V1 = "serve_bench/v1"
SCHEMA_PROBE = "serve_bench/probe"     # partial (continuous-only) runs
ROOT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ROW_FIELDS = ("mode", "batch", "prompt", "n_steps", "prefill_ms",
              "decode_ms_per_tok", "tokens_per_s", "scan_decode_ms_per_tok",
              "step_decode_ms_per_tok", "dispatch_overhead_ms_per_tok",
              "scan_speedup")

# measured-autotune fields added by serve_bench/v8 static rows. Quant rows
# are timed twice — under the measured autotune cache ("force") and on the
# modeled routing it displaced ("off") — and must never report the routed
# path slower than the displaced one: when it is, the bench demotes the
# cache entry and reports the displaced timings (autotune_demoted=True).
# decode_vs_fp = fp scan decode s/tok ÷ this row's scan decode s/tok at
# the same (batch, prompt); 1.0 on fp rows by construction. Non-smoke
# validation gates decode_vs_fp >= 1 on every w4a8_aser row.
AUTOTUNE_ROW_FIELDS = ("decode_tokens_per_s", "autotune", "decode_plan",
                       "displaced_decode_ms_per_tok", "autotune_demoted",
                       "decode_vs_fp")
ROW_FIELDS_V8 = ROW_FIELDS + AUTOTUNE_ROW_FIELDS

# goodput fields added by serve_bench/v2 continuous rows
CONT_ROW_FIELDS = ("mode", "requests", "batch_slots", "chunk",
                   "prompt_len_min", "prompt_len_max", "new_tokens_min",
                   "new_tokens_max", "useful_tokens", "static_s",
                   "continuous_s", "static_goodput_tok_s", "goodput_tok_s",
                   "goodput_speedup")

# steady-state sanitizer counters added by serve_bench/v6 continuous rows:
# after the warm run, an identical replay runs under
# jax.transfer_guard("disallow") with the backend-compile counter armed
# (repro.analysis.sanitizers.audit_steady_state). Both must be exactly
# zero — the validator rejects any nonzero value, so a retrace bomb or an
# implicit h2d upload on the decode path fails the bench, not just lint.
SANITIZER_FIELDS = ("recompiles_after_warmup", "h2d_transfers_per_step")
CONT_ROW_FIELDS_V6 = CONT_ROW_FIELDS + SANITIZER_FIELDS

# shared-prefix paged-cache fields added by serve_bench/v3 prefix rows
PREFIX_ROW_FIELDS = ("mode", "requests", "prefix_groups", "prefix_len",
                     "batch_slots", "chunk", "block_size", "num_blocks",
                     "useful_tokens", "noreuse_s", "reuse_s",
                     "noreuse_goodput_tok_s", "goodput_tok_s",
                     "goodput_speedup", "prefix_hit_rate")

# quantized-KV fixed-HBM-budget fields added by serve_bench/v4 kv rows.
# "bf16" here means the model's *native* cache dtype (f32 on the CPU bench).
KV_ROW_FIELDS = ("mode", "requests", "batch_slots", "chunk", "block_size",
                 "hbm_budget_kb", "bf16_blocks", "int8_blocks",
                 "useful_tokens", "bf16_s", "int8_s", "bf16_preemptions",
                 "int8_preemptions", "bf16_goodput_tok_s", "goodput_tok_s",
                 "goodput_speedup")

# chunked-prefill tail-latency fields added by serve_bench/v7 latency
# rows: the same wave-arrival workload served one-shot vs chunked +
# budgeted, measured in deterministic **token-time** (the scheduler's
# injectable clock advanced by each step's dispatched token positions —
# the same cost model as the head-of-line regression pin in
# tests/test_scheduler.py; `_tok` fields are token units, not seconds).
# Exact nearest-rank TTFT/TPOT percentiles for both legs
# (repro.serve.telemetry — NaN-free by construction, the reducer raises),
# goodput as useful tokens per dispatched token position (utilization —
# one-shot prefill pays power-of-two bucket padding the chunked leg
# avoids), wall-clock for reference, the p95-TTFT speedup, and the
# chunked leg's steady-state sanitizer counters (must be exactly zero:
# the chunk loop adds no retraces and no implicit transfers).
LATENCY_ROW_FIELDS = (
    "mode", "requests", "batch_slots", "chunk", "prefill_chunk",
    "step_token_budget", "block_size", "wave", "arrival_gap_tok",
    "useful_tokens",
    "oneshot_s", "chunked_s",
    "oneshot_tokens_dispatched", "tokens_dispatched",
    "oneshot_goodput_util", "goodput_util", "goodput_ratio",
    "oneshot_ttft_p50_tok", "oneshot_ttft_p95_tok", "oneshot_ttft_p99_tok",
    "oneshot_tpot_p50_tok", "oneshot_tpot_p95_tok", "oneshot_tpot_p99_tok",
    "ttft_p50_tok", "ttft_p95_tok", "ttft_p99_tok",
    "tpot_p50_tok", "tpot_p95_tok", "tpot_p99_tok",
    "ttft_p95_speedup",
    "chunked_recompiles_after_warmup", "chunked_h2d_transfers_per_step")

# multi-tenant adapter fields added by serve_bench/v5 adapter rows.
# w4a8_aser only: adapter pools ride on quantized leaves, fp has none.
# "base" legs run the same traffic adapter-free on a pool-free engine;
# token_exact certifies one request per tenant against its merged-weight
# reference generation (bool, not a latency).
ADAPTER_ROW_FIELDS = ("mode", "requests", "adapters", "adapter_rank",
                      "adapter_slots", "batch_slots", "chunk",
                      "useful_tokens", "base_s", "mixed_s",
                      "base_goodput_tok_s", "goodput_tok_s", "goodput_ratio",
                      "adapter_loads", "adapter_evictions", "token_exact")


def _bench_cfg(smoke: bool):
    base = get_smoke_config("llama3_8b")
    if smoke:
        return base.reduced(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                            head_dim=32, d_ff=128, vocab_size=128,
                            dtype="float32")
    return base.reduced(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                        head_dim=64, d_ff=512, vocab_size=512,
                        dtype="float32")


def _best_time(fn, reps: int) -> float:
    """Best-of-``reps`` wall-clock seconds of ``fn()`` (one untimed
    warmup/compile rep). Min, not mean: scheduler noise only ever adds."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_engine(params, cfg, rt, b, prompt, n_steps, max_len, reps):
    corpus_key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(corpus_key, (b, prompt), 0, cfg.vocab_size)
    out = {}
    for loop in ("scan", "step"):
        eng = Engine(params, cfg, ServeConfig(max_len=max_len,
                                              decode_loop=loop), rt=rt)
        t1 = _best_time(lambda: eng.generate(prompts, 1), reps)
        tn = _best_time(lambda: eng.generate(prompts, n_steps), reps)
        out[loop] = {"prefill_s": t1,
                     "decode_s_per_tok": max(tn - t1, 1e-9) / (n_steps - 1),
                     "total_s": tn}
    return out


def _time_quant_autotuned(qparams, cfg, rt, b, prompt, n_steps, max_len,
                          reps):
    """Time the quant static bucket under measured autotune next to the
    modeled routing it displaced, and enforce routed-never-slower.

    Returns ``(t, displaced, plan, demoted)`` where ``t`` are the timings
    to report, ``displaced`` the ``autotune="off"`` timings, ``plan`` the
    decode plan actually served, and ``demoted`` whether the cache entry
    was tombstoned because the measured winner lost the rematch on this
    machine (in which case ``t is displaced`` and ``plan == "default"``)."""
    from repro.kernels import autotune

    t_at = _time_engine(qparams, cfg, rt.replace(autotune="force"), b,
                        prompt, n_steps, max_len, reps)
    t_off = _time_engine(qparams, cfg, rt, b, prompt, n_steps, max_len, reps)
    key = autotune.engine_plan_key(qparams, cfg, ServeConfig(max_len=max_len))
    cache = autotune.get_cache()
    plan = "default"
    if key is not None:
        hit = cache.lookup(key)
        if hit is not None:
            plan = hit
    if t_at["scan"]["decode_s_per_tok"] > t_off["scan"]["decode_s_per_tok"]:
        if key is not None and plan != "default":
            cache.demote(key, f"slower than displaced modeled path at "
                              f"b={b} prompt={prompt}")
            cache.save()
        return t_off, t_off, "default", True
    return t_at, t_off, plan, False


# -- continuous-batching goodput --------------------------------------------

def _workload(n_requests, p_lo, p_hi, n_lo, n_hi, vocab, seed=13,
              straggler_frac=0.25):
    """Heavy-tailed mixed-length traffic: mostly short generations
    (``n_lo``..) with a ``straggler_frac`` tail of long ones (..``n_hi``) —
    the realistic chat mix, and the shape static batching handles worst
    (every batch runs at its straggler's length)."""
    rng = np.random.default_rng(seed)
    n_mid = max(n_lo + 1, (n_lo + n_hi) // 6)
    n_tail = max(n_mid + 1, (3 * n_hi) // 4)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(p_lo, p_hi + 1))
        if rng.random() < straggler_frac:
            n = int(rng.integers(n_tail, n_hi + 1))
        else:
            n = int(rng.integers(n_lo, n_mid + 1))
        reqs.append((rng.integers(0, vocab, size=plen).astype(np.int32), n))
    return reqs


def _run_static(engine, reqs):
    """Static baseline: slots-sized ragged batches, each running until its
    LONGEST request finishes (the pre-scheduler serving discipline, with the
    pad-position bug fixed via prompt_lens)."""
    slots = engine.scfg.batch_slots
    for group in (reqs[i:i + slots] for i in range(0, len(reqs), slots)):
        width = max(p.size for p, _ in group)
        padded = np.zeros((len(group), width), np.int32)
        for j, (p, _) in enumerate(group):
            padded[j, :p.size] = p
        lens = np.asarray([p.size for p, _ in group], np.int32)
        n_steps = max(n for _, n in group)
        jax.block_until_ready(engine.generate(
            jnp.asarray(padded), n_steps, prompt_lens=lens))


def _run_continuous(engine, reqs, chunk):
    sched = Scheduler(engine, chunk_size=chunk)
    handles = [sched.submit(p, n) for p, n in reqs]
    sched.run()
    return handles


def _time_continuous(params, cfg, rt, *, slots, max_len, chunk, reqs, reps):
    eng = Engine(params, cfg, ServeConfig(max_len=max_len,
                                          batch_slots=slots), rt=rt)
    handles = _run_continuous(eng, reqs, chunk)    # correctness gate + warm
    assert all(h.done for h in handles)
    # steady-state audit: replay the identical workload on the warmed
    # engine under the transfer guard + compile counter (serve_bench/v6)
    audit = audit_steady_state(
        lambda: Scheduler(eng, chunk_size=chunk),
        lambda sched: [sched.submit(p, n) for p, n in reqs])
    # both legs through _best_time: one timing policy for the comparison
    static_s = _best_time(lambda: _run_static(eng, reqs), reps)
    cont_s = _best_time(lambda: _run_continuous(eng, reqs, chunk), reps)
    useful = sum(n for _, n in reqs)      # eos disabled ⇒ budget == useful
    return static_s, cont_s, useful, audit


# -- shared-prefix prefix-cache goodput --------------------------------------

def _prefix_workload(n_requests, n_groups, prefix_len, t_lo, t_hi, n_lo,
                     n_hi, vocab, seed=17):
    """Multi-tenant chat traffic: every request is one of ``n_groups``
    system prompts (``prefix_len`` tokens, the shared part) plus a short
    unique tail — the shape the ref-counted prefix index exists for."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                for _ in range(n_groups)]
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(t_lo, t_hi + 1)))
        prompt = np.concatenate([prefixes[i % n_groups],
                                 tail.astype(np.int32)])
        reqs.append((prompt, int(rng.integers(n_lo, n_hi + 1))))
    return reqs


def _run_paged(engine, reqs, chunk, reuse):
    sched = Scheduler(engine, chunk_size=chunk, prefix_reuse=reuse)
    handles = [sched.submit(p, n) for p, n in reqs]
    sched.run()
    return sched, handles


def _time_prefix(params, cfg, rt, *, slots, max_len, block_size, chunk,
                 reqs, reps):
    eng = Engine(params, cfg, ServeConfig(max_len=max_len, batch_slots=slots,
                                          kv_layout="paged",
                                          block_size=block_size), rt=rt)
    sched, handles = _run_paged(eng, reqs, chunk, True)   # gate + warm
    assert all(h.done for h in handles)
    hit_rate = sched.prefix_hit_rate
    noreuse_s = _best_time(lambda: _run_paged(eng, reqs, chunk, False), reps)
    reuse_s = _best_time(lambda: _run_paged(eng, reqs, chunk, True), reps)
    useful = sum(n for _, n in reqs)
    return noreuse_s, reuse_s, useful, hit_rate, eng.scfg.pool_blocks


# -- quantized-KV goodput at a fixed HBM budget ------------------------------

def _time_kv_budget(params, cfg, rt, *, slots, max_len, block_size, chunk,
                    reqs, reps):
    """Native-KV vs int8-KV paged continuous serving at one HBM budget.

    The budget is chosen memory-constrained (a quarter of the slots' worth
    of native pages — enough for only ~2 full-length requests natively) so
    the native leg queues on admission; the int8 pool converts its ~4×
    smaller page (f32 native on this CPU bench) into proportionally more
    blocks at the same budget and admits more of the workload
    concurrently. An ample budget would instead measure pure dequant
    overhead — the unconstrained-memory latency story already lives in the
    static rows.
    """
    bps = max_len // block_size
    native_blocks = max(bps, (slots * bps) // 4)
    budget = native_blocks * kv_page_bytes(cfg, block_size, "bf16")
    int8_blocks = blocks_for_hbm_budget(cfg, block_size, "int8", budget)

    def mk(kv_dtype, blocks):
        return Engine(params, cfg,
                      ServeConfig(max_len=max_len, batch_slots=slots,
                                  kv_layout="paged", block_size=block_size,
                                  num_blocks=blocks, kv_dtype=kv_dtype),
                      rt=rt)

    engines = {"bf16": mk("bf16", native_blocks),
               "int8": mk("int8", int8_blocks)}
    out = {}
    for name, eng in engines.items():
        sched, handles = _run_paged(eng, reqs, chunk, True)  # gate + warm
        assert all(h.done for h in handles)
        out[name + "_preemptions"] = sched.preemptions
        out[name + "_s"] = _best_time(
            lambda e=eng: _run_paged(e, reqs, chunk, True), reps)
    useful = sum(n for _, n in reqs)
    return (budget, native_blocks, int8_blocks, useful,
            out["bf16_s"], out["int8_s"],
            out["bf16_preemptions"], out["int8_preemptions"])


# -- chunked-prefill tail latency --------------------------------------------

def _latency_workload(n_requests, vocab, *, p_short, p_strag, n_lo, n_hi,
                      seed=31, straggler_frac=0.25):
    """Wave traffic for the TTFT-tail comparison: mostly short prompts
    with a ``straggler_frac`` tail of long-prompt requests. The long
    prompts are the head-of-line blockers — one-shot admission prefills
    each as a single power-of-two-bucketed dispatch (a 33-token prompt
    pays for 64 positions) that every co-scheduled request's step waits
    behind; chunked prefill pays only per-chunk buckets and spreads the
    work across budgeted steps."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        lo, hi = p_strag if rng.random() < straggler_frac else p_short
        plen = int(rng.integers(lo, hi + 1))
        n = int(rng.integers(n_lo, n_hi + 1))
        reqs.append((rng.integers(0, vocab, size=plen).astype(np.int32), n))
    return reqs


def _run_latency(engine, reqs, chunk, arrivals):
    """Serve ``reqs`` under open-loop arrivals on a deterministic
    token-time clock.

    ``arrivals[i]`` is request *i*'s arrival instant in token-time. The
    scheduler's injectable ``clock`` reads a counter this driver advances
    after every step by the step's dispatched token positions — the same
    cost model the head-of-line regression pins in
    ``tests/test_scheduler.py``: under a token budget the scheduler's own
    ``last_step_tokens`` accounting; one-shot, the power-of-two-bucketed
    prompt width of each admission this step plus one decode chunk per
    occupied slot. Submissions happen *between* steps whenever their
    arrival instant has passed, so TTFT (stamped from submit by the same
    clock) includes real admission queueing — the SLO number, not just
    prefill latency. When the scheduler goes idle before the next
    arrival, the clock jumps forward to it (open-loop traffic does not
    wait for the server). Returns the scheduler, the handles, and the
    total token positions dispatched (excluding idle time)."""
    clk = [0.0]
    sched = Scheduler(engine, chunk_size=chunk, clock=lambda: clk[0])
    handles = []
    dispatched = 0
    i = 0
    while True:
        while i < len(reqs) and arrivals[i] <= clk[0]:
            p, n = reqs[i]
            handles.append(sched.submit(p, n))
            i += 1
        queued = [h for h in handles if h.status is RequestStatus.QUEUED]
        more = sched.step()
        if sched.prefill_chunk:
            cost = sched.last_step_tokens
        else:
            admitted = sum(
                _bucket(len(h.request.prompt), sched.max_len)
                for h in queued if h.status is not RequestStatus.QUEUED)
            decoding = sum(1 for s in range(sched.slots)
                           if sched._slot_handle[s] is not None)
            cost = admitted + chunk * decoding
        dispatched += cost
        clk[0] += max(cost, 1)
        if i < len(reqs):
            if not more:                       # idle until the next arrival
                clk[0] = max(clk[0], arrivals[i])
        elif not more:
            break
    assert all(h.done for h in handles)
    return sched, handles, dispatched


def _time_latency(params, cfg, rt, *, slots, max_len, block_size, chunk,
                  prefill_chunk, step_token_budget, reqs, wave, gap, reps):
    """One-shot vs chunked+budgeted prefill over the same open-loop
    traffic: waves of ``wave`` requests arriving every ``gap`` token-time
    units.

    Both legs run the paged engine and the identical arrival schedule;
    the chunked leg's engine sets ``ServeConfig(prefill_chunk,
    step_token_budget)``. Percentiles and dispatched-token totals come
    from the deterministic token-time driver (a gate run pays
    compilation first, then a warm measurement run — token-time is
    wall-clock-independent, but the warm run keeps the wall seconds
    comparable); wall seconds come from ``_best_time`` and are reported
    unguarded (CPU wall-clock measures Python dispatch overhead, not
    scheduling policy). The chunked leg additionally replays under the
    steady-state audit — the chunk loop must add zero recompiles and
    zero implicit transfers."""
    arrivals = [(i // wave) * gap for i in range(len(reqs))]

    def mk(chunked):
        sc = ServeConfig(max_len=max_len, batch_slots=slots,
                         kv_layout="paged", block_size=block_size)
        if chunked:
            sc = dataclasses.replace(sc, prefill_chunk=prefill_chunk,
                                     step_token_budget=step_token_budget)
        return Engine(params, cfg, sc, rt=rt)

    legs = {"oneshot": mk(False), "chunked": mk(True)}
    out = {}
    for name, eng in legs.items():
        _run_latency(eng, reqs, chunk, arrivals)       # gate + warm
        _, handles, dispatched = _run_latency(eng, reqs, chunk, arrivals)
        summ = latency_summary([h.timing for h in handles])
        # latency_summary scales to milliseconds for wall clocks; undo it —
        # this clock counts token positions, not seconds
        out[name] = {fam: {q: v / 1e3 for q, v in summ[fam].items()}
                     for fam in ("ttft_ms", "tpot_ms")}
        out[name + "_tokens"] = dispatched
        out[name + "_s"] = _best_time(
            lambda e=eng: _run_latency(e, reqs, chunk, arrivals), reps)
    audit = audit_steady_state(
        lambda: Scheduler(legs["chunked"], chunk_size=chunk),
        lambda sched: [sched.submit(p, n) for p, n in reqs])
    useful = sum(n for _, n in reqs)
    return out, useful, audit


# -- multi-tenant adapter goodput --------------------------------------------

def _run_adapters(engine, reqs, chunk, registry, apool=None):
    """One serve of ``reqs`` (``(prompt, n, adapter_id)``); ``registry``
    None = base leg (adapter-free scheduler, tags ignored). ``apool`` is
    the warm shared pool: factor loads happen on the gate run, the timed
    reps hit resident slots — matching a long-lived serving process."""
    sched = Scheduler(engine, chunk_size=chunk, adapters=registry,
                      adapter_pool=apool)
    handles = [sched.submit(p, n,
                            adapter_id=aid if registry is not None else None)
               for p, n, aid in reqs]
    sched.run()
    return sched, handles


def _time_adapters(qparams, cfg, rt, *, n_adapters, rank, slots, max_len,
                   block_size, chunk, reqs, reps):
    """Mixed N-tenant traffic vs the same traffic served base-only.

    Both legs run the paged continuous-batching scheduler over the same
    request stream; the mixed leg routes each request through its tenant's
    pooled factors (batched-gather epilogue), the base leg serves a
    pool-free engine (the adapter-free compiled programs). The gate run
    also certifies one request per tenant token-exact against its
    merged-weight reference (``AdapterRegistry.merged_params``) — routed
    serving must price in zero accuracy.
    """
    from repro.serve.adapters import AdapterPool, AdapterRegistry, \
        install_pools
    reg = AdapterRegistry(qparams, rank=rank)
    tenants = [reg.add(f"tenant-{i}") for i in range(n_adapters)]
    pooled = install_pools(qparams, slots=n_adapters + 1, rank=rank)
    apool = AdapterPool(n_adapters + 1)      # shared: pool lifetime = engine's
    # round-robin tenant tags with base traffic threaded through: every
    # (n_adapters+1)-th request serves the unadapted base from the same batch
    reqs = [(p, n, None if i % (n_adapters + 1) == 0
             else tenants[i % n_adapters]) for i, (p, n) in enumerate(reqs)]

    def mk(params):
        return Engine(params, cfg,
                      ServeConfig(max_len=max_len, batch_slots=slots,
                                  kv_layout="paged",
                                  block_size=block_size), rt=rt)

    base_eng, mixed_eng = mk(qparams), mk(pooled)
    # correctness gate + warm: every tenant's first request token-exact
    # against its merged-weight single-request generation
    sched, handles = _run_adapters(mixed_eng, reqs, chunk, reg, apool)
    assert all(h.done for _, h in zip(reqs, handles))
    token_exact = True
    seen = set()
    for (p, n, aid), h in zip(reqs, handles):
        if aid in seen:
            continue
        seen.add(aid)
        refp = qparams if aid is None else reg.merged_params(qparams, aid)
        ref_eng = Engine(refp, cfg, ServeConfig(max_len=max_len,
                                                batch_slots=1), rt=rt)
        ref = np.asarray(ref_eng.generate(jnp.asarray(p[None]), n))[0]
        token_exact &= bool(np.array_equal(np.asarray(h.tokens), ref))
    loads, evictions = sched.adapter_loads, sched.apool.evictions
    _run_adapters(base_eng, reqs, chunk, None)           # warm the base leg
    base_s = _best_time(lambda: _run_adapters(base_eng, reqs, chunk, None),
                        reps)
    mixed_s = _best_time(
        lambda: _run_adapters(mixed_eng, reqs, chunk, reg, apool), reps)
    useful = sum(n for _, n, _ in reqs)
    return base_s, mixed_s, useful, token_exact, loads, evictions


def run(smoke: bool = False, out_path: str = ROOT_OUT, verbose: bool = True,
        mode: str = "both"):
    cfg = dataclasses.replace(_bench_cfg(smoke), remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 32)), cfg)
    qparams = quantize_model(params, tape, "aser_as")
    rt = RuntimeConfig(use_pallas=False)     # XLA serving path (CPU-honest)

    buckets = [(1, 16), (4, 16)] if smoke else [(1, 32), (4, 64), (8, 64)]
    n_steps = 16 if smoke else 64
    reps = 3 if smoke else 5
    max_len = 64 if smoke else 128

    rows = []
    fp_scan_tok = {}    # (batch, prompt) -> fp scan decode s/tok
    cont_rows = []
    prefix_rows = []
    kv_rows = []
    adapter_rows = []
    latency_rows = []
    for m, p in (("fp", params), ("w4a8_aser", qparams)):
        if mode in ("both", "static"):
            for (b, prompt) in buckets:
                if m == "fp":
                    t = _time_engine(p, cfg, rt, b, prompt, n_steps,
                                     max_len, reps)
                    displaced, plan, demoted = t, "default", False
                    at_mode = "off"
                else:
                    t, displaced, plan, demoted = _time_quant_autotuned(
                        p, cfg, rt, b, prompt, n_steps, max_len, reps)
                    at_mode = "force"
                scan_tok = t["scan"]["decode_s_per_tok"]
                step_tok = t["step"]["decode_s_per_tok"]
                if m == "fp":
                    fp_scan_tok[(b, prompt)] = scan_tok
                fp_tok = fp_scan_tok.get((b, prompt), scan_tok)
                row = {
                    "mode": m, "batch": b, "prompt": prompt,
                    "n_steps": n_steps,
                    "prefill_ms": 1e3 * t["scan"]["prefill_s"],
                    "decode_ms_per_tok": 1e3 * scan_tok,
                    "tokens_per_s": b * n_steps / t["scan"]["total_s"],
                    "scan_decode_ms_per_tok": 1e3 * scan_tok,
                    "step_decode_ms_per_tok": 1e3 * step_tok,
                    "dispatch_overhead_ms_per_tok": 1e3 * (step_tok
                                                           - scan_tok),
                    "scan_speedup": step_tok / max(scan_tok, 1e-12),
                    "decode_tokens_per_s": b / scan_tok,
                    "autotune": at_mode,
                    "decode_plan": plan,
                    "displaced_decode_ms_per_tok":
                        1e3 * displaced["scan"]["decode_s_per_tok"],
                    "autotune_demoted": demoted,
                    "decode_vs_fp": fp_tok / scan_tok,
                }
                rows.append(row)
                if verbose:
                    print(f"  {m:>10} b={b} s={prompt}: "
                          f"prefill {row['prefill_ms']:7.2f}ms  "
                          f"decode {row['decode_ms_per_tok']:6.2f}ms/tok "
                          f"(step {row['step_decode_ms_per_tok']:6.2f})  "
                          f"{row['tokens_per_s']:8.1f} tok/s  "
                          f"scan×{row['scan_speedup']:.2f}  "
                          f"plan={row['decode_plan']}"
                          f"{' DEMOTED' if demoted else ''}  "
                          f"vs fp ×{row['decode_vs_fp']:.2f}", flush=True)

        if mode in ("both", "continuous"):
            slots = 2 if smoke else 8
            chunk = 4 if smoke else 8
            n_req = 8 if smoke else 32
            p_lo, p_hi = (2, 10) if smoke else (4, 32)
            n_lo, n_hi = (2, 12) if smoke else (4, 56)
            c_reps = 2 if smoke else 3
            reqs = _workload(n_req, p_lo, p_hi, n_lo, n_hi, cfg.vocab_size)
            static_s, cont_s, useful, audit = _time_continuous(
                p, cfg, rt, slots=slots, max_len=max_len, chunk=chunk,
                reqs=reqs, reps=c_reps)
            crow = {
                "mode": m, "requests": n_req, "batch_slots": slots,
                "chunk": chunk,
                "prompt_len_min": p_lo, "prompt_len_max": p_hi,
                "new_tokens_min": n_lo, "new_tokens_max": n_hi,
                "useful_tokens": useful,
                "static_s": static_s, "continuous_s": cont_s,
                "static_goodput_tok_s": useful / static_s,
                "goodput_tok_s": useful / cont_s,
                "goodput_speedup": static_s / cont_s,
                "recompiles_after_warmup": audit.recompiles,
                "h2d_transfers_per_step": audit.h2d_transfers_per_step,
            }
            cont_rows.append(crow)
            if verbose:
                print(f"  {m:>10} continuous: {n_req} reqs on {slots} slots "
                      f"(chunk {chunk}): goodput "
                      f"{crow['goodput_tok_s']:7.1f} tok/s vs static "
                      f"{crow['static_goodput_tok_s']:7.1f} "
                      f"(×{crow['goodput_speedup']:.2f})", flush=True)

            # shared-prefix workload on the paged engine: reuse vs no-reuse
            block_size = 8 if smoke else 16
            n_groups = 2
            prefix_len = 16 if smoke else 32
            t_lo, t_hi = (2, 6) if smoke else (2, 12)
            pn_lo, pn_hi = (2, 8) if smoke else (4, 24)
            preqs = _prefix_workload(n_req, n_groups, prefix_len, t_lo, t_hi,
                                     pn_lo, pn_hi, cfg.vocab_size)
            noreuse_s, reuse_s, useful, hit_rate, pool = _time_prefix(
                p, cfg, rt, slots=slots, max_len=max_len,
                block_size=block_size, chunk=chunk, reqs=preqs, reps=c_reps)
            prow = {
                "mode": m, "requests": n_req, "prefix_groups": n_groups,
                "prefix_len": prefix_len, "batch_slots": slots,
                "chunk": chunk, "block_size": block_size,
                "num_blocks": pool, "useful_tokens": useful,
                "noreuse_s": noreuse_s, "reuse_s": reuse_s,
                "noreuse_goodput_tok_s": useful / noreuse_s,
                "goodput_tok_s": useful / reuse_s,
                "goodput_speedup": noreuse_s / reuse_s,
                "prefix_hit_rate": hit_rate,
            }
            prefix_rows.append(prow)
            if verbose:
                print(f"  {m:>10} shared-prefix: {n_req} reqs × "
                      f"{n_groups} prefixes ({prefix_len} tok, paged "
                      f"bs={block_size}): goodput "
                      f"{prow['goodput_tok_s']:7.1f} tok/s vs no-reuse "
                      f"{prow['noreuse_goodput_tok_s']:7.1f} "
                      f"(×{prow['goodput_speedup']:.2f}, hit rate "
                      f"{hit_rate:.0%})", flush=True)

            # int8-KV vs native-KV at one fixed HBM budget (memory-bound)
            kv_lo, kv_hi = (8, 24) if smoke else (16, 48)
            kreqs = _workload(n_req, p_lo, p_hi, kv_lo, kv_hi,
                              cfg.vocab_size, seed=23)
            (budget, nb_native, nb_int8, useful, bf16_s, int8_s,
             bf16_pre, int8_pre) = _time_kv_budget(
                p, cfg, rt, slots=slots, max_len=max_len,
                block_size=block_size, chunk=chunk, reqs=kreqs,
                reps=c_reps)
            krow = {
                "mode": m, "requests": n_req, "batch_slots": slots,
                "chunk": chunk, "block_size": block_size,
                "hbm_budget_kb": budget / 1024,
                "bf16_blocks": nb_native, "int8_blocks": nb_int8,
                "useful_tokens": useful,
                "bf16_s": bf16_s, "int8_s": int8_s,
                "bf16_preemptions": bf16_pre,
                "int8_preemptions": int8_pre,
                "bf16_goodput_tok_s": useful / bf16_s,
                "goodput_tok_s": useful / int8_s,
                "goodput_speedup": bf16_s / int8_s,
            }
            kv_rows.append(krow)
            if verbose:
                print(f"  {m:>10} kv-quant: {n_req} reqs at "
                      f"{krow['hbm_budget_kb']:.0f} KiB KV budget "
                      f"(native {nb_native} / int8 {nb_int8} blocks): "
                      f"goodput {krow['goodput_tok_s']:7.1f} tok/s vs "
                      f"native {krow['bf16_goodput_tok_s']:7.1f} "
                      f"(×{krow['goodput_speedup']:.2f}, preemptions "
                      f"{bf16_pre}→{int8_pre})", flush=True)

            # chunked-prefill tail latency: open-loop wave arrivals in
            # token-time, served one-shot vs chunked + token-budgeted on
            # the paged engine. Straggler prompts sit just past a
            # power-of-two bucket boundary so the one-shot leg pays
            # maximal prefill padding; the chunked leg pays per-chunk
            # buckets and bounded steps. The arrival gap undercuts the
            # service rate — the tail only exists under queueing pressure,
            # and bounding step size is precisely what drains a backlog
            # fairly. Scheduler ctor constraint:
            # prefill_chunk + chunk <= step_token_budget.
            l_pc = 8 if smoke else 32
            l_budget = 20 if smoke else 160
            lp_short = (2, 6) if smoke else (4, 12)
            lp_strag = (17, 24) if smoke else (65, 80)
            ln_lo, ln_hi = (2, 6) if smoke else (4, 12)
            wave = 3 if smoke else 6
            gap = 40 if smoke else 120
            lreqs = _latency_workload(n_req, cfg.vocab_size,
                                      p_short=lp_short, p_strag=lp_strag,
                                      n_lo=ln_lo, n_hi=ln_hi)
            lat, useful, laudit = _time_latency(
                p, cfg, rt, slots=slots, max_len=max_len,
                block_size=block_size, chunk=chunk, prefill_chunk=l_pc,
                step_token_budget=l_budget, reqs=lreqs, wave=wave,
                gap=gap, reps=c_reps)
            lrow = {
                "mode": m, "requests": n_req, "batch_slots": slots,
                "chunk": chunk, "prefill_chunk": l_pc,
                "step_token_budget": l_budget, "block_size": block_size,
                "wave": wave, "arrival_gap_tok": gap,
                "useful_tokens": useful,
                "oneshot_s": lat["oneshot_s"],
                "chunked_s": lat["chunked_s"],
                "oneshot_tokens_dispatched": lat["oneshot_tokens"],
                "tokens_dispatched": lat["chunked_tokens"],
                "oneshot_goodput_util": useful / lat["oneshot_tokens"],
                "goodput_util": useful / lat["chunked_tokens"],
                "goodput_ratio": (lat["oneshot_tokens"]
                                  / lat["chunked_tokens"]),
                "ttft_p95_speedup": (lat["oneshot"]["ttft_ms"]["p95"]
                                     / lat["chunked"]["ttft_ms"]["p95"]),
                "chunked_recompiles_after_warmup": laudit.recompiles,
                "chunked_h2d_transfers_per_step":
                    laudit.h2d_transfers_per_step,
            }
            for prefix, leg in (("oneshot_", "oneshot"), ("", "chunked")):
                for fam in ("ttft", "tpot"):
                    for q in (50, 95, 99):
                        lrow[f"{prefix}{fam}_p{q}_tok"] = \
                            lat[leg][f"{fam}_ms"][f"p{q}"]
            latency_rows.append(lrow)
            if verbose:
                print(f"  {m:>10} latency: {n_req} reqs in waves of {wave} "
                      f"every {gap} tok (prefill_chunk {l_pc}, budget "
                      f"{l_budget}): p95 TTFT {lrow['ttft_p95_tok']:6.0f} "
                      f"tok vs one-shot {lrow['oneshot_ttft_p95_tok']:6.0f} "
                      f"(×{lrow['ttft_p95_speedup']:.2f}, goodput ratio "
                      f"{lrow['goodput_ratio']:.2f})", flush=True)

    if mode in ("both", "continuous"):
        # multi-tenant adapters: w4a8_aser only (pools ride on quantized
        # leaves — fp params have nothing to install them on)
        slots = 2 if smoke else 8
        chunk = 4 if smoke else 8
        n_req = 9 if smoke else 27
        n_adapters = 4 if smoke else 8
        a_rank = 4 if smoke else 8
        block_size = 8 if smoke else 16
        a_reps = 2 if smoke else 3
        p_lo, p_hi = (2, 10) if smoke else (4, 32)
        a_lo, a_hi = (2, 12) if smoke else (4, 40)
        areqs = _workload(n_req, p_lo, p_hi, a_lo, a_hi, cfg.vocab_size,
                          seed=29)
        base_s, mixed_s, useful, token_exact, loads, evictions = \
            _time_adapters(qparams, cfg, rt, n_adapters=n_adapters,
                           rank=a_rank, slots=slots, max_len=max_len,
                           block_size=block_size, chunk=chunk, reqs=areqs,
                           reps=a_reps)
        arow = {
            "mode": "w4a8_aser", "requests": n_req, "adapters": n_adapters,
            "adapter_rank": a_rank, "adapter_slots": n_adapters + 1,
            "batch_slots": slots, "chunk": chunk, "useful_tokens": useful,
            "base_s": base_s, "mixed_s": mixed_s,
            "base_goodput_tok_s": useful / base_s,
            "goodput_tok_s": useful / mixed_s,
            "goodput_ratio": base_s / mixed_s,
            "adapter_loads": loads, "adapter_evictions": evictions,
            "token_exact": token_exact,
        }
        adapter_rows.append(arow)
        if verbose:
            print(f"  w4a8_aser adapters: {n_req} reqs x {n_adapters} "
                  f"tenants (rank {a_rank}): goodput "
                  f"{arow['goodput_tok_s']:7.1f} tok/s vs base-only "
                  f"{arow['base_goodput_tok_s']:7.1f} "
                  f"(ratio {arow['goodput_ratio']:.2f}, "
                  f"token-exact {token_exact})", flush=True)

    # partial runs must self-describe honestly: static-only is a valid v1
    # file; continuous-only matches no released schema and is stamped as a
    # probe (the validator rejects it by design — it is not a baseline)
    schema = {"static": SCHEMA_V1, "continuous": SCHEMA_PROBE}.get(mode,
                                                                   SCHEMA)
    report = {
        "schema": schema,
        "smoke": smoke,
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "vocab_size": cfg.vocab_size},
        "decode_loop_default": "scan",
        "rows": rows,
    }
    if mode != "static":
        report["continuous_rows"] = cont_rows
        report["prefix_rows"] = prefix_rows
        report["kv_rows"] = kv_rows
        report["adapter_rows"] = adapter_rows
        report["latency_rows"] = latency_rows
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    if verbose:
        print(f"  wrote {os.path.abspath(out_path)}")
    return report


# -- schema validation (CI smoke gate) --------------------------------------

def _check_finite(row, fields, positive=()):
    missing = [f for f in fields if f not in row]
    if missing:
        raise ValueError(f"row missing fields {missing}: {row}")
    for f in fields:
        # legitimate string fields
        if f in ("mode", "autotune", "decode_plan"):
            continue
        # bools, checked by their row validators
        if f in ("token_exact", "autotune_demoted"):
            continue
        v = row[f]
        if isinstance(v, bool) or not isinstance(v, (int, float)) \
                or not (v == v and abs(v) < 1e12):
            raise ValueError(f"non-finite {f}={v!r} in {row}")
        if f in positive and v <= 0:
            raise ValueError(f"non-positive {f}={v!r} in {row}")


def _validate_static_rows(rows, v8=False, smoke=True):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no benchmark rows")
    modes = set()
    for row in rows:
        # deltas (dispatch_overhead, speedup) may dip negative/below-1 on a
        # noisy CI machine; absolute latencies must be positive
        fields = ROW_FIELDS_V8 if v8 else ROW_FIELDS
        positive = ("prefill_ms", "decode_ms_per_tok", "tokens_per_s")
        if v8:
            positive += ("decode_tokens_per_s",
                         "displaced_decode_ms_per_tok", "decode_vs_fp")
        _check_finite(row, fields, positive=positive)
        if v8:
            if row["autotune"] not in ("off", "cache", "force"):
                raise ValueError(f"bad autotune mode in {row}")
            if not isinstance(row["decode_plan"], str):
                raise ValueError(f"decode_plan must be a string: {row}")
            if not isinstance(row["autotune_demoted"], bool):
                raise ValueError(f"autotune_demoted must be a bool: {row}")
            # satellite assertion: the routed kernel is never slower than
            # the path it displaced (a demotion reports displaced == routed,
            # so this holds by construction unless the bench is broken)
            if row["decode_ms_per_tok"] > \
                    row["displaced_decode_ms_per_tok"] * 1.001 + 1e-9:
                raise ValueError(
                    f"routed path slower than the displaced path it was "
                    f"measured to beat (demotion failed?): {row}")
            if not smoke and row["mode"] == "w4a8_aser" \
                    and row["decode_vs_fp"] < 1.0:
                raise ValueError(
                    f"quantized decode lost to fp "
                    f"(decode_vs_fp={row['decode_vs_fp']:.3f} < 1) — the "
                    f"W4A8 serving path must win on decode throughput: "
                    f"{row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser rows, got {modes}")


def _validate_continuous_rows(rows, sanitizers=False):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no continuous rows (serve_bench/v2+ requires them)")
    modes = set()
    fields = CONT_ROW_FIELDS_V6 if sanitizers else CONT_ROW_FIELDS
    for row in rows:
        _check_finite(row, fields,
                      positive=("useful_tokens", "static_s", "continuous_s",
                                "static_goodput_tok_s", "goodput_tok_s"))
        if sanitizers:
            for f in SANITIZER_FIELDS:
                if row[f] != 0:
                    raise ValueError(
                        f"steady-state decode is not clean: {f}={row[f]!r} "
                        f"(must be exactly 0 — a retrace or implicit "
                        f"transfer survived warmup): {row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser continuous rows, "
                         f"got {modes}")


def _validate_prefix_rows(rows):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no prefix rows (serve_bench/v3 requires them)")
    modes = set()
    for row in rows:
        _check_finite(row, PREFIX_ROW_FIELDS,
                      positive=("useful_tokens", "noreuse_s", "reuse_s",
                                "noreuse_goodput_tok_s", "goodput_tok_s",
                                "prefix_hit_rate"))
        if not 0 < row["prefix_hit_rate"] <= 1:
            raise ValueError(f"prefix_hit_rate out of (0, 1]: {row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser prefix rows, got {modes}")


def _validate_kv_rows(rows):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no kv rows (serve_bench/v4 requires them)")
    modes = set()
    for row in rows:
        _check_finite(row, KV_ROW_FIELDS,
                      positive=("useful_tokens", "bf16_s", "int8_s",
                                "bf16_blocks", "int8_blocks",
                                "hbm_budget_kb", "bf16_goodput_tok_s",
                                "goodput_tok_s"))
        if row["int8_blocks"] < row["bf16_blocks"]:
            raise ValueError(
                f"int8 pool smaller than native at equal budget: {row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser kv rows, got {modes}")


def _validate_adapter_rows(rows):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no adapter rows (serve_bench/v5 requires them)")
    for row in rows:
        _check_finite(row, ADAPTER_ROW_FIELDS,
                      positive=("useful_tokens", "base_s", "mixed_s",
                                "adapters", "adapter_rank", "adapter_slots",
                                "base_goodput_tok_s", "goodput_tok_s",
                                "goodput_ratio"))
        if row["mode"] != "w4a8_aser":
            raise ValueError(f"adapter rows are w4a8_aser-only (pools ride "
                             f"on quantized leaves): {row}")
        if row["token_exact"] is not True:
            raise ValueError(f"adapter serving not token-exact vs merged "
                             f"reference: {row}")
        if row["goodput_ratio"] < 0.85:
            raise ValueError(f"mixed-tenant goodput below 0.85x base-only: "
                             f"{row}")


def _validate_latency_rows(rows, smoke):
    if not isinstance(rows, list) or not rows:
        raise ValueError("no latency rows (serve_bench/v7 requires them)")
    pct = tuple(f"{prefix}{fam}_p{q}_tok" for prefix in ("", "oneshot_")
                for fam in ("ttft", "tpot") for q in (50, 95, 99))
    modes = set()
    for row in rows:
        # percentiles are finite-checked but may legitimately be zero: on
        # the token-time clock an uncontended request admitted in the step
        # after its arrival has TTFT 0 (events stamp at step granularity)
        _check_finite(row, LATENCY_ROW_FIELDS,
                      positive=("useful_tokens", "oneshot_s", "chunked_s",
                                "prefill_chunk", "step_token_budget",
                                "wave", "arrival_gap_tok",
                                "oneshot_tokens_dispatched",
                                "tokens_dispatched",
                                "oneshot_goodput_util", "goodput_util",
                                "goodput_ratio", "ttft_p95_speedup"))
        for f in pct:
            if row[f] < 0:
                raise ValueError(f"negative percentile {f}={row[f]!r} "
                                 f"in {row}")
        for f in ("oneshot_goodput_util", "goodput_util"):
            if row[f] > 1:
                raise ValueError(
                    f"{f}={row[f]!r} > 1: useful tokens cannot exceed "
                    f"dispatched token positions: {row}")
        for fam in ("ttft", "tpot"):
            for prefix in ("", "oneshot_"):
                p50, p95, p99 = (row[f"{prefix}{fam}_p{q}_tok"]
                                 for q in (50, 95, 99))
                if not p50 <= p95 <= p99:
                    raise ValueError(
                        f"{prefix}{fam} percentiles out of order "
                        f"(p50 {p50} / p95 {p95} / p99 {p99} must be "
                        f"non-decreasing): {row}")
        for f in ("chunked_recompiles_after_warmup",
                  "chunked_h2d_transfers_per_step"):
            if row[f] != 0:
                raise ValueError(
                    f"chunked steady state is not clean: {f}={row[f]!r} "
                    f"(must be exactly 0 — the chunk loop retraced or "
                    f"uploaded implicitly): {row}")
        if not smoke:
            # the acceptance gates chunked prefill ships under: better
            # p95 TTFT at equal-or-better goodput. Smoke runs are too
            # small for stable tails (p95 of 8 requests is the max) and
            # only have to be well-formed.
            if row["ttft_p95_speedup"] < 1.0:
                raise ValueError(
                    f"chunked prefill did not improve p95 TTFT "
                    f"(speedup {row['ttft_p95_speedup']:.3f} < 1): {row}")
            if row["goodput_ratio"] < 1.0:
                raise ValueError(
                    f"chunked goodput below one-shot "
                    f"(ratio {row['goodput_ratio']:.3f} < 1): {row}")
        modes.add(row["mode"])
    if not {"fp", "w4a8_aser"} <= modes:
        raise ValueError(f"need fp and w4a8_aser latency rows, got {modes}")


def validate(report: dict):
    """Raise ValueError unless ``report`` is a valid serve_bench file.

    Accepts every released schema generation: ``serve_bench/v1`` (static
    rows only), ``serve_bench/v2`` (+ continuous goodput rows),
    ``serve_bench/v3`` (+ shared-prefix paged-cache rows),
    ``serve_bench/v4`` (+ fixed-HBM-budget KV-quant rows),
    ``serve_bench/v5`` (+ multi-tenant adapter rows), ``serve_bench/v6``
    (+ steady-state sanitizer counters on continuous rows, required to be
    exactly zero), ``serve_bench/v7`` (+ chunked-vs-one-shot prefill
    tail-latency rows with exact TTFT/TPOT percentiles and, on non-smoke
    baselines, the improvement gates) and ``serve_bench/v8`` (+ measured
    autotune columns on static rows: routed-never-slower-than-displaced
    always, and on non-smoke baselines ``decode_vs_fp >= 1`` on every
    quant row), so old baselines keep validating.
    """
    schema = report.get("schema")
    if schema not in (SCHEMA, SCHEMA_V7, SCHEMA_V6, SCHEMA_V5, SCHEMA_V4,
                      SCHEMA_V3, SCHEMA_V2, SCHEMA_V1):
        raise ValueError(f"schema mismatch: {schema!r}")
    _validate_static_rows(report.get("rows"), v8=schema == SCHEMA,
                          smoke=bool(report.get("smoke")))
    if schema != SCHEMA_V1:
        _validate_continuous_rows(
            report.get("continuous_rows"),
            sanitizers=schema in (SCHEMA, SCHEMA_V7, SCHEMA_V6))
    if schema not in (SCHEMA_V1, SCHEMA_V2):
        _validate_prefix_rows(report.get("prefix_rows"))
    if schema not in (SCHEMA_V1, SCHEMA_V2, SCHEMA_V3):
        _validate_kv_rows(report.get("kv_rows"))
    if schema in (SCHEMA, SCHEMA_V7, SCHEMA_V6, SCHEMA_V5):
        _validate_adapter_rows(report.get("adapter_rows"))
    if schema in (SCHEMA, SCHEMA_V7):
        _validate_latency_rows(report.get("latency_rows"),
                               smoke=bool(report.get("smoke")))
    return True


def validate_file(path: str = ROOT_OUT):
    with open(path) as f:
        validate(json.load(f))
    print(f"{path}: serve_bench schema OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (same schema)")
    ap.add_argument("--mode", choices=("both", "static", "continuous"),
                    default="both",
                    help="which workloads to run (default: both; partial "
                    "modes are probes and must write somewhere other than "
                    "the checked-in baseline)")
    ap.add_argument("--out", default=ROOT_OUT)
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="validate an existing BENCH_serve.json and exit")
    args = ap.parse_args()
    if args.validate:
        validate_file(args.validate)
        return
    if args.mode != "both" and (os.path.abspath(args.out)
                                == os.path.abspath(ROOT_OUT)):
        ap.error(f"--mode {args.mode} would overwrite the checked-in "
                 f"baseline with a partial report; pass an explicit --out")
    report = run(smoke=args.smoke, out_path=args.out, mode=args.mode)
    if args.mode != "continuous":      # continuous-only lacks static rows
        validate(report)


if __name__ == "__main__":
    main()
