"""Property tests for the host-side adapter-slot manager invariants.

`serve/adapters.py::AdapterPool` mirrors `BlockPool` for the device factor
pools: a refcount bug here routes one tenant's requests through another
tenant's factors. Random op sequences run against a shadow model and pin:

* refcounts never go negative; slot 0 (the pinned base adapter) is never
  allocated, never evicted, never refcounted;
* every adapter-holding slot is in exactly one of three states (live /
  cached / free) and the id<->slot maps stay a bijection;
* LRU eviction never reclaims a live (referenced) adapter;
* ``acquire`` when every slot is pinned fails cleanly (returns None,
  state unchanged); re-acquire of a resident adapter is a hit (no load).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.serve.adapters import BASE_SLOT, AdapterPool


def _invariants(pool: AdapterPool):
    """The global consistency every op sequence must preserve."""
    assert (pool.ref >= 0).all(), "negative refcount"
    assert pool.ref[BASE_SLOT] == 0, "base slot acquired a refcount"
    assert BASE_SLOT not in pool._id_of, "base slot holds an adapter"
    free = set(pool._free)
    live = {int(s) for s in np.flatnonzero(pool.ref > 0)}
    cached = {s for s in pool._id_of if pool.ref[s] == 0}
    assert not (free & live), "free list holds a live slot"
    assert not (free & set(pool._id_of)), "free list holds a resident slot"
    assert free | live | cached == set(range(1, pool.num_slots))
    assert pool.live() == len(live)
    assert pool.cached() == len(cached)
    assert pool.available() == len(free) + len(cached)
    assert pool.resident() == len(pool._id_of)
    # id<->slot maps are a bijection
    assert len(pool._by_id) == len(pool._id_of)
    for aid, slot in pool._by_id.items():
        assert pool._id_of[slot] == aid


def _random_ops(pool: AdapterPool, rng: np.random.Generator, n_ops: int):
    """Random acquire/release traffic over more ids than slots."""
    ids = [f"t{i}" for i in range(pool.capacity * 2)]
    held = []                  # adapter ids we still owe releases for
    resident = {}              # shadow residency: id -> slot
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        if op in (0, 1):       # acquire (biased: traffic dominates)
            aid = ids[int(rng.integers(0, len(ids)))]
            was_resident = aid in resident
            got = pool.acquire(aid)
            if got is None:    # every slot pinned — state unchanged
                assert pool.available() == 0
            else:
                slot, needs_load = got
                assert slot != BASE_SLOT
                assert needs_load == (not was_resident), \
                    "hit/miss disagrees with shadow residency"
                if was_resident:
                    assert slot == resident[aid], "resident adapter moved"
                else:
                    # a miss claimed a free or evicted slot — drop the
                    # shadow entry of whoever held it before
                    for other, s in list(resident.items()):
                        if s == slot:
                            del resident[other]
                resident[aid] = slot
                held.append(aid)
        elif op == 2 and held:  # release one we hold
            aid = held.pop(int(rng.integers(0, len(held))))
            pool.release(aid)
        _invariants(pool)
    return held


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_pool_invariants_under_random_traffic(seed):
    rng = np.random.default_rng(seed)
    pool = AdapterPool(int(rng.integers(2, 10)))
    held = _random_ops(pool, rng, 60)
    # drain: every held reference releases exactly once; residents stay
    # cached (warm for returning tenants), nothing is live
    for aid in held:
        pool.release(aid)
    _invariants(pool)
    assert pool.live() == 0
    assert pool.available() == pool.capacity


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_eviction_never_reclaims_live_adapters(seed):
    rng = np.random.default_rng(seed)
    pool = AdapterPool(6)                  # 5 adapter slots + base
    n_live = int(rng.integers(1, 4))
    live = [f"live{i}" for i in range(n_live)]
    live_slots = {aid: pool.acquire(aid)[0] for aid in live}
    cached = [f"cached{i}" for i in range(pool.capacity - n_live)]
    for aid in cached:
        pool.acquire(aid)
        pool.release(aid)                  # resident, evictable
    _invariants(pool)
    # exhaust the pool with fresh tenants: every miss must evict from the
    # cached set only, and live adapters keep their slots
    for i in range(len(cached)):
        slot, needs_load = pool.acquire(f"fresh{i}")
        assert needs_load and slot not in live_slots.values()
    assert pool.acquire("one-too-many") is None
    for aid in live:
        slot, needs_load = pool.acquire(aid)   # still resident: hit
        assert not needs_load and slot == live_slots[aid]
    _invariants(pool)
    assert pool.evictions == len(cached)


def test_acquire_when_all_pinned_fails_cleanly():
    pool = AdapterPool(3)
    a = pool.acquire("a")
    b = pool.acquire("b")
    assert a[1] and b[1]
    before = (pool.ref.copy(), list(pool._free), dict(pool._by_id),
              pool.hits, pool.misses, pool.evictions)
    assert pool.acquire("c") is None       # all pinned: clean failure
    after = (pool.ref, list(pool._free), dict(pool._by_id),
             pool.hits, pool.misses, pool.evictions)
    assert (before[0] == after[0]).all() and before[1:] == after[1:], \
        "failed acquire mutated pool state"
    pool.release("a")
    slot, needs_load = pool.acquire("c")   # evicts a, recovers fully
    assert needs_load and slot == a[0]
    assert pool.evictions == 1


def test_lru_evicts_least_recently_acquired():
    pool = AdapterPool(3)
    pool.acquire("a")
    pool.acquire("b")
    pool.release("a")
    pool.release("b")
    sa, hit = pool.acquire("a")            # touch a — b is now LRU
    assert not hit
    pool.release("a")
    slot, needs_load = pool.acquire("c")
    assert needs_load
    assert pool.slot_of("b") is None, "evicted the recently-touched adapter"
    assert pool.slot_of("a") == sa


def test_release_guards_and_base_slot_pinned():
    pool = AdapterPool(2)
    with pytest.raises(KeyError, match="non-resident"):
        pool.release("ghost")
    pool.acquire("a")
    pool.release("a")
    with pytest.raises(ValueError, match="double release"):
        pool.release("a")
    with pytest.raises(ValueError, match=">= 2 slots"):
        AdapterPool(1)
    _invariants(pool)


def test_stats_track_hits_misses_occupancy():
    pool = AdapterPool(4)
    pool.acquire("a")
    pool.acquire("a")
    pool.acquire("b")
    pool.release("a")
    s = pool.stats()
    assert s == {"capacity": 3, "resident": 2, "live": 2,
                 "occupancy": 2 / 3, "hits": 1, "misses": 2, "evictions": 0}
