"""α-threshold adaptive rank: per-layer and per-expert rank selection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import forward, init_params
from repro.quant import PTQConfig, calibrate, quantize_model
import pytest


def _selected_ranks(qp):
    ranks = []

    def walk(node):
        if isinstance(node, dict):
            if "la" in node:
                la = np.asarray(node["la"], np.float32)
                nz = (np.abs(la).sum(axis=-1) > 0).sum(axis=-1)
                ranks.extend(np.atleast_1d(nz).reshape(-1).tolist())
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
    walk(qp)
    return ranks


def test_alpha_rank_varies_and_monotone():
    cfg = dataclasses.replace(get_smoke_config("llama3_8b"), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 32))
    mean_ranks = []
    for alpha in (0.2, 0.5, 0.8):
        qp = quantize_model(params, tape,
                            PTQConfig(method="aser_as", rank=64, alpha=alpha,
                                      outlier_f=8))
        mean_ranks.append(float(np.mean(_selected_ranks(qp))))
    assert mean_ranks[0] <= mean_ranks[1] <= mean_ranks[2], mean_ranks
    assert mean_ranks[2] > mean_ranks[0]   # genuinely adaptive


@pytest.mark.slow
def test_per_expert_ranks_differ():
    """Per-expert calibration ⇒ per-expert α-ranks (beyond-paper: experts
    with few routed tokens get smaller compensation)."""
    cfg = dataclasses.replace(get_smoke_config("moonshot_v1_16b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 64))
    qp = quantize_model(params, tape,
                        PTQConfig(method="aser_as", rank=32, alpha=0.5,
                                  outlier_f=8))
    # gate experts leaf la: [G, e, r, n]
    la = None

    def find(node):
        nonlocal la
        if isinstance(node, dict):
            if "experts" in node and isinstance(node["experts"], dict) \
                    and "gate" in node["experts"] \
                    and isinstance(node["experts"]["gate"], dict):
                la = np.asarray(node["experts"]["gate"]["la"], np.float32)
            for v in node.values():
                find(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                find(v)
    find(qp)
    assert la is not None and la.ndim == 4
    per_expert = (np.abs(la).sum(axis=-1) > 0).sum(axis=-1)   # [G, e]
    assert per_expert.min() >= 1
    # at least some variation across experts (different routed token sets)
    assert per_expert.max() > per_expert.min()
