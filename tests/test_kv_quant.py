"""Quantized (int8 / int4) KV cache: parity, tolerance, kernel, recipe.

The acceptance pins for the KV-quant tentpole:

* **per-dtype parity** — for ANY ragged prompt mix, the paged engine
  generates token-for-token what the contiguous engine generates *at the
  same ``kv_dtype``*, on both decode loops (quantization error is
  identical in both layouts, so it cancels exactly);
* **tolerance vs native** — int8-KV prefill logits stay within
  ``KV_INT8_REL_TOL`` (max-abs relative) of the native-dtype cache, and
  short greedy generations agree on ≥ ``KV_INT8_TOKEN_AGREEMENT`` of
  tokens (greedy argmax can flip near-ties; the *documented* tolerance
  policy lives in docs/serving_perf.md and mirrors these constants);
* the Pallas paged-gather kernel's fused dequant epilogue matches the XLA
  gather path;
* ``KVQuantSpec`` rides the recipe API (JSON round-trip, registry
  overrides, v1-blob back-compat);
* scale pools shard and page-budget math holds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.models import (dequantize_kv, forward, init_caches, init_params,
                          kv_qmax, quantize_kv)
from repro.quant import KVQuantSpec, QuantRecipe, registry
from repro.runtime import KV_CACHE_DTYPES, RuntimeConfig
from repro.serve.engine import (Engine, ServeConfig, blocks_for_hbm_budget,
                                kv_page_bytes)
from repro.serve.scheduler import Scheduler

# documented tolerance policy (docs/serving_perf.md#quantized-kv-cache):
# measured worst-case int8 rel. logit error on the smoke model is ~1.6%
KV_INT8_REL_TOL = 0.05
KV_INT8_TOKEN_AGREEMENT = 0.9

MAX_PROMPT = 8
BATCH = 3


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ragged_batch(cfg, seed: int):
    key = jax.random.PRNGKey(seed)
    lens = np.asarray(jax.random.randint(key, (BATCH,), 1, MAX_PROMPT + 1))
    padded = np.zeros((BATCH, MAX_PROMPT), np.int32)
    for i, L in enumerate(lens):
        padded[i, :int(L)] = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (int(L),), 0, cfg.vocab_size))
    return lens.astype(np.int32), padded


def _engine(tiny, *, kv="int8", layout="paged", loop="scan", rt=None,
            **kw):
    cfg, params = tiny
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 8)
    return Engine(params, cfg,
                  ServeConfig(decode_loop=loop, kv_layout=layout,
                              kv_dtype=kv, **kw), rt=rt)


# ---------------------------------------------------------------------------
# quantize/dequantize primitives
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 5, 3, 16)).astype(np.float32) * 7)
    for dtype in ("int8", "int4"):
        qm = kv_qmax(dtype)
        codes, scale = quantize_kv(x, qm)
        assert codes.dtype == jnp.int8
        assert float(jnp.max(jnp.abs(codes))) <= qm
        back = dequantize_kv(codes, scale)
        # symmetric abs-max: error ≤ scale/2 per element, per (token, head)
        bound = np.asarray(scale)[..., None] / 2 + 1e-7
        assert np.all(np.abs(np.asarray(back - x)) <= bound), dtype


def test_quantize_zero_rows_are_exact():
    codes, scale = quantize_kv(jnp.zeros((1, 2, 2, 8)), 127.0)
    assert not np.any(np.asarray(codes)) and not np.any(np.asarray(scale))
    assert not np.any(np.asarray(dequantize_kv(codes, scale)))


# ---------------------------------------------------------------------------
# Property: paged ≡ contiguous pinned PER DTYPE (the acceptance pin)
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_paged_matches_contiguous_per_kv_dtype(tiny, seed):
    cfg, _ = tiny
    lens, padded = _ragged_batch(cfg, seed)
    for kv in ("int8", "int4"):
        for loop in ("scan", "step"):
            cont = np.asarray(_engine(tiny, kv=kv, layout="contiguous",
                                      loop=loop).generate(
                jnp.asarray(padded), 6, prompt_lens=lens))
            paged = np.asarray(_engine(tiny, kv=kv, layout="paged",
                                       loop=loop).generate(
                jnp.asarray(padded), 6, prompt_lens=lens))
            assert np.array_equal(cont, paged), (kv, loop, seed, lens)


def test_int8_decode_within_documented_tolerance(tiny):
    """int8-KV vs native-KV: prefill logits within KV_INT8_REL_TOL and
    greedy generations ≥ KV_INT8_TOKEN_AGREEMENT token agreement."""
    cfg, params = tiny
    agree, total = 0, 0
    for seed in range(4):
        toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 10), 0,
                                  cfg.vocab_size)
        ref, _, _ = forward(params, cfg, toks,
                            caches=init_caches(cfg, 2, 16))
        q8, _, _ = forward(params, cfg, toks,
                           caches=init_caches(cfg, 2, 16, kv_dtype="int8"))
        rel = float(jnp.max(jnp.abs(q8 - ref))) / float(jnp.max(jnp.abs(ref)))
        assert rel < KV_INT8_REL_TOL, (seed, rel)

        lens, padded = _ragged_batch(cfg, seed)
        a = np.asarray(_engine(tiny, kv="bf16").generate(
            jnp.asarray(padded), 8, prompt_lens=lens))
        b = np.asarray(_engine(tiny, kv="int8").generate(
            jnp.asarray(padded), 8, prompt_lens=lens))
        agree += int((a == b).sum())
        total += a.size
    assert agree / total >= KV_INT8_TOKEN_AGREEMENT, agree / total


def test_int8_uniform_and_eos_paths(tiny):
    """The non-ragged contiguous write path and the eos masked
    continuation quantize identically in both layouts."""
    cfg, params = tiny
    prompts = jax.random.randint(jax.random.PRNGKey(11), (BATCH, 5), 0,
                                 cfg.vocab_size)
    a = np.asarray(_engine(tiny, kv="int8", layout="contiguous")
                   .generate(prompts, 6))
    b = np.asarray(_engine(tiny, kv="int8", layout="paged")
                   .generate(prompts, 6))
    assert np.array_equal(a, b)
    eos = int(a[0, 2])
    c = np.asarray(_engine(tiny, kv="int8", layout="contiguous",
                           eos_id=eos).generate(prompts, 6))
    d = np.asarray(_engine(tiny, kv="int8", layout="paged",
                           eos_id=eos).generate(prompts, 6))
    assert np.array_equal(c, d)


# ---------------------------------------------------------------------------
# Engine / ServeConfig surface
# ---------------------------------------------------------------------------

def test_serve_config_kv_dtype_validation():
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeConfig(kv_dtype="fp8")
    assert ServeConfig(kv_dtype="int8").kv_bits == 8
    assert ServeConfig(kv_dtype="int4").kv_bits == 4
    assert ServeConfig().kv_bits == 16


def test_quantized_kv_gates_unsupported_configs(tiny):
    ssm_cfg = get_smoke_config("mamba2_780m").reduced(d_model=32, n_layers=2)
    ssm_params = init_params(jax.random.PRNGKey(0), ssm_cfg)
    eng = Engine(ssm_params, ssm_cfg, ServeConfig(max_len=16,
                                                  kv_dtype="int8"))
    with pytest.raises(NotImplementedError, match="family 'ssm'"):
        eng.generate(jnp.zeros((1, 4), jnp.int32), 2)
    win_cfg = get_smoke_config("gemma2_9b")
    with pytest.raises(NotImplementedError, match="sliding-window"):
        init_caches(win_cfg, 1, 16, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        init_caches(_tiny_cfg(), 1, 16, kv_dtype="fp8")


def test_scheduler_on_int8_paged_engine_matches_per_request(tiny):
    """Continuous batching (admission, lazy page growth, retirement,
    prefix reuse, COW) over an int8 pool reproduces the int8 engine's
    dedicated runs token-for-token — the scales travel with their pages."""
    cfg, _ = tiny
    eng = _engine(tiny, kv="int8", max_len=64, batch_slots=2)
    sched = Scheduler(eng, chunk_size=3)
    key = jax.random.PRNGKey(2)
    reqs = []
    shared = np.asarray(jax.random.randint(key, (16,), 0, cfg.vocab_size))
    for i, (L, n) in enumerate([(5, 8), (2, 4), (7, 11), (3, 6)]):
        p = np.asarray(jax.random.randint(jax.random.fold_in(key, i), (L,),
                                          0, cfg.vocab_size))
        reqs.append((p, n, sched.submit(p, n)))
    # two prefix-sharing requests exercise match/COW on quantized pages
    for tail, n in ([7, 3], 5), ([1], 4):
        p = np.concatenate([shared, np.asarray(tail, np.int32)])
        reqs.append((p, n, sched.submit(p, n)))
    sched.run()
    for prompt, n, handle in reqs:
        ref = np.asarray(eng.generate(jnp.asarray(prompt[None]), n))[0]
        assert np.array_equal(np.asarray(handle.tokens), ref), \
            (len(prompt), n)
    assert sched.pool.live() == 0
    assert sched.prefix_hits >= 1


def test_copy_blocks_carries_scales(tiny):
    """Device-side COW must copy the scale tiles with the codes — a page
    copied without its scales dequantizes garbage."""
    cfg, _ = tiny
    eng = _engine(tiny, kv="int8")
    caches = eng.new_caches()

    def bump(leaf):
        if not hasattr(leaf, "k_scale") or leaf.k_scale is None:
            return leaf
        # block 1 gets distinctive codes and scales everywhere
        return leaf._replace(
            k=leaf.k.at[..., 1, :, :, :].set(5),
            k_scale=leaf.k_scale.at[..., 1, :, :].set(2.5))

    caches = jax.tree.map(bump, caches,
                          is_leaf=lambda x: hasattr(x, "k_scale"))
    caches = eng.copy_blocks(caches, src=[1], dst=[3])
    leaf = jax.tree.leaves(
        caches, is_leaf=lambda x: hasattr(x, "k_scale"))[0]
    assert np.all(np.asarray(leaf.k)[..., 3, :, :, :] == 5)
    assert np.all(np.asarray(leaf.k_scale)[..., 3, :, :] == 2.5)
    assert np.all(np.asarray(leaf.k_scale)[..., 0, :, :] == 0)


# ---------------------------------------------------------------------------
# Pallas kernel: fused dequant epilogue
# ---------------------------------------------------------------------------

def test_paged_kernel_dequant_epilogue_matches_reference():
    from repro.kernels.paged_attention import paged_decode_attention
    rng = np.random.default_rng(0)
    b, hq, hkv, hd, bs, n_total, nbr = 3, 4, 2, 32, 8, 12, 3
    q = jnp.asarray(rng.normal(size=(b, 1, hq, hd)).astype(np.float32))
    kc = jnp.asarray(rng.integers(-127, 128, size=(n_total, bs, hkv, hd))
                     .astype(np.int8))
    vc = jnp.asarray(rng.integers(-127, 128, size=(n_total, bs, hkv, hd))
                     .astype(np.int8))
    ks = jnp.asarray(rng.uniform(0.01, 0.1, size=(n_total, bs, hkv))
                     .astype(np.float32))
    vs = jnp.asarray(rng.uniform(0.01, 0.1, size=(n_total, bs, hkv))
                     .astype(np.float32))
    bt = jnp.asarray(np.array([[0, 3, 7], [2, 5, n_total],
                               [9, n_total, n_total]], np.int32))
    klen = jnp.asarray(np.array([20, 11, 4], np.int32))
    out = np.asarray(paged_decode_attention(q, kc, vc, bt, klen, ks, vs,
                                            interpret=True))

    kf = np.asarray(dequantize_kv(kc, ks)).reshape(n_total * bs, hkv, hd)
    vf = np.asarray(dequantize_kv(vc, vs)).reshape(n_total * bs, hkv, hd)
    group = hq // hkv
    for i in range(b):
        idx = (np.clip(np.asarray(bt)[i], 0, n_total - 1)[:, None] * bs
               + np.arange(bs)).reshape(-1)
        for h in range(hq):
            kh, vh = kf[idx][:, h // group], vf[idx][:, h // group]
            s = (np.asarray(q)[i, 0, h] @ kh.T) * hd ** -0.5
            s[np.arange(len(s)) >= int(klen[i])] = -1e30
            p = np.exp(s - s.max())
            p /= p.sum()
            np.testing.assert_allclose(out[i, 0, h], p @ vh,
                                       rtol=1e-5, atol=1e-5)


def test_int8_paged_engine_with_pallas_kernel(tiny):
    """Full int8 paged generation through the kernel's dequant epilogue
    tracks the XLA gather path (greedy near-ties may flip)."""
    cfg, _ = tiny
    lens, padded = _ragged_batch(cfg, seed=3)
    xla = np.asarray(_engine(tiny, kv="int8",
                             rt=RuntimeConfig(use_pallas=False)).generate(
        jnp.asarray(padded), 5, prompt_lens=lens))
    pls = np.asarray(_engine(tiny, kv="int8",
                             rt=RuntimeConfig(use_pallas=True,
                                              interpret=True)).generate(
        jnp.asarray(padded), 5, prompt_lens=lens))
    assert (xla == pls).mean() > 0.8


def test_tuning_accounts_for_dequant_epilogue():
    from repro.kernels import tuning
    base = tuning.paged_vmem_bytes(16, 8, 128)
    quant = tuning.paged_vmem_bytes(16, 8, 128, quantized=True)
    assert quant == base + 2 * 16 * 128 + 2 * 16 * 4
    assert tuning.use_paged_kernel(8, 32, 16, 8, 128, quantized=True)
    assert not tuning.use_paged_kernel(8, 4, 65536, 8, 4096, quantized=True)


# ---------------------------------------------------------------------------
# Recipe API: KVQuantSpec stage
# ---------------------------------------------------------------------------

def test_kv_quant_spec_validation_and_bits():
    assert KVQuantSpec().is_noop and KVQuantSpec().bits == 16
    assert KVQuantSpec("int8").bits == 8
    assert KVQuantSpec("int4").bits == 4
    with pytest.raises(ValueError, match="kv cache dtype"):
        KVQuantSpec("fp8")
    scfg = KVQuantSpec("int8").serve_config(max_len=64, kv_layout="paged")
    assert scfg.kv_dtype == "int8" and scfg.kv_layout == "paged"


def test_recipe_kv_roundtrip_and_backcompat():
    r = registry.resolve("aser_as", kv_dtype="int8")
    assert r.kv == KVQuantSpec("int8")
    blob = r.to_json()
    assert QuantRecipe.from_json(blob) == r
    d = r.to_dict()
    assert d["format_version"] == 3 and d["kv"] == {"dtype": "int8"}
    # v1 blobs (pre-KV-quant) deserialize with the bf16 default
    legacy = {k: v for k, v in d.items() if k not in ("kv", "adapter")}
    legacy["format_version"] = 1
    assert QuantRecipe.from_dict(legacy).kv == KVQuantSpec()
    with pytest.raises(ValueError, match="format version"):
        QuantRecipe.from_dict({**d, "format_version": 4})


def test_registry_kv_dtype_override_everywhere():
    for name in registry.available():
        r = registry.resolve(f"{name}(kv_dtype=int8)")
        assert r.kv == KVQuantSpec("int8"), name
        assert registry.resolve(name).kv == KVQuantSpec(), name
    with pytest.raises(ValueError, match="kv cache dtype"):
        registry.resolve("aser", kv_dtype="fp8")


# ---------------------------------------------------------------------------
# Sharding: scale lanes / pools
# ---------------------------------------------------------------------------

def test_scale_pool_and_lane_specs():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import cache_spec, paged_pool_spec
    sizes = {"data": 2, "model": 2}
    # paged scale pools [num_blocks, block_size, n_kv]: model → kv heads
    assert paged_pool_spec("/g/0/k_scale", (64, 16, 4), sizes) == \
        P(None, None, "model")
    # no head_dim fallback: odd heads stay replicated
    assert paged_pool_spec("/g/0/v_scale", (64, 16, 1), sizes) == \
        P(None, None, None)
    assert paged_pool_spec("/g/0/k_scale", (64, 16, 4), sizes,
                           seq_to_data=True) == P("data", None, "model")
    # contiguous scale lanes [b, cache_len, n_kv]
    assert cache_spec("/g/0/k_scale", (4, 32, 4), sizes) == \
        P(("data",), None, "model")
    assert cache_spec("/g/0/v_scale", (4, 32, 4), sizes,
                      seq_to_data=True) == P(None, "data", "model")
    assert cache_spec("/g/0/qmax", (), sizes) == P()


def test_cache_shardings_handle_quantized_trees(tiny):
    from repro.models import init_paged_caches
    from repro.sharding.rules import cache_shardings
    cfg, _ = tiny
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1),
                             ("model",))
    for caches in (init_paged_caches(cfg, 16, 8, kv_dtype="int8"),
                   init_caches(cfg, 2, 16, kv_dtype="int8"),
                   init_caches(cfg, 2, 16)):
        sds = cache_shardings(caches, mesh)
        # structure must match exactly (None leaves line up), so device_put
        # of the cache tree against its shardings is well-formed
        assert (jax.tree.structure(sds) == jax.tree.structure(
            jax.tree.map(lambda _: object(), caches)))


# ---------------------------------------------------------------------------
# Memory accounting: more pages at the same HBM budget
# ---------------------------------------------------------------------------

def test_kv_page_bytes_math(tiny):
    cfg, _ = tiny                      # float32 native, 2 kv heads, hd 32
    bs = 8
    native = kv_page_bytes(cfg, bs, "bf16")
    int8 = kv_page_bytes(cfg, bs, "int8")
    assert native == 2 * bs * 2 * 32 * 4 * cfg.n_layers
    assert int8 == (2 * bs * 2 * 32 + 2 * bs * 2 * 4) * cfg.n_layers
    assert kv_page_bytes(cfg, bs, "int4") == int8   # unpacked: honest
    with pytest.raises(ValueError, match="kv_dtype"):
        kv_page_bytes(cfg, bs, "fp8")
    budget = 64 * native
    assert blocks_for_hbm_budget(cfg, bs, "bf16", budget) == 64
    assert blocks_for_hbm_budget(cfg, bs, "int8", budget) == \
        budget // int8 > 64
    # a budget below one page must raise, not return 0 (which ServeConfig
    # would read as "use the default pool size" and blow the budget)
    with pytest.raises(ValueError, match="smaller than one"):
        blocks_for_hbm_budget(cfg, bs, "int8", int8 - 1)
    bf16_cfg = dataclasses.replace(cfg, dtype="bfloat16")
    assert kv_page_bytes(bf16_cfg, bs, "bf16") == native // 2


def test_kv_dtypes_vocabulary_is_single_sourced():
    from repro.models.attention import _KV_QMAX
    assert set(_KV_QMAX) == set(KV_CACHE_DTYPES) - {"bf16"}
    assert kv_qmax("int8") == 127.0 and kv_qmax("int4") == 7.0
