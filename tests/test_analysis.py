"""repro-analyze unit tests: each rule RA001–RA005 on paired good/bad
snippets at exact lines, noqa suppression, JSON output, the kernel
contract checker both clean and poisoned, and the whole real tree clean.
"""
import json
import os
import textwrap

import pytest

from repro.analysis.contracts import (check_flash_candidates,
                                      check_gemm_candidates,
                                      check_kernel_contracts,
                                      check_paged_candidates)
from repro.analysis.findings import Finding, findings_to_json
from repro.analysis.lint import is_hot_path, lint_source, lint_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")
KERNELS = os.path.join(SRC_REPRO, "kernels")


def _lint(snippet, hot=True):
    return lint_source(textwrap.dedent(snippet), "repro/serve/x.py"
                       if hot else "repro/launch/x.py", hot=hot)


def _hits(findings, rule):
    return [(f.line, f.message) for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# RA001 — host syncs on the hot path
# ---------------------------------------------------------------------------

BAD_RA001 = """\
import jax
import jax.numpy as jnp
import numpy as np

def decode_step(tok):
    x = jnp.argmax(tok)
    v = float(x)
    w = np.asarray(x)
    y = x.item()
    jax.device_get(x)
    x.block_until_ready()
    return v, w, y
"""

GOOD_RA001 = """\
import numpy as np

def admit(lengths):
    arr = np.asarray(lengths)       # host value: no sync
    return int(arr.max()), float(arr.mean())
"""


def test_ra001_flags_each_sync_at_exact_line():
    lines = sorted(line for line, _ in _hits(_lint(BAD_RA001), "RA001"))
    assert lines == [7, 8, 9, 10, 11]


def test_ra001_silent_on_host_values():
    assert _hits(_lint(GOOD_RA001), "RA001") == []


def test_ra001_scoped_to_hot_path_dirs():
    # the same device syncs are legitimate in host-side orchestration
    assert _hits(_lint(BAD_RA001, hot=False), "RA001") == []
    assert is_hot_path("repro/serve/engine.py")
    assert is_hot_path("repro/kernels/w4a8_gemm.py")
    assert not is_hot_path("repro/launch/dryrun.py")


def test_ra001_host_escape_clears_taint():
    # np.asarray is itself the (flagged) escape; downstream reads of its
    # result are host-side and must not cascade into more findings
    findings = _lint("""\
        import jax
        import jax.numpy as jnp
        import numpy as np

        def readback(toks):
            host = jax.device_get(toks)
            return int(host[0])
        """)
    assert _hits(findings, "RA001") == [
        (6, "`jax.device_get` is a device→host sync")]


# ---------------------------------------------------------------------------
# RA002 — side effects under trace
# ---------------------------------------------------------------------------

BAD_RA002 = """\
import jax

_calls = 0

@jax.jit
def decode(x):
    global _calls
    print("tracing", x)
    jax.debug.print("x={}", x)
    return x
"""

GOOD_RA002 = """\
import jax

@jax.jit
def decode(x):
    return x * 2

def host_log(x):
    print("result", x)     # not traced: fine
"""


def test_ra002_flags_traced_side_effects():
    lines = sorted(line for line, _ in _hits(_lint(BAD_RA002), "RA002"))
    assert lines == [7, 8, 9]      # global, print, jax.debug.print


def test_ra002_silent_outside_trace():
    assert _hits(_lint(GOOD_RA002), "RA002") == []


def test_ra002_sees_pallas_kernels():
    findings = _lint("""\
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            print("inside kernel")
            o_ref[...] = x_ref[...]

        def call(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
        """)
    assert [line for line, _ in _hits(findings, "RA002")] == [4]


# ---------------------------------------------------------------------------
# RA003 — donated buffer read after donation
# ---------------------------------------------------------------------------

BAD_RA003 = """\
import jax

def _impl(params, caches):
    return caches

step = jax.jit(_impl, donate_argnums=(1,))

def drive(params, caches):
    out = step(params, caches)
    stale = caches[0]
    return out, stale
"""

GOOD_RA003 = """\
import jax

def _impl(params, caches):
    return caches

step = jax.jit(_impl, donate_argnums=(1,))

def drive(params, caches):
    caches = step(params, caches)   # rebind: the sound pattern
    return caches[0]
"""


def test_ra003_flags_read_after_donate():
    hits = _hits(_lint(BAD_RA003), "RA003")
    assert [line for line, _ in hits] == [10]
    assert "donated" in hits[0][1]


def test_ra003_rebind_is_clean():
    assert _hits(_lint(GOOD_RA003), "RA003") == []


def test_ra003_terminating_branch_does_not_leak():
    findings = _lint("""\
        import jax

        def _impl(params, caches):
            return caches

        step = jax.jit(_impl, donate_argnums=(1,))

        def drive(params, caches, fast):
            if fast:
                return step(params, caches)
            return step(params, caches)
        """)
    assert _hits(findings, "RA003") == []


# ---------------------------------------------------------------------------
# RA004 — unhashable / f-string static args
# ---------------------------------------------------------------------------

BAD_RA004 = """\
import jax

def _impl(x, mode):
    return x

run = jax.jit(_impl, static_argnames=("mode",))

def drive(x, n):
    a = run(x, mode=f"steps-{n}")
    b = run(x, mode=[n])
    return a, b
"""

GOOD_RA004 = """\
import jax

def _impl(x, mode):
    return x

run = jax.jit(_impl, static_argnames=("mode",))

def drive(x, n):
    return run(x, mode=int(n))
"""


def test_ra004_flags_fstring_and_unhashable_static():
    hits = _hits(_lint(BAD_RA004), "RA004")
    assert [line for line, _ in hits] == [9, 10]
    assert "f-string" in hits[0][1]
    assert "unhashable" in hits[1][1]


def test_ra004_hashable_static_is_clean():
    assert _hits(_lint(GOOD_RA004), "RA004") == []


# ---------------------------------------------------------------------------
# RA005 — set iteration feeding pytrees
# ---------------------------------------------------------------------------

BAD_RA005 = """\
def collect(names):
    kinds = {n.split("/")[0] for n in names}
    out = [kind for kind in kinds]
    for kind in kinds:
        out.append(kind)
    return out
"""

GOOD_RA005 = """\
def collect(names):
    kinds = {n.split("/")[0] for n in names}
    return [kind for kind in sorted(kinds)]
"""


def test_ra005_flags_set_iteration():
    lines = sorted(line for line, _ in _hits(_lint(BAD_RA005, hot=False),
                                             "RA005"))
    assert lines == [3, 4]         # comprehension + for loop


def test_ra005_sorted_set_is_clean():
    assert _hits(_lint(GOOD_RA005, hot=False), "RA005") == []


# ---------------------------------------------------------------------------
# Suppression, syntax errors, JSON
# ---------------------------------------------------------------------------

def test_noqa_suppresses_named_rule_only():
    src = """\
import jax

def decode_step(x):
    y = jax.device_get(x)  # repro: noqa[RA001] designed sync point
    z = jax.device_get(x)
    return y, z
"""
    findings = lint_source(src, "repro/serve/x.py", hot=True)
    assert [(f.rule, f.line) for f in findings] == [("RA001", 5)]


def test_noqa_wrong_rule_does_not_suppress():
    src = """\
import jax

def decode_step(x):
    return jax.device_get(x)  # repro: noqa[RA005]
"""
    findings = lint_source(src, "repro/serve/x.py", hot=True)
    assert [f.rule for f in findings] == ["RA001"]


def test_syntax_error_reports_ra000():
    findings = lint_source("def broken(:\n", "repro/serve/x.py")
    assert [f.rule for f in findings] == ["RA000"]


def test_findings_json_roundtrip():
    findings = _lint(BAD_RA003)
    doc = json.loads(findings_to_json(findings, root="src/repro"))
    assert doc["root"] == "src/repro"
    assert doc["count"] == len(findings) == len(doc["findings"])
    entry = doc["findings"][0]
    assert entry["rule"] == "RA003"
    assert entry["path"].endswith("x.py")
    assert isinstance(entry["line"], int)


def test_finding_format_is_clickable():
    f = Finding(rule="RA001", path="repro/serve/engine.py", line=7, col=5,
                message="sync")
    assert f.format() == "repro/serve/engine.py:7:5: RA001 sync"


# ---------------------------------------------------------------------------
# The real tree is clean (every true positive fixed or justified)
# ---------------------------------------------------------------------------

def test_src_repro_tree_is_clean():
    assert [f.format() for f in lint_tree(SRC_REPRO)] == []


# ---------------------------------------------------------------------------
# Kernel contracts — static, zero device launches
# ---------------------------------------------------------------------------

def test_contract_checker_clean_on_real_kernels():
    assert [f.format() for f in check_kernel_contracts(KERNELS)] == []


def test_contract_checker_rejects_tiny_budget():
    findings = check_kernel_contracts(KERNELS, budget=1024)
    rules = {f.rule for f in findings}
    assert "KC001" in rules        # VMEM overflows everywhere
    assert len(findings) > 50      # the whole candidate lattice trips


def test_gemm_candidates_checked_without_device(monkeypatch):
    # the checker must stay static: fail the test if anything tries to
    # launch a computation while the contract pass runs
    import jax
    def boom(*a, **k):
        raise AssertionError("contract checker launched a device op")
    monkeypatch.setattr(jax, "jit", boom)
    monkeypatch.setattr(jax, "device_put", boom)
    assert check_gemm_candidates() == []
    assert check_paged_candidates() == []
    assert check_flash_candidates() == []


def test_contract_findings_name_the_candidate():
    findings = check_gemm_candidates(budget=1024)
    assert findings, "1KiB budget must overflow some gemm candidate"
    assert all("budget" in f.message for f in findings
               if f.rule == "KC001")
    assert any("GEMM_BLOCK_TABLE" in f.message for f in findings)
    assert any("select_gemm_blocks" in f.message for f in findings)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_strict_clean_tree(tmp_path, capsys):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "repro_analyze", os.path.join(REPO, "tools", "repro_analyze.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    out_json = tmp_path / "report.json"
    rc = mod.main(["--strict", "--json", str(out_json)])
    captured = capsys.readouterr().out
    assert rc == 0
    assert "0 findings — clean" in captured
    doc = json.loads(out_json.read_text())
    assert doc["count"] == 0

    bad = tmp_path / "tree" / "serve"
    bad.mkdir(parents=True)
    (bad / "x.py").write_text(textwrap.dedent(BAD_RA001))
    rc = mod.main(["--strict", str(tmp_path / "tree")])
    captured = capsys.readouterr().out
    assert rc == 1
    assert "RA001" in captured
