"""Chunked prefill ≡ one-shot prefill: the token-exactness property.

The chunked-prefill tentpole splits a prompt's prefill into fixed-token
chunks interleaved with decode so short requests stop queueing behind
long prompts. The *only* acceptable observable difference is latency:
for every request the generated stream must equal the one-shot run token
for token — chunking changes when KV is written, never what is written.

The matrix here pins that across every axis that shares the write path:

* chunk size 1 (every boundary a scheduling point), a prime that never
  divides the prompt length (ragged final chunks), and one at least as
  large as any prompt (degenerate single-chunk = one-shot shape);
* contiguous and paged KV layouts (two different scatter disciplines);
* both decode loops (``scan`` device-resident and ``step`` debug);
* fp stack and the full quantized stack (w4a8 ASER base + int8 KV +
  LoRA adapter routing), where KV writes go through scale tensors;
* prefix-reuse hits landing mid-chunk: a cached-prefix admission starts
  its chunked prefill at ``start = shared_tok`` inside a chunk;
* a finite ``step_token_budget``, which changes chunk interleaving
  order but must not change tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.serve.lifecycle import RequestStatus, assert_drained
from repro.serve.scheduler import Scheduler


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


# mixed lengths: one-token generation, prompts longer/shorter than the
# prime chunk, and one prompt longer than the decode chunk
SPEC = [(5, 8), (2, 4), (7, 11), (3, 1), (11, 6)]
CHUNKS = (1, 3, 64)     # 1, a prime, >= every prompt


def _prompts(cfg, spec, seed=2):
    key = jax.random.PRNGKey(seed)
    return [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (L,), 0, cfg.vocab_size)), n)
            for i, (L, n) in enumerate(spec)]


def _scfg(layout, loop, chunk, budget=0):
    kw = dict(max_len=64, batch_slots=2, decode_loop=loop,
              prefill_chunk=chunk, step_token_budget=budget)
    if layout == "paged":
        kw.update(kv_layout="paged", block_size=8, num_blocks=40)
    return ServeConfig(**kw)


@pytest.fixture(scope="module")
def fp():
    cfg = _tiny_cfg()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def quant():
    """w4a8 ASER base + two LoRA tenants; engines add int8 KV."""
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.quant import calibrate, quantize_model, reduce_shared
    from repro.serve.adapters import AdapterRegistry, install_pools
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    qp = quantize_model(params, tape, "aser_as(rank=8)")
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    reg.add("t1")
    return cfg, install_pools(qp, slots=3, rank=4), reg


def _run(engine, prompts, extra=None, aids=None, **sched_kw):
    sched = Scheduler(engine, chunk_size=3, **dict(extra or {}, **sched_kw))
    hs = [sched.submit(p, n, adapter_id=aids[i] if aids else None)
          for i, (p, n) in enumerate(prompts)]
    sched.run(max_steps=500)
    assert_drained(sched)
    for h in hs:
        assert h.status is RequestStatus.COMPLETED, h.status
    return [list(h.tokens) for h in hs], sched


# ---------------------------------------------------------------------------
# The property: chunked == one-shot, across the matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_matches_oneshot_fp(fp, layout):
    """fp stack, scan loop: every chunk size and a budgeted variant
    reproduce the one-shot scheduler's streams exactly."""
    cfg, params = fp
    prompts = _prompts(cfg, SPEC)
    ref, _ = _run(Engine(params, cfg, _scfg(layout, "scan", 0)), prompts)
    for chunk in CHUNKS:
        eng = Engine(params, cfg, _scfg(layout, "scan", chunk))
        got, sched = _run(eng, prompts)
        assert got == ref, (layout, chunk)
        n_chunks = sum(-(-len(p) // chunk) for p, _ in prompts)
        assert sched.prefill_chunks_run == n_chunks
    # a finite budget reorders chunk interleaving, never tokens
    eng = Engine(params, cfg, _scfg(layout, "scan", 3, budget=9))
    got, sched = _run(eng, prompts)
    assert got == ref, (layout, "budgeted")
    assert sched.tokens_spent > 0


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_matches_oneshot_fp_step_loop(fp, layout):
    """The step debug loop shares the property (different decode path,
    same chunked prefill writes)."""
    cfg, params = fp
    prompts = _prompts(cfg, SPEC)
    ref, _ = _run(Engine(params, cfg, _scfg(layout, "step", 0)), prompts)
    for chunk in CHUNKS:
        got, _ = _run(Engine(params, cfg, _scfg(layout, "step", chunk)),
                      prompts)
        assert got == ref, (layout, chunk)


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["scan", "step"])
def test_chunked_matches_oneshot_quantized(quant, loop):
    """Full quantized stack: w4a8 ASER + int8 KV + adapter routing. The
    chunked scatter goes through KV scale tensors and adapter-salted
    prefixes; tokens must still match one-shot exactly."""
    cfg, pooled, reg = quant
    prompts = _prompts(cfg, SPEC)
    aids = [None, "t0", "t1", "t0", None]

    def scfg(chunk):
        return ServeConfig(max_len=64, batch_slots=2, decode_loop=loop,
                           kv_layout="paged", block_size=8, num_blocks=40,
                           kv_dtype="int8", prefill_chunk=chunk)

    extra = {"adapters": reg}
    ref, _ = _run(Engine(pooled, cfg, scfg(0)), prompts, extra, aids)
    for chunk in CHUNKS:
        got, _ = _run(Engine(pooled, cfg, scfg(chunk)), prompts, extra,
                      aids)
        assert got == ref, (loop, chunk)


# ---------------------------------------------------------------------------
# Prefix-reuse hits landing mid-chunk
# ---------------------------------------------------------------------------

def test_prefix_hit_resumes_mid_chunk(fp):
    """A cached-prefix admission starts its chunked prefill at
    ``start = shared_tok``, which lands strictly inside a chunk for
    chunk sizes that don't divide it — the stream must still be exact
    and the hit must be counted."""
    cfg, params = fp
    p = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (21,),
                                      0, cfg.vocab_size))
    ref, _ = _run(Engine(params, cfg, _scfg("paged", "scan", 0)),
                  [(p, 6)], prefix_reuse=True)
    for chunk in (3, 5, 32):       # 16 % 3, 16 % 5 != 0: mid-chunk starts
        eng = Engine(params, cfg, _scfg("paged", "scan", chunk))
        sched = Scheduler(eng, chunk_size=3, prefix_reuse=True)
        h1 = sched.submit(p, 6)
        sched.run(max_steps=200)
        h2 = sched.submit(p, 6)
        sched.run(max_steps=200)
        assert_drained(sched)
        assert [h1.tokens, h2.tokens] == [ref[0], ref[0]], chunk
        # 21 tokens / block 8 -> two full pages cached: 16 shared tokens
        assert sched.prefix_hits == 1 and sched.shared_tokens == 16, chunk


def test_fully_cached_prompt_cow_mid_chunk(fp):
    """A 100%-cached prompt takes the COW path (private copy of the last
    shared page, re-prefill only the final token) — in chunked mode that
    final token is a single one-token chunk."""
    cfg, params = fp
    p = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (16,),
                                      0, cfg.vocab_size))
    ref, _ = _run(Engine(params, cfg, _scfg("paged", "scan", 0)),
                  [(p, 5)], prefix_reuse=True)
    eng = Engine(params, cfg, _scfg("paged", "scan", 4))
    sched = Scheduler(eng, chunk_size=3, prefix_reuse=True)
    h1 = sched.submit(p, 5)
    sched.run(max_steps=200)
    h2 = sched.submit(p, 5)
    sched.run(max_steps=200)
    assert_drained(sched)
    assert [h1.tokens, h2.tokens] == [ref[0], ref[0]]
    assert sched.cow_copies == 1


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_chunked_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_len=64, prefill_chunk=-1)
    with pytest.raises(ValueError):
        # budgeting a one-shot prefill is meaningless: the whole prompt
        # is a single unbudgetable dispatch
        ServeConfig(max_len=64, step_token_budget=8)
    with pytest.raises(ValueError):
        # a budget smaller than one chunk can never schedule that chunk
        ServeConfig(max_len=64, prefill_chunk=8, step_token_budget=4)
    ServeConfig(max_len=64, prefill_chunk=8, step_token_budget=8)
