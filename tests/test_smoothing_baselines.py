import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AserConfig, aser_smoothing, awq_quantize, gptq_quantize,
                        gram, layer_forward, outlier_indices, quantize_layer,
                        smoothquant_scales)
from repro.core.metrics import relative_output_error
from repro.core.quantizers import (A6, A8, W4, fake_quant_activation,
                                   fake_quant_weight)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(3)
    d_in, d_out, t = 128, 96, 1024
    w = jnp.asarray(rng.normal(size=(d_out, d_in)).astype(np.float32))
    x = rng.normal(size=(d_in, t)).astype(np.float32)
    x[rng.choice(d_in, 6, replace=False)] *= 15
    x = jnp.asarray(x)
    return w, x, gram(x), jnp.mean(jnp.abs(x), axis=1)


def test_outlier_indices_topf(setup):
    w, x, _, xm = setup
    mask = outlier_indices(xm, jnp.mean(jnp.abs(w), axis=0), f=8)
    assert int(mask.sum()) == 8


def test_smoothing_decomposition_exact(setup):
    """W M = W_s + W_o exactly, and the smoothed activation range shrinks."""
    w, x, _, xm = setup
    sm = aser_smoothing(w, xm, f=8)
    assert jnp.allclose(sm.w_scaled, sm.w_smooth + sm.w_outlier, atol=1e-5)
    x_s = x / sm.m[:, None]
    assert float(jnp.max(jnp.abs(x_s))) < float(jnp.max(jnp.abs(x)))
    # smoothing preserves the product: (W M)(M^{-1} X) == W X
    y0 = w @ x
    y1 = sm.w_scaled @ (x / sm.m[:, None])
    assert jnp.allclose(y0, y1, rtol=1e-4, atol=1e-3)


def test_activation_smoothing_helps_low_bit(setup):
    """Paper Fig. 5/Table claims: A.S. matters most at low activation bits."""
    w, x, g, xm = setup
    ref = w @ x
    errs = {}
    for smooth in (False, True):
        lay = quantize_layer(w, g, xm, AserConfig(rank=16, smooth=smooth,
                                                  outlier_f=8, damp=1e-4))
        y = layer_forward(lay, x,
                          act_fake_quant=lambda t: fake_quant_activation(t, A6))
        errs[smooth] = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert errs[True] < errs[False]


def test_smoothquant_scales_shift_difficulty(setup):
    w, x, _, _ = setup
    s = smoothquant_scales(jnp.max(jnp.abs(x), axis=1), jnp.max(jnp.abs(w), axis=0))
    x_s = x / s[:, None]
    # per-channel dynamic range is flattened
    assert float(jnp.std(jnp.max(jnp.abs(x_s), axis=1))) < \
        float(jnp.std(jnp.max(jnp.abs(x), axis=1)))


def test_gptq_beats_rtn(setup):
    w, x, g, _ = setup
    w_rtn = fake_quant_weight(w, W4)
    w_gptq = gptq_quantize(w, g, W4)
    assert relative_output_error(w, w_gptq, x) < relative_output_error(w, w_rtn, x)


def test_awq_beats_rtn(setup):
    w, x, g, xm = setup
    w_rtn = fake_quant_weight(w, W4)
    w_awq, s = awq_quantize(w, g, xm, W4)
    assert relative_output_error(w, w_awq, x) < relative_output_error(w, w_rtn, x)
    assert jnp.all(s > 0)


def test_aser_on_gptq_base(setup):
    """ER is orthogonal to the weight quantizer (paper: 'not limited to RTN')."""
    from repro.quant.apply import PTQConfig, _quantize_one
    from repro.models.layers import LinStats
    w, x, g, xm = setup
    t = x.shape[1]
    st = LinStats(g, jnp.abs(x).sum(1), jnp.abs(x).max(1), jnp.asarray(float(t)))
    ref = w @ x
    outs = {}
    for base in ("rtn", "gptq"):
        leaf = _quantize_one(w.T, st, PTQConfig(method="aser_as", rank=16,
                                                outlier_f=8, base=base))
        from repro.kernels.ref import w4a8_linear_ref
        y = w4a8_linear_ref(x.T, leaf["qw"], leaf["sw"], leaf["m"],
                            leaf["lb"], leaf["la"]).T
        outs[base] = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert outs["gptq"] < outs["rtn"] * 1.2   # gptq base at least comparable
