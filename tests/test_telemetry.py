"""Latency telemetry: exact percentiles, timing traces, summaries.

The percentile reducer feeds the ``serve_bench/v7`` TTFT/TPOT rows the
CI improvement gates read, so its edge behaviour is pinned hard here:
empty samples and non-finite values must *raise* (a NaN latency is a
stamping bug upstream, not a data point), and ranks are exact
nearest-rank — always an observed sample, never an interpolation.
"""
import math

import pytest

from repro.serve.telemetry import (RequestTiming, latency_summary,
                                   percentile, percentiles)


# ---------------------------------------------------------------------------
# percentile: exact nearest-rank
# ---------------------------------------------------------------------------

def test_percentile_single_sample():
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 0) == 7.0
    assert percentile([7.0], 100) == 7.0


def test_percentile_exact_ranks():
    vals = list(range(1, 101))          # 1..100: pN == N exactly
    assert percentile(vals, 50) == 50
    assert percentile(vals, 95) == 95
    assert percentile(vals, 99) == 99
    assert percentile(vals, 100) == 100
    assert percentile(vals, 0) == 1     # q=0 is the minimum
    assert percentile(vals, 1) == 1     # ceil(0.01 * 100) = rank 1


def test_percentile_is_an_observed_sample():
    """Nearest-rank never interpolates: the result is always an element
    of the input, even for awkward sample sizes."""
    vals = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.3]
    for q in (0, 10, 25, 50, 75, 90, 95, 99, 100):
        assert percentile(vals, q) in vals


def test_percentile_unsorted_input_and_copy():
    vals = [9.0, 1.0, 5.0]
    assert percentile(vals, 50) == 5.0
    assert vals == [9.0, 1.0, 5.0]      # input not mutated


def test_percentile_empty_raises():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)


def test_percentile_rejects_non_finite():
    for bad in (float("nan"), float("inf"), -float("inf")):
        with pytest.raises(ValueError, match="non-finite"):
            percentile([1.0, bad, 3.0], 50)


def test_percentile_rejects_bad_q():
    with pytest.raises(ValueError):
        percentile([1.0], -1)
    with pytest.raises(ValueError):
        percentile([1.0], 100.5)


def test_percentiles_dict():
    out = percentiles(list(range(1, 101)))
    assert out == {"p50": 50, "p95": 95, "p99": 99}
    assert percentiles([2.0, 1.0], qs=(50,)) == {"p50": 1.0}


# ---------------------------------------------------------------------------
# RequestTiming: TTFT / TPOT derivations
# ---------------------------------------------------------------------------

def test_ttft_none_until_first_token():
    t = RequestTiming(submitted_at=1.0)
    assert t.ttft() is None             # rejected/expired: no first token
    t.first_token_at = 1.25
    assert t.ttft() == pytest.approx(0.25)


def test_tpot_excludes_sub_two_token_requests():
    t = RequestTiming(submitted_at=0.0, first_token_at=1.0)
    assert t.tpot() is None             # no events at all
    t.token_events.append((1.0, 1))
    assert t.tpot() is None             # one token: no inter-token gap
    t.token_events.append((1.6, 4))     # 3 more tokens by t=1.6
    assert t.tpot() == pytest.approx(0.2)


def test_latency_summary_converts_to_ms():
    timings = []
    for i in range(4):
        t = RequestTiming(submitted_at=0.0, first_token_at=0.010 * (i + 1))
        t.token_events.append((t.first_token_at + 0.005, 3))
        timings.append(t)
    out = latency_summary(timings)
    assert out["n_ttft"] == 4 and out["n_tpot"] == 4
    assert out["ttft_ms"]["p50"] == pytest.approx(20.0)
    assert out["ttft_ms"]["p99"] == pytest.approx(40.0)
    assert out["tpot_ms"]["p50"] == pytest.approx(2.5)
    assert out["ttft_ms"]["p50"] <= out["ttft_ms"]["p95"] \
        <= out["ttft_ms"]["p99"]


def test_latency_summary_tokenless_requests_excluded():
    emitted = RequestTiming(submitted_at=0.0, first_token_at=0.5)
    emitted.token_events.append((1.0, 2))
    silent = RequestTiming(submitted_at=0.0)      # shed before any token
    out = latency_summary([emitted, silent])
    assert out["n_ttft"] == 1 and out["n_tpot"] == 1


def test_latency_summary_empty_raises():
    with pytest.raises(ValueError, match="no request"):
        latency_summary([])
    # tokens emitted but never a second one: TPOT sample empty -> raise
    only_one = RequestTiming(submitted_at=0.0, first_token_at=0.5)
    only_one.token_events.append((0.5, 1))
    with pytest.raises(ValueError, match="TPOT"):
        latency_summary([only_one])


def test_latency_summary_propagates_nan_rejection():
    t = RequestTiming(submitted_at=0.0, first_token_at=math.nan)
    t.token_events.append((1.0, 2))
    with pytest.raises(ValueError, match="non-finite"):
        latency_summary([t])
