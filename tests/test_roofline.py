"""Roofline machinery: HLO collective parsing, term arithmetic."""
import pytest

from repro.roofline.analysis import (Roofline, _shape_bytes, collective_bytes,
                                     model_flops_estimate)


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[8,1,2048]{2,1,0}") == 8 * 2048 * 2
    assert _shape_bytes("(f32[4,4], s8[16])") == 64 + 16
    assert _shape_bytes("s32[]") == 4


def test_collective_parse():
    hlo = """
  %all-reduce.97 = f32[8,1,2048]{2,1,0} all-reduce(%fusion), channel_id=4
  %ag = bf16[64,64]{1,0} all-gather(%x), dimensions={0}
  ROOT %rs = f32[32]{0} reduce-scatter(%y), dimensions={0}
  %cp-start = bf16[16,16]{1,0} collective-permute-start(%z)
  %cp-done = bf16[16,16]{1,0} collective-permute-done(%cp-start)
  %not-a-coll = f32[4]{0} add(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 8 * 2048 * 4
    assert got["all-gather"] == 64 * 64 * 2
    assert got["reduce-scatter"] == 32 * 4
    assert got["collective-permute"] == 16 * 16 * 2  # start only, done skipped
    assert "add" not in got


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", cell="c", mesh="single", chips=256,
                 flops=197e12, bytes_accessed=819e9 * 2,
                 coll_bytes=50e9 * 0.5, coll_breakdown={},
                 model_flops=197e12 * 256 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.roofline_fraction - 0.25) < 1e-9


def test_model_flops_estimates_sane():
    from repro.configs.registry import SHAPES, get_config
    train = SHAPES[0]
    f_dense = model_flops_estimate(get_config("olmo_1b"), train)
    # olmo-1b ≈ 1.3B params → 6·N·D ≈ 6 × 1.3e9 × 1e6 tokens
    assert 5e15 < f_dense < 1.5e16
    f_moe = model_flops_estimate(get_config("kimi_k2_1t"), train)
    # active ≈ 32B → ~2e17
    assert 8e16 < f_moe < 5e17
    decode = SHAPES[2]
    f_dec = model_flops_estimate(get_config("olmo_1b"), decode)
    assert f_dec < f_dense / 1000
