"""MoE dispatch correctness + properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.models.config import ModelConfig
from repro.models.moe import _capacity, _positions_in_expert, moe_block, moe_params


def _cfg(e=8, k=2, cf=64.0):
    return ModelConfig(name="t", family="moe", d_model=32, n_experts=e,
                       top_k=k, moe_d_ff=16, n_shared_experts=0,
                       capacity_factor=cf, dtype="float32")


def dense_moe_reference(p, cfg, x):
    """Compute every expert for every token, combine with top-k gates."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["experts"]["gate"])) \
        * jnp.einsum("td,edf->tef", xt, p["experts"]["up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["experts"]["down"])
    y = jnp.einsum("tk,tkd->td", gv,
                   jnp.take_along_axis(y_all, gi[:, :, None], axis=1))
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference(key):
    cfg = _cfg()
    p = moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y, aux = moe_block(p, cfg, x)
    y_ref = dense_moe_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens(key):
    """With tiny capacity, outputs differ from the dense reference (drops)."""
    cfg = _cfg(cf=0.25)
    p = moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, cfg.d_model))
    y, _ = moe_block(p, cfg, x)
    y_ref = dense_moe_reference(p, cfg, x)
    assert float(jnp.max(jnp.abs(y - y_ref))) > 1e-3
    assert bool(jnp.all(jnp.isfinite(y)))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(10, 300))
def test_positions_in_expert_property(e, n):
    rng = np.random.default_rng(e * 1000 + n)
    flat = jnp.asarray(rng.integers(0, e, size=(n,)), jnp.int32)
    pos = np.asarray(_positions_in_expert(flat, e, chunk=64))
    flat = np.asarray(flat)
    # positions within each expert are 0..count-1, in order of appearance
    for ee in range(e):
        got = pos[flat == ee]
        assert list(got) == list(range(len(got)))


def test_shared_expert_added(key):
    cfg = dataclasses.replace(_cfg(), n_shared_experts=1)
    p = moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 8, cfg.d_model))
    y, _ = moe_block(p, cfg, x)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(lambda a: a * 0, p["shared"])
    y2, _ = moe_block(p2, cfg, x)
    assert float(jnp.max(jnp.abs(y - y2))) > 1e-5


def test_aux_loss_balanced_vs_skewed(key):
    cfg = _cfg(e=4, k=1)
    p = moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 4), (4, 64, cfg.d_model))
    _, aux_rand = moe_block(p, cfg, x)
    # force router collapse to expert 0
    p_skew = dict(p)
    wr = np.zeros_like(np.asarray(p["router"]["w"]))
    wr[:, 0] = 10.0
    p_skew["router"] = {"w": jnp.asarray(wr)}
    _, aux_skew = moe_block(p_skew, cfg, x)
    assert float(aux_skew) > float(aux_rand)
