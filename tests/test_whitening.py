import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.core.quantizers import W4, fake_quant_weight
from repro.core.whitening import (cholesky_whitener, effective_rank, gram,
                                  low_rank_factors, rank_from_alpha,
                                  whiten_svd)


def _data(rng, d=64, t=512, out=48, outliers=4):
    w = rng.normal(size=(out, d)).astype(np.float32)
    x = rng.normal(size=(d, t)).astype(np.float32)
    x[rng.choice(d, outliers, replace=False)] *= 10
    return jnp.asarray(w), jnp.asarray(x)


def test_whitening_identity(rng):
    _, x = _data(rng)
    g = gram(x)
    s = cholesky_whitener(g, damp=1e-9)
    xw = jnp.linalg.solve(s, x)
    gw = xw @ xw.T
    assert jnp.allclose(gw, jnp.eye(x.shape[0]), atol=2e-2)


def test_eq8_truncation_loss_equals_singular_values(rng):
    """Paper Eq. 8: residual after rank-r compensation = sqrt(Σ_{i>r} σ_i²)."""
    w, x = _data(rng)
    g = gram(x)
    wq = fake_quant_weight(w, W4)
    e_q = w - wq
    s = cholesky_whitener(g, damp=1e-8)
    u, sig, vt = whiten_svd(e_q, s)
    for r in (4, 16, 32):
        la, lb = low_rank_factors(u, sig, vt, s, r)
        resid = jnp.linalg.norm((e_q - la @ lb) @ x)
        pred = jnp.sqrt(jnp.sum(sig[r:] ** 2))
        assert abs(float(resid - pred)) / float(pred) < 1e-3


def test_effective_rank_bounds(rng):
    # identity-like spectrum → eff rank ≈ n; one dominant value → ≈ 1
    n = 32
    flat = effective_rank(jnp.ones((n,)))
    assert abs(float(flat) - n) < 1e-2
    spiked = effective_rank(jnp.asarray([1e6] + [1e-9] * (n - 1)))
    assert float(spiked) < 1.5


def test_rank_from_alpha_monotone():
    sig = jnp.asarray(np.linspace(10, 0.1, 50).astype(np.float32))
    r1 = int(rank_from_alpha(sig, 0.1))
    r2 = int(rank_from_alpha(sig, 0.5))
    r3 = int(rank_from_alpha(sig, 0.9))
    assert 1 <= r1 <= r2 <= r3 <= 50


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 48), st.floats(0.05, 0.95))
def test_rank_alpha_property(n, alpha):
    rng = np.random.default_rng(n)
    sig = jnp.sort(jnp.asarray(rng.uniform(0.01, 1, n).astype(np.float32)))[::-1]
    r = int(rank_from_alpha(sig, alpha))
    cum = jnp.cumsum(sig) / jnp.sum(sig)
    # r is maximal with cumulative fraction below alpha (clamped to >=1)
    if r > 1:
        assert float(cum[r - 2]) < alpha
    if r < n:
        assert float(cum[r]) >= alpha
