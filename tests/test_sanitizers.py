"""Steady-state sanitizers: continuous decode must be retrace-free and
implicit-transfer-free after warmup, across serving configurations.

Each test warms a scheduler workload once (compiling every program the
bucket widths need), then replays the identical workload on a fresh
scheduler over the *same* engine under ``jax.transfer_guard("disallow")``
with the backend-compile counter armed. A nonzero count means a decode
step re-traced (retrace bomb) or synced implicitly (hidden ``int()`` /
numpy coercion on the hot path) — exactly the regressions RA001/RA004
lint for statically, proven here at runtime on four configs:

fp contiguous · w4a8_aser contiguous · paged + int8 KV · adapter-routed.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import init_params
from repro.quant import calibrate, quantize_model, reduce_shared
from repro.serve.adapters import AdapterRegistry, install_pools
from repro.serve.engine import Engine, ServeConfig
from repro.serve.lifecycle import assert_drained
from repro.serve.scheduler import Scheduler


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tiny_quant(tiny):
    cfg, params = tiny
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    return cfg, quantize_model(params, tape, "aser_as(rank=8)")


def _prompts(cfg, spec, seed=2):
    key = jax.random.PRNGKey(seed)
    return [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (L,), 0, cfg.vocab_size)), n)
            for i, (L, n) in enumerate(spec)]


_SPEC = [(5, 8), (2, 4), (7, 6), (4, 5)]


def _assert_steady(audit, eng, cfg, *, adapters=None, adapter_ids=None):
    reqs = _prompts(cfg, _SPEC)

    def submit(sched):
        for i, (p, n) in enumerate(reqs):
            aid = adapter_ids[i % len(adapter_ids)] if adapter_ids else None
            sched.submit(p, n, adapter_id=aid)

    def make():
        return Scheduler(eng, chunk_size=3, adapters=adapters)

    report = audit(make, submit)
    assert report.recompiles == 0, \
        f"{report.recompiles} recompiles in steady-state decode"
    assert report.implicit_transfers == 0, \
        f"implicit transfer in steady-state decode: {report.errors}"
    # the audited replay really ran the workload
    sched = make()
    submit(sched)
    sched.run()
    assert_drained(sched)


def test_steady_state_fp_contiguous(tiny, steady_state_audit):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    _assert_steady(steady_state_audit, eng, cfg)


def test_steady_state_w4a8_contiguous(tiny_quant, steady_state_audit):
    cfg, qp = tiny_quant
    eng = Engine(qp, cfg, ServeConfig(max_len=64, batch_slots=2))
    _assert_steady(steady_state_audit, eng, cfg)


def test_steady_state_paged_int8_kv(tiny, steady_state_audit):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=16, kv_dtype="int8"))
    _assert_steady(steady_state_audit, eng, cfg)


def test_steady_state_adapters(tiny_quant, steady_state_audit):
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    reg.add("a")
    reg.add("b")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=2))
    _assert_steady(steady_state_audit, eng, cfg, adapters=reg,
                   adapter_ids=["a", "b", None])


def test_transfer_guard_blocks_implicit(transfer_guard):
    """The guard itself works: implicit h2d into a jitted call aborts,
    explicit get/put stays legal."""
    f = jax.jit(lambda x: x * 2)
    host = np.arange(4, dtype=np.float32)
    dev = jax.device_put(host)
    with transfer_guard():
        f(dev)                         # device arg: fine
        _ = jax.device_get(dev)        # explicit d2h: fine
        with pytest.raises(Exception):
            int(f(dev)[0])             # implicit scalar d2h: blocked
        with pytest.raises(Exception):
            f(host)                    # implicit h2d upload: blocked


def test_retrace_counter_counts(retrace_counter):
    """The counter sees real compiles and stays silent on cache hits."""
    @jax.jit
    def g(x):
        return x + 1

    x = jax.device_put(np.ones((3,), np.float32))
    with retrace_counter() as cc:
        g(x)
    assert cc.count >= 1               # first call compiles
    with retrace_counter() as cc:
        g(x)
        g(x)
    assert cc.count == 0               # cached thereafter
