"""Continuous-batching scheduler: lifecycle, parity, backfill, streaming."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.serve.lifecycle import assert_drained
from repro.serve.scheduler import (RequestHandle, RequestStatus, Scheduler,
                                   _bucket)


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, spec, seed=2):
    key = jax.random.PRNGKey(seed)
    return [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (L,), 0, cfg.vocab_size)), n)
            for i, (L, n) in enumerate(spec)]


# ---------------------------------------------------------------------------
# Scheduler output ≡ per-request Engine.generate (greedy)
# ---------------------------------------------------------------------------

def test_scheduler_matches_per_request_generate(tiny):
    """6 mixed requests over 2 slots with backfill: every request's stream
    equals its dedicated single-request generation, token for token."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    sched = Scheduler(eng, chunk_size=3)
    reqs = [(p, n, sched.submit(p, n)) for p, n in
            _prompts(cfg, [(5, 8), (2, 4), (7, 11), (3, 1), (4, 6), (6, 9)])]
    assert sched.pending == 6
    sched.run()
    assert sched.pending == 0
    assert_drained(sched)
    for prompt, n, handle in reqs:
        assert handle.done
        ref = np.asarray(eng.generate(jnp.asarray(prompt[None]), n))[0]
        assert np.array_equal(np.asarray(handle.tokens), ref), \
            (len(prompt), n)
    # backfill actually happened: 6 requests can't fit 2 slots at once, and
    # the whole run must cost far fewer chunks than serial per-request runs
    assert sched.chunks_run >= 2


def test_scheduler_eos_retires_and_backfills(tiny):
    """A slot that hits EOS retires early; queued work backfills it and
    still matches its own dedicated run."""
    cfg, params = tiny
    probe = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    (p0, _), (p1, _) = _prompts(cfg, [(5, 20), (4, 20)], seed=9)
    free = np.asarray(probe.generate(jnp.asarray(p0[None]), 8))[0]
    eos = int(free[3])

    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=1,
                                          eos_id=eos))
    sched = Scheduler(eng, chunk_size=2)
    h0, h1 = sched.submit(p0, 20), sched.submit(p1, 20)
    sched.run()
    ref0 = np.asarray(eng.generate(jnp.asarray(p0[None]), 20))[0]
    stop0 = int(np.nonzero(ref0 == eos)[0][0])
    assert h0.tokens == ref0[:stop0 + 1].tolist()     # eos included, then cut
    ref1 = np.asarray(eng.generate(jnp.asarray(p1[None]), 20))[0]
    hits1 = np.nonzero(ref1 == eos)[0]
    want1 = ref1[:int(hits1[0]) + 1] if hits1.size else ref1
    assert h1.tokens == want1.tolist()


def test_streaming_poll_yields_deltas(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    sched = Scheduler(eng, chunk_size=2)
    (p, n), = _prompts(cfg, [(5, 7)])
    handle = sched.submit(p, n)
    assert handle.poll() == []                         # still queued
    seen = []
    while sched.step():
        delta = handle.poll()
        seen += delta
    seen += handle.poll()
    assert handle.done and seen == handle.tokens and len(seen) == n
    assert handle.poll() == []                         # drained


def test_one_token_requests_never_occupy_a_slot(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=1))
    sched = Scheduler(eng, chunk_size=4)
    reqs = [(p, sched.submit(p, 1)) for p, _ in
            _prompts(cfg, [(3, 1), (5, 1), (2, 1)])]
    sched.run()
    assert sched.chunks_run == 0                       # prefill-only traffic
    for p, h in reqs:
        ref = np.asarray(eng.generate(jnp.asarray(p[None]), 1))[0]
        assert h.done and h.tokens == ref.tolist()


def test_submit_validation(tiny):
    """Malformed input raises (caller bug); capacity sheds with REJECTED
    (load condition) — an oversized request must never wedge run()."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=16, batch_slots=1))
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit([1, 2], 0)
    with pytest.raises(ValueError, match="empty"):
        sched.submit([], 2)
    with pytest.raises(ValueError, match="chunk_size"):
        Scheduler(eng, chunk_size=0)
    # capacity is shed, not raised: terminal handle, nothing enqueued
    h = sched.submit(list(range(10)), 10)
    assert h.done and h.status is RequestStatus.REJECTED
    assert "exceeds max_len" in h.error
    assert sched.pending == 0 and sched.rejected == 1
    sched.run()                                  # returns immediately
    assert_drained(sched)


def test_bucket_bounds_recompiles():
    assert _bucket(1, 512) == 8
    assert _bucket(8, 512) == 8
    assert _bucket(9, 512) == 16
    assert _bucket(300, 512) == 512
    assert _bucket(300, 256) == 256


# ---------------------------------------------------------------------------
# Edge cases: retired handles, exhausted pools, page recycling
# ---------------------------------------------------------------------------

def test_poll_after_handle_retired(tiny):
    """poll() on a retired handle drains the tail once, then stays empty —
    callers that poll lazily never lose or duplicate tokens."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    sched = Scheduler(eng, chunk_size=4)
    (p, n), = _prompts(cfg, [(5, 7)])
    handle = sched.submit(p, n)
    sched.run()                                # never polled while running
    assert handle.done
    tail = handle.poll()
    assert tail == handle.tokens and len(tail) == n
    assert handle.poll() == [] and handle.poll() == []


def _paged_engine(params, cfg, **kw):
    return Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                           kv_layout="paged", block_size=8,
                                           **kw))


def test_admission_waits_for_pages(tiny):
    """Page-aware admission: with the pool fully owned by a long request,
    a queued request stays queued (no slot is wasted on it) and admits
    only after pages free up."""
    cfg, params = tiny
    eng = _paged_engine(params, cfg, num_blocks=8)   # one max_len lane
    sched = Scheduler(eng, chunk_size=2)
    # big takes ceil(41/8) = 6 of 8 pages at admission; small needs
    # ceil(18/8) = 3 > the 2 remaining, so it must wait for big to retire
    (p_big, n_big), (p_small, n_small) = _prompts(cfg, [(40, 24), (17, 4)],
                                                  seed=21)
    h_big = sched.submit(p_big, n_big)
    h_small = sched.submit(p_small, n_small)
    assert sched.step()                        # admits big; small won't fit
    assert h_big.tokens and not h_small.tokens
    assert not h_small.done and sched.pending == 2
    sched.run()
    assert h_big.done and h_small.done
    ref = np.asarray(eng.generate(jnp.asarray(p_small[None]), n_small))[0]
    assert h_small.tokens == ref.tolist()


def test_retire_backfills_reusing_freed_pages(tiny):
    """Retire-then-backfill recycles physical pages: with a pool that only
    fits ~2 live requests, 6 requests drain correctly and every page is
    back (free or prefix-cached) at the end."""
    cfg, params = tiny
    eng = _paged_engine(params, cfg, num_blocks=10)
    sched = Scheduler(eng, chunk_size=3)
    reqs = [(p, n, sched.submit(p, n)) for p, n in
            _prompts(cfg, [(9, 6), (12, 8), (10, 5), (8, 7), (11, 4),
                           (7, 9)], seed=31)]
    sched.run()
    seen = set()
    for p, n, h in reqs:
        assert h.done
        ref = np.asarray(eng.generate(jnp.asarray(p[None]), n))[0]
        assert np.array_equal(np.asarray(h.tokens), ref), (len(p), n)
    assert sched.pool.live() == 0
    assert sched.pool.available() == 10        # free + evictable cache
    # the pool is far smaller than Σ request footprints: pages were reused
    total_blocks = sum(-(-(len(p) + n + 1) // 8) for p, n, _ in reqs)
    assert total_blocks > 10


# ---------------------------------------------------------------------------
# Adapter telemetry through the streaming API
# ---------------------------------------------------------------------------

def test_poll_with_stats_reports_adapter_telemetry(tiny):
    """poll(with_stats=True) surfaces the per-adapter prefix hit rate and
    the pool counters on every handle; adapter-free schedulers report the
    zeroed base view with the same keys (stable client schema)."""
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.quant import calibrate, quantize_model, reduce_shared
    from repro.serve.adapters import AdapterRegistry, install_pools
    cfg, params = tiny
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    qp = quantize_model(params, tape, "aser_as(rank=8)")
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = _paged_engine(pooled, cfg, num_blocks=16)
    sched = Scheduler(eng, chunk_size=2, adapters=reg)
    (p, n), = _prompts(cfg, [(17, 4)], seed=3)
    base_keys = {"adapter_id", "adapter_prefix_hit_rate", "adapter_loads",
                 "capacity", "resident", "live", "occupancy", "hits",
                 "misses", "evictions"}

    h = sched.submit(p, n, adapter_id="t0")
    sched.run()
    delta, st = h.poll(with_stats=True)
    assert delta == h.tokens and set(st) == base_keys
    assert st["adapter_id"] == "t0" and st["adapter_loads"] == 1
    assert st["misses"] == 1 and st["resident"] == 1
    assert st["capacity"] == 2 and st["occupancy"] == 0.5
    assert st["adapter_prefix_hit_rate"] == 0.0          # cold prefix

    h2 = sched.submit(p, n, adapter_id="t0")             # warm repeat
    sched.run()
    _, st2 = h2.poll(with_stats=True)
    assert st2["hits"] == 1 and st2["adapter_loads"] == 1   # no reload
    assert st2["adapter_prefix_hit_rate"] > 0.0             # salted hit
    assert sched.adapter_stats()["live"] == 0               # all released

    # adapter-free scheduler: same keys, zeroed base view
    plain = Scheduler(_paged_engine(params, cfg, num_blocks=16),
                      chunk_size=2)
    hp = plain.submit(p, n)
    plain.run()
    _, stp = hp.poll(with_stats=True)
    assert set(stp) == base_keys and stp["adapter_id"] is None
    assert stp["capacity"] == 0 and stp["adapter_loads"] == 0


# ---------------------------------------------------------------------------
# Head-of-line blocking: the chunked + budgeted regression pin
# ---------------------------------------------------------------------------

def _drive(sched, clock_cell, short, long_prompt, long_n, submit_at=4):
    """Step the scheduler under a token-proportional cost model: after
    each step the fake clock advances by the device tokens that step
    dispatched — prefill (one-shot or chunked) plus the decode chunk.
    That makes inter-token *time* gaps equal token costs, so head-of-line
    blocking shows up deterministically without wall-clock noise."""
    costs = []
    h_long = None
    for step in range(400):
        if step == submit_at:
            h_long = sched.submit(long_prompt, long_n)
        queued = {h for h in (h_long,) if h is not None
                  and h.status is RequestStatus.QUEUED}
        more = sched.step()
        admitted = sum(len(h.request.prompt) for h in queued
                       if h.status is not RequestStatus.QUEUED
                       and not getattr(sched, "prefill_chunk", 0))
        cost = admitted + sched.last_step_tokens \
            if sched.prefill_chunk else \
            admitted + sched.chunk_size * sum(
                1 for s in range(sched.slots)
                if sched._slot_handle[s] is not None)
        costs.append(cost)
        clock_cell[0] += cost
        if not more and h_long is not None:
            break
    return h_long, costs


def test_chunked_budget_bounds_inter_token_gaps(tiny):
    """The head-of-line-blocking pin: a max-length prompt arriving while
    a short request decodes must not open an inter-token gap beyond the
    per-step token budget — chunked+budgeted bounds every step's token
    cost, where one-shot prefill dispatches the whole prompt inside one
    step and stalls the in-flight stream for its full length."""
    cfg, params = tiny
    long_prompt = _prompts(cfg, [(48, 1)], seed=7)[0][0]   # near max_len
    short, short_n = _prompts(cfg, [(4, 24)], seed=8)[0]
    budget = 10

    def gaps(handle):
        ev = handle.timing.token_events
        return [b[0] - a[0] for a, b in zip(ev, ev[1:])]

    # chunked + budgeted: every in-flight gap obeys the budget bound
    clk = [0.0]
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          prefill_chunk=4,
                                          step_token_budget=budget))
    sched = Scheduler(eng, chunk_size=3, clock=lambda: clk[0])
    h_short = sched.submit(short, short_n)
    h_long, costs = _drive(sched, clk, h_short, long_prompt, 1)
    assert_drained(sched)
    assert h_short.status is RequestStatus.COMPLETED
    assert h_long.status is RequestStatus.COMPLETED
    assert max(costs) <= budget                  # per-step hard cap
    assert all(sched.last_step_tokens <= budget for _ in (0,))
    chunked_gaps = gaps(h_short)
    assert chunked_gaps and max(chunked_gaps) <= budget
    chunked_tokens = list(h_short.tokens)

    # one-shot: the long admission step blows a > budget gap into the
    # short request's stream (the regression this test exists to catch)
    clk = [0.0]
    eng1 = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2))
    sched1 = Scheduler(eng1, chunk_size=3, clock=lambda: clk[0])
    h_short1 = sched1.submit(short, short_n)
    h_long1, _ = _drive(sched1, clk, h_short1, long_prompt, 1)
    assert_drained(sched1)
    oneshot_gaps = gaps(h_short1)
    assert max(oneshot_gaps) > budget            # HOL blocking, visible
    # and chunking changed latency shape only — never the tokens
    assert chunked_tokens == h_short1.tokens


def test_step_token_budget_accounting(tiny):
    """`tokens_spent` / `last_step_tokens` account every device token a
    step dispatches: prefill chunks plus decode-chunk cost; the run total
    covers every prompt token exactly once plus all decode chunks."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          prefill_chunk=4,
                                          step_token_budget=12))
    sched = Scheduler(eng, chunk_size=3)
    reqs = _prompts(cfg, [(5, 8), (11, 4), (2, 6)])
    hs = [sched.submit(p, n) for p, n in reqs]
    per_step = []
    while True:
        more = sched.step()
        per_step.append(sched.last_step_tokens)   # final step counts too
        if not more:
            break
    assert_drained(sched)
    assert all(c <= 12 for c in per_step), per_step
    assert sum(per_step) == sched.tokens_spent
    prompt_toks = sum(len(p) for p, _ in reqs)
    # every prompt token prefilled exactly once; the rest is decode chunks
    assert sched.tokens_spent >= prompt_toks
    assert (sched.tokens_spent - prompt_toks) % sched.chunk_size == 0
