import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (aser_er, aser_er_alpha, l2qer, lorc, gram)
from repro.core.metrics import relative_output_error
from repro.core.quantizers import W4, fake_quant_weight


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(7)
    d_in, d_out, t = 128, 96, 1024
    w = jnp.asarray(rng.normal(size=(d_out, d_in)).astype(np.float32))
    x = rng.normal(size=(d_in, t)).astype(np.float32)
    x[rng.choice(d_in, 6, replace=False)] *= 12
    x = jnp.asarray(x)
    wq = fake_quant_weight(w, W4)
    return w, x, wq, w - wq, gram(x), jnp.mean(jnp.abs(x), axis=1)


def test_method_ordering(setup):
    """Paper's central claim: data-aware whitening beats activation scaling
    beats plain weight-SVD beats no compensation."""
    w, x, wq, e_q, g, xm = setup
    r = 24
    err = {"rtn": relative_output_error(w, wq, x)}
    c = lorc(e_q, r)
    err["lorc"] = relative_output_error(w, wq + c.l_a @ c.l_b, x)
    c = l2qer(e_q, xm, r)
    err["l2qer"] = relative_output_error(w, wq + c.l_a @ c.l_b, x)
    c = aser_er(e_q, g, r, damp=1e-4)
    err["aser"] = relative_output_error(w, wq + c.l_a @ c.l_b, x)
    assert err["aser"] < err["l2qer"] < err["lorc"] < err["rtn"]


def test_rank_monotone(setup):
    w, x, wq, e_q, g, _ = setup
    errs = []
    for r in (4, 16, 48, 96):
        c = aser_er(e_q, g, r, damp=1e-4)
        errs.append(float(relative_output_error(w, wq + c.l_a @ c.l_b, x)))
    assert errs == sorted(errs, reverse=True)


def test_full_rank_recovers_error(setup):
    w, x, wq, e_q, g, _ = setup
    c = aser_er(e_q, g, min(e_q.shape), damp=1e-8)
    assert float(relative_output_error(w, wq + c.l_a @ c.l_b, x)) < 1e-4


def test_alpha_selects_rank(setup):
    _, _, _, e_q, g, _ = setup
    comp_lo, r_lo = aser_er_alpha(e_q, g, alpha=0.1, max_rank=96)
    comp_hi, r_hi = aser_er_alpha(e_q, g, alpha=0.9, max_rank=96)
    assert int(r_lo) <= int(r_hi)
    # masked tail rows/cols are zero
    assert jnp.allclose(comp_lo.l_a[:, int(r_lo):], 0)
    assert jnp.allclose(comp_lo.l_b[int(r_lo):, :], 0)


def test_lorc_optimal_for_weight_error(setup):
    """LoRC minimizes ‖E−Ẽ‖_F (not ‖(E−Ẽ)X‖_F): check Eckart-Young holds."""
    _, _, _, e_q, _, _ = setup
    c = lorc(e_q, 16)
    sig = jnp.linalg.svd(e_q, compute_uv=False)
    resid = jnp.linalg.norm(e_q - c.l_a @ c.l_b)
    assert abs(float(resid) - float(jnp.sqrt(jnp.sum(sig[16:] ** 2)))) < 1e-2
