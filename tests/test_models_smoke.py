"""Per-architecture smoke tests (deliverable f): reduced config, one forward
and one train step on CPU, asserting shapes + finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, PAPER_IDS, get_config, get_smoke_config
from repro.models import encode, forward, init_params, param_count
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

ALL = ARCH_IDS + PAPER_IDS


def _inputs(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        kwargs["mrope_positions"] = jnp.stack([pos, pos, pos])
    return tokens, kwargs


@pytest.mark.parametrize("arch", ALL)
def test_forward_smoke(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(key, cfg)
    tokens, kwargs = _inputs(cfg, key)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (2, cfg.encoder_seq, cfg.d_model))
        kwargs["encoder_out"] = encode(params, cfg, frames)
    logits, caches, aux = forward(params, cfg, tokens, **kwargs)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert param_count(params) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    params = init_params(key, cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    if cfg.family == "encdec" or cfg.mrope_sections:
        from repro.launch.steps import make_train_step_fn
        step = make_train_step_fn(cfg, tcfg)
    else:
        step = make_train_step(cfg, tcfg)
    opt = init_opt_state(params)
    tokens, kwargs = _inputs(cfg, key, s=17)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (2, cfg.encoder_seq, cfg.d_model))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL)
def test_full_config_shapes(arch):
    """The exact assigned config is importable and self-consistent."""
    cfg = get_config(arch)
    assert cfg.d_model > 0 and cfg.vocab_size > 0
    if cfg.n_heads:
        assert cfg.q_dim % cfg.head_dim == 0
        assert cfg.n_heads % max(cfg.n_kv_heads, 1) == 0
    if cfg.n_experts:
        assert 0 < cfg.top_k <= cfg.n_experts
    (cfg.n_layers - cfg.n_dense_layers) % cfg.group_size == 0
