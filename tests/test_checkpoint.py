"""Fault-tolerance: checkpoint atomicity, retention, resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.train.optimizer import OptState, init_opt_state


def _state(key, scale=1.0):
    p = {"a": jax.random.normal(key, (8, 16)) * scale,
         "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                    "c": [jnp.ones((3,)), jnp.zeros((2, 2))]}}
    return {"params": p, "opt": init_opt_state(p), "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state(key)
    mgr.save(10, st)
    step, restored = mgr.restore_latest(st)
    assert step == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_latest_k(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state(key)
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert sorted(steps) == ["step_3", "step_4"]


def test_async_save(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    st = _state(key)
    mgr.save(5, st)
    mgr.wait()
    assert mgr.latest_step() == 5
    _, restored = mgr.restore_latest(st)
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(st["params"]["a"]))


def test_async_save_failure_surfaces(tmp_path, key):
    """A background write that dies must NOT be swallowed: the error
    re-raises on wait() — and on the next save() for loops that never
    wait — so a dead disk is caught at the next step, not at restore."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    st = _state(key)
    real_write = mgr._write

    def failing_write(*a, **k):
        raise OSError("injected: disk full")

    mgr._write = failing_write
    mgr.save(1, st)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    mgr.wait()                                   # error surfaced once: clear
    mgr._write = failing_write
    mgr.save(2, st)                              # fails in the background...
    mgr._write = real_write
    with pytest.raises(OSError, match="disk full"):
        mgr.save(3, st)                          # ...and surfaces here
    mgr.save(4, st)                              # manager still usable
    mgr.wait()
    assert mgr.latest_step() == 4
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")], \
        "failed writes left partial tmp dirs"


def test_failed_write_cleans_tmp_and_keeps_latest(tmp_path, key):
    """A write that dies mid-flight removes its tmp dir and leaves the
    previous checkpoint untouched (atomicity under failure)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    st = _state(key)
    mgr.save(1, st)
    real_savez = np.savez

    def exploding_savez(*a, **k):
        raise OSError("injected: volume gone")

    np.savez = exploding_savez
    try:
        with pytest.raises(OSError, match="volume gone"):
            mgr.save(2, st)
    finally:
        np.savez = real_savez
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
    assert mgr.latest_step() == 1
    _, restored = mgr.restore_latest(st)
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(st["params"]["a"]))


def test_restore_pytree_template_free(tmp_path):
    """Dict-only trees (scheduler snapshots) restore without a template;
    non-dict nodes are rejected with a pointer at restore()."""
    mgr = CheckpointManager(str(tmp_path))
    snap = {"format": np.int64(1),
            "requests": {"00000": {"rid": np.int64(4),
                                   "prompt": np.arange(5, dtype=np.int32)},
                         "00001": {"rid": np.int64(9),
                                   "prompt": np.arange(2, dtype=np.int32)}}}
    mgr.save(3, snap)
    out = mgr.restore_pytree(3)
    assert set(out) == {"format", "requests"}
    assert int(out["requests"]["00001"]["rid"]) == 9
    np.testing.assert_array_equal(out["requests"]["00000"]["prompt"],
                                  np.arange(5, dtype=np.int32))
    flat = mgr.restore_flat(3)
    assert "/requests/00000/rid" in flat
    mgr.save(4, {"seq": [np.ones(2), np.zeros(2)]})      # list node
    with pytest.raises(ValueError, match="template"):
        mgr.restore_pytree(4)


def test_crash_mid_save_leaves_previous_intact(tmp_path, key):
    """A stale tmp dir (simulated crash) must not shadow the good ckpt."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    st = _state(key)
    mgr.save(1, st)
    os.makedirs(os.path.join(tmp_path, "tmp.2.9999"), exist_ok=True)  # debris
    assert mgr.latest_step() == 1
    _, restored = mgr.restore_latest(st)
    assert restored is not None


def test_restore_resumes_training(tmp_path, key):
    """Kill-and-restart: restored state continues bit-identically."""
    import dataclasses
    from repro.configs.registry import get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.models import init_params
    from repro.train.loop import TrainConfig, make_train_step

    cfg = get_smoke_config("llama3_8b").reduced(
        n_layers=1, d_model=32, n_heads=1, n_kv_heads=1, head_dim=32,
        d_ff=64, vocab_size=64, dtype="float32")
    cfg = dataclasses.replace(cfg, remat=False)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    step_fn = jax.jit(make_train_step(cfg, TrainConfig()))
    params = init_params(key, cfg)
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path))

    # run 4 steps, checkpoint at 2
    ps, os_ = params, opt
    for i in range(4):
        if i == 2:
            mgr.save(i, {"params": ps, "opt": os_})
        batch = {"tokens": corpus.sample(jnp.asarray(i), 4, 17)}
        ps, os_, _ = step_fn(ps, os_, batch)

    # "restart": restore at 2, replay steps 2,3 (deterministic data by step id)
    step0, st = mgr.restore_latest({"params": params, "opt": opt})
    p2, o2 = st["params"], st["opt"]
    for i in (2, 3):
        batch = {"tokens": corpus.sample(jnp.asarray(i), 4, 17)}
        p2, o2, _ = step_fn(p2, o2, batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), ps, p2)
    assert max(jax.tree.leaves(diffs)) == 0.0
