"""Recipe API: registry resolution, golden parity with the seed PTQ
implementation, JSON round-trip, and RuntimeConfig propagation."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantConfig, aser_smoothing, awq_quantize,
                        cholesky_whitener, gptq_quantize, l2qer, lorc,
                        low_rank_factors, pack_int4, quantize_weight,
                        rank_from_alpha, smoothquant_scales, whiten_svd)
from repro.core.aser import smooth_gram
from repro.kernels import ops
from repro.kernels.ref import w4a8_linear_ref
from repro.models.layers import LinStats
from repro.quant import (ActQuantSpec, BaseQuantizer, ErrorReconstructor,
                         PTQConfig, QuantRecipe, Smoother, quantize_model,
                         registry)
from repro.quant.apply import _quantize_one
from repro.runtime import RuntimeConfig

LEGACY_METHODS = ["rtn", "llmint4", "smoothquant", "gptq", "awq",
                  "lorc", "l2qer", "aser", "aser_as"]


# ---------------------------------------------------------------------------
# Golden reference: the seed (pre-recipe) _quantize_one, copied verbatim from
# commit 2a80fd1 (string dispatch + PTQConfig). The registry-resolved recipe
# pipeline must reproduce its output leaf-for-leaf.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _SeedCfg:
    method: str = "aser_as"
    w_bits: int = 4
    rank: int = 64
    alpha: float = 0.0
    outlier_f: int = 32
    damp: float = 1e-2
    base: str = "rtn"


def _seed_recode(w_hat, wt, wq_cfg):
    qmax = wq_cfg.qmax
    sc = jnp.maximum(jnp.max(jnp.abs(wt), axis=1, keepdims=True), 1e-8) / qmax
    codes = jnp.clip(jnp.round(w_hat / sc), wq_cfg.qmin, wq_cfg.qmax)
    return codes.astype(jnp.int8), sc.astype(jnp.float32)


def _seed_base_quant(w_s, g_eff, wq_cfg, cfg: _SeedCfg):
    if cfg.base == "gptq":
        w_hat = gptq_quantize(w_s, g_eff, wq_cfg, damp=cfg.damp)
        codes, sc = _seed_recode(w_hat, w_s, wq_cfg)
        return codes, sc, codes.astype(jnp.float32) * sc
    codes, sc = quantize_weight(w_s, wq_cfg)
    return codes, sc, codes.astype(jnp.float32) * sc


def _seed_quantize_one(w, st: LinStats, cfg: _SeedCfg):
    k, n = w.shape
    wt = w.astype(jnp.float32).T
    count = jnp.maximum(st.count, 1.0)
    g = st.gram
    absmean = st.abssum / count
    wq_cfg = QuantConfig(bits=cfg.w_bits)
    m = jnp.ones((k,), jnp.float32)
    la = lb = None
    method = cfg.method

    if method in ("rtn", "llmint4"):
        codes, sc = quantize_weight(wt, wq_cfg)
    elif method == "smoothquant":
        w_absmax_in = jnp.max(jnp.abs(wt), axis=0)
        m = smoothquant_scales(st.absmax, w_absmax_in, alpha=0.5)
        codes, sc = quantize_weight(wt * m[None, :], wq_cfg)
    elif method == "gptq":
        w_hat = gptq_quantize(wt, g, wq_cfg, damp=cfg.damp)
        codes, sc = _seed_recode(w_hat, wt, wq_cfg)
    elif method == "awq":
        _, s = awq_quantize(wt, g, absmean, wq_cfg)
        m = s
        codes, sc = quantize_weight(wt * s[None, :], wq_cfg)
    elif method in ("lorc", "l2qer"):
        codes, sc = quantize_weight(wt, wq_cfg)
        w_deq = codes.astype(jnp.float32) * sc
        e_q = wt - w_deq
        r = min(cfg.rank, k, n)
        comp = (lorc(e_q, r) if method == "lorc" else l2qer(e_q, absmean, r))
        la, lb = comp.l_a, comp.l_b
    elif method.startswith("aser"):
        smooth = method == "aser_as"
        if smooth:
            sm = aser_smoothing(wt, absmean, cfg.outlier_f)
            m = sm.m
            w_s = sm.w_smooth
            extra = sm.w_outlier
            g_eff = smooth_gram(g, m)
        else:
            w_s, extra, g_eff = wt, jnp.zeros_like(wt), g
        codes, sc, w_deq = _seed_base_quant(w_s, g_eff, wq_cfg, cfg)
        e_q = (w_s - w_deq) + extra
        r = min(cfg.rank, k, n)
        s_chol = cholesky_whitener(g_eff, damp=cfg.damp)
        u, sig, vt = whiten_svd(e_q, s_chol)
        if cfg.alpha > 0:
            r_sel = jnp.minimum(rank_from_alpha(sig, cfg.alpha), r)
            la_f, lb_f = low_rank_factors(u, sig, vt, s_chol, r)
            keepm = (jnp.arange(r) < r_sel).astype(jnp.float32)
            la, lb = la_f * keepm[None, :], lb_f * keepm[:, None]
        else:
            la, lb = low_rank_factors(u, sig, vt, s_chol, r)
    else:
        raise ValueError(method)

    if la is None:
        lb_m = jnp.zeros((k, 0), jnp.float32)
        la_m = jnp.zeros((0, n), jnp.float32)
    else:
        lb_m, la_m = lb.T, la.T
    qw = pack_int4(codes).T if cfg.w_bits == 4 else codes.T
    return {"qw": qw.astype(jnp.int8), "sw": sc[:, 0].astype(jnp.float32),
            "m": m.astype(jnp.float32), "lb": lb_m.astype(jnp.float32),
            "la": la_m.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Fixtures: a synthetic linear layer + calibration stats with outliers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def leaf_data():
    rng = np.random.default_rng(7)
    k, n, t = 64, 48, 512
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)) * 0.1
    x = rng.normal(size=(t, k)).astype(np.float32)
    x[:, rng.choice(k, 4, replace=False)] *= 12.0      # activation outliers
    xj = jnp.asarray(x)
    st = LinStats(xj.T @ xj, jnp.sum(jnp.abs(xj), axis=0),
                  jnp.max(jnp.abs(xj), axis=0),
                  jnp.asarray(float(t), jnp.float32))
    return w, st


def _assert_leaves_equal(got, want, method):
    assert set(got) == set(want), method
    for key in want:
        np.testing.assert_allclose(
            np.asarray(got[key], np.float32), np.asarray(want[key], np.float32),
            rtol=1e-6, atol=1e-6, err_msg=f"{method}/{key}")


# ---------------------------------------------------------------------------
# Golden parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", LEGACY_METHODS)
def test_registry_parity_with_seed(leaf_data, method):
    """Registry-resolved recipes reproduce the seed implementation
    leaf-for-leaf for every legacy method string."""
    w, st = leaf_data
    seed = _seed_quantize_one(w, st, _SeedCfg(method=method, rank=8,
                                              outlier_f=8))
    recipe = registry.resolve(method, rank=8, outlier_f=8)
    got = _quantize_one(w, st, recipe)
    _assert_leaves_equal(got, seed, method)


def test_parity_aser_base_gptq(leaf_data):
    w, st = leaf_data
    seed = _seed_quantize_one(w, st, _SeedCfg(method="aser", base="gptq",
                                              rank=8))
    got = _quantize_one(w, st, registry.resolve("aser(base=gptq)", rank=8))
    _assert_leaves_equal(got, seed, "aser(base=gptq)")


def test_parity_adaptive_rank(leaf_data):
    w, st = leaf_data
    seed = _seed_quantize_one(w, st, _SeedCfg(method="aser_as", rank=16,
                                              alpha=0.3, outlier_f=8))
    got = _quantize_one(w, st, registry.resolve("aser_as", rank=16,
                                                alpha=0.3, outlier_f=8))
    _assert_leaves_equal(got, seed, "aser_as(alpha)")


def test_ptqconfig_shim_matches_recipe(leaf_data):
    """The deprecated PTQConfig path goes through the same pipeline."""
    w, st = leaf_data
    cfg = PTQConfig(method="aser_as", rank=8, outlier_f=8)
    got = _quantize_one(w, st, cfg.to_recipe())
    want = _quantize_one(w, st, registry.resolve("aser_as", rank=8,
                                                 outlier_f=8))
    _assert_leaves_equal(got, want, "ptqconfig-shim")


# ---------------------------------------------------------------------------
# Registry + recipe construction semantics
# ---------------------------------------------------------------------------

def test_registry_has_all_legacy_names():
    names = set(registry.available())
    assert set(LEGACY_METHODS + ["fp16"]) <= names


def test_string_override_syntax():
    r = registry.resolve("aser(base=gptq, rank=32)")
    assert r.base.kind == "gptq" and r.reconstructor.rank == 32
    with pytest.raises(ValueError):        # same override twice
        registry.resolve("aser(rank=8)", rank=16)
    with pytest.raises(ValueError):
        registry.resolve("no_such_method")


def test_mistyped_overrides_raise():
    """Typo'd override keys must not be silently swallowed."""
    with pytest.raises(ValueError, match="rnk"):
        registry.resolve("aser", rnk=8)
    with pytest.raises(ValueError, match="w_bit"):
        registry.resolve("aser(w_bit=8)")
    # irrelevant-but-recognized keys are still tolerated (PTQConfig-style
    # sweeps across heterogeneous methods)
    assert registry.resolve("rtn", rank=8, outlier_f=4).name == "rtn"
    # overrides on an already-resolved spec raise instead of being dropped
    with pytest.raises(ValueError):
        registry.resolve(PTQConfig(method="aser"), rank=8)


def test_quantize_one_rejects_noop_recipe(leaf_data):
    w, st = leaf_data
    with pytest.raises(ValueError, match="noop"):
        _quantize_one(w, st, registry.resolve("fp16"))


def test_unsupported_combos_raise_at_construction():
    with pytest.raises(ValueError):        # dead seed branch, now explicit
        registry.resolve("aser", base="awq")
    with pytest.raises(ValueError):
        registry.resolve("aser_as", base="awq")
    with pytest.raises(ValueError):        # outlier weight would be dropped
        QuantRecipe(smoother=Smoother("aser-outlier"),
                    reconstructor=ErrorReconstructor("none"))
    with pytest.raises(ValueError):        # fp passthrough composes nothing
        QuantRecipe(base=BaseQuantizer("none"),
                    reconstructor=ErrorReconstructor("lorc"))
    with pytest.raises(ValueError):
        Smoother("totally-new-kind")
    with pytest.raises(ValueError):
        ActQuantSpec(bits=5)


def test_new_combination_composes(leaf_data):
    """Stage composition the string API never offered: awq-scale smoothing
    under GPTQ with whitened-SVD reconstruction."""
    w, st = leaf_data
    recipe = QuantRecipe(
        smoother=Smoother("awq-scale"),
        base=BaseQuantizer("gptq"),
        reconstructor=ErrorReconstructor("whitened-svd", rank=8))
    leaf = _quantize_one(w, st, recipe)
    assert leaf["lb"].shape[1] == 8
    assert not bool(jnp.all(leaf["m"] == 1.0))      # smoothing engaged
    for v in leaf.values():
        assert bool(jnp.all(jnp.isfinite(v.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

def test_recipe_json_round_trip_identity():
    r = registry.resolve("aser_as", rank=24, alpha=0.1, outlier_f=16)
    r2 = QuantRecipe.from_dict(json.loads(json.dumps(r.to_dict())))
    assert r2 == r


def test_round_tripped_recipe_quantizes_identically(leaf_data):
    w, st = leaf_data
    recipe = registry.resolve("aser_as", rank=8, outlier_f=8)
    recipe2 = QuantRecipe.from_json(recipe.to_json())
    a = _quantize_one(w, st, recipe)
    b = _quantize_one(w, st, recipe2)
    for key in a:
        assert bool(jnp.all(a[key] == b[key])), key


def test_from_dict_rejects_unknown_version():
    d = registry.resolve("rtn").to_dict()
    d["format_version"] = 99
    with pytest.raises(ValueError):
        QuantRecipe.from_dict(d)


# ---------------------------------------------------------------------------
# RuntimeConfig propagation
# ---------------------------------------------------------------------------

def test_runtime_config_act_bits_through_w4a8_linear(leaf_data, rng):
    """rt.a_bits reaches the kernel: explicit rt == explicit a_bits= ==
    reference at each bit-width, and differs across bit-widths."""
    w, st = leaf_data
    leaf = _quantize_one(w, st, registry.resolve("aser_as", rank=8,
                                                 outlier_f=8))
    x = jnp.asarray(rng.normal(size=(16, w.shape[0])).astype(np.float32))
    args = (x, leaf["qw"], leaf["sw"], leaf["m"], leaf["lb"], leaf["la"])
    outs = {}
    for bits in (8, 6):
        y_rt = ops.w4a8_linear(*args, rt=RuntimeConfig(a_bits=bits))
        y_ref = w4a8_linear_ref(*args, a_bits=bits)
        np.testing.assert_allclose(np.asarray(y_rt), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        outs[bits] = np.asarray(y_rt)
    assert not np.allclose(outs[8], outs[6])
    # >=16 = weight-only path: no activation quantization at all
    outs[16] = np.asarray(ops.w4a8_linear(*args, rt=RuntimeConfig(a_bits=16)))
    assert np.all(np.isfinite(outs[16]))
    assert not np.allclose(outs[16], outs[8])


def test_runtime_config_per_tensor_granularity(leaf_data, rng):
    w, st = leaf_data
    leaf = _quantize_one(w, st, registry.resolve("rtn"))
    x = jnp.asarray(rng.normal(size=(16, w.shape[0])).astype(np.float32))
    args = (x, leaf["qw"], leaf["sw"], leaf["m"], leaf["lb"], leaf["la"])
    y_tok = ops.w4a8_linear(*args, rt=RuntimeConfig(a_bits=8))
    y_ten = ops.w4a8_linear(
        *args, rt=RuntimeConfig(a_bits=8, act_granularity="per_tensor"))
    assert y_tok.shape == y_ten.shape
    assert not np.allclose(np.asarray(y_tok), np.asarray(y_ten))


def test_runtime_config_threads_through_forward():
    """forward(rt=...) steers the quantized path: a_bits=6 differs from the
    default, and rt=None means exactly DEFAULT_RUNTIME."""
    from repro.configs.registry import get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.models import forward, init_params
    from repro.quant import calibrate, reduce_shared
    from repro.runtime import DEFAULT_RUNTIME

    cfg = dataclasses.replace(get_smoke_config("llama3_8b"), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(1, 2, 16)), cfg)
    qp = quantize_model(params, tape, registry.resolve("aser_as", rank=8,
                                                       outlier_f=8))
    toks = corpus.sample(jnp.asarray(5), 2, 16)
    lg_a6, _, _ = forward(qp, cfg, toks, rt=RuntimeConfig(a_bits=6))
    lg_default, _, _ = forward(qp, cfg, toks)
    lg_explicit, _, _ = forward(qp, cfg, toks, rt=DEFAULT_RUNTIME)
    np.testing.assert_array_equal(np.asarray(lg_default),
                                  np.asarray(lg_explicit))
    assert not np.allclose(np.asarray(lg_a6), np.asarray(lg_default))


def test_global_shims_are_gone():
    """PR 1 kept ops.set_act_bits / ops.use_pallas "one release"; that
    release shipped — mutating process state is no longer possible."""
    assert not hasattr(ops, "set_act_bits")
    assert not hasattr(ops, "use_pallas")
    from repro.runtime import DEFAULT_RUNTIME
    assert ops.default_runtime() == DEFAULT_RUNTIME


def test_fp16_recipe_is_noop(leaf_data):
    recipe = registry.resolve("fp16")
    assert recipe.is_noop
    params = {"groups": []}
    assert quantize_model(params, {}, recipe) is params
