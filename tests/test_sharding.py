"""Sharding rules + an 8-device subprocess integration test."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch.specs import params_template, quantized_template
from repro.sharding import rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


from repro.launch.mesh import _mesh as _make_mesh  # version-robust make_mesh


def test_param_shardings_cover_tree():
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    mesh = _make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("llama3_8b")
    p_sds = params_template(cfg)
    sh = rules.param_shardings(p_sds, mesh)
    assert jax.tree.structure(
        jax.tree.map(lambda x: 0, p_sds)) == jax.tree.structure(
        jax.tree.map(lambda x: 0, sh,
                     is_leaf=lambda x: isinstance(x, NamedSharding)))


def test_quantized_template_structure():
    cfg = get_smoke_config("moonshot_v1_16b")
    q = quantized_template(params_template(cfg))
    # attention leaves quantized
    blk = q["groups"][0]
    assert "qw" in blk["attn"]["wq"]
    assert "qw" in blk["moe"]["experts"]["gate"]
    # router and head stay fp
    assert "w" in blk["moe"]["router"]
    assert "w" in q["head"]


def test_cache_spec_odd_dims_degrade_to_replicated():
    """Every cache branch (k/v, conv, state) runs through the same
    first-fit + sanitize path: a dim the model axis doesn't divide must
    degrade to replicated, never emit an invalid sharding. Regression for
    the conv/state branches, which used to place the model axis without a
    divisibility check."""
    from jax.sharding import PartitionSpec as P
    sizes = {"data": 1, "model": 2}

    # SSM conv cache [b, k-1, conv_dim]: odd conv_dim ⇒ no model axis
    assert rules.cache_spec("/groups/c/0/conv", (4, 3, 5), sizes) == \
        P(("data",), None, None)
    assert rules.cache_spec("/groups/c/0/conv", (4, 3, 6), sizes) == \
        P(("data",), None, "model")

    # SSM state cache [b, nh, hd, ds]: odd head count ⇒ replicated heads
    assert rules.cache_spec("/groups/c/0/state", (4, 3, 8, 16), sizes) == \
        P(("data",), None, None, None)
    assert rules.cache_spec("/groups/c/0/state", (4, 4, 8, 16), sizes) == \
        P(("data",), "model", None, None)

    # KV cache [b, L, n_kv, hd]: heads → head_dim → cache_len fallback chain
    assert rules.cache_spec("/groups/c/0/k", (4, 16, 2, 8), sizes) == \
        P(("data",), None, "model", None)
    assert rules.cache_spec("/groups/c/0/k", (4, 16, 3, 8), sizes) == \
        P(("data",), None, None, "model")
    assert rules.cache_spec("/groups/c/0/v", (4, 16, 3, 7), sizes) == \
        P(("data",), "model", None, None)
    assert rules.cache_spec("/groups/c/0/v", (4, 15, 3, 7), sizes) == \
        P(("data",), None, None, None)

    # seq_to_data moves cache_len to data and drops batch
    assert rules.cache_spec("/groups/c/0/k", (4, 16, 2, 8), sizes,
                            seq_to_data=True) == \
        P(None, "data", "model", None)


def test_cache_shardings_odd_conv_dim_end_to_end():
    """cache_shardings over a real SSM cache tree with an odd conv_dim
    builds a valid NamedSharding for every leaf."""
    from jax.sharding import NamedSharding
    from repro.models import init_caches
    mesh = _make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("mamba2_780m").reduced(d_model=32, n_layers=2)
    caches = jax.eval_shape(lambda: init_caches(cfg, 2, 16))
    sh = rules.cache_shardings(caches, mesh)
    leaves = jax.tree.leaves(sh,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)


@pytest.mark.slow
def test_multi_device_train_step():
    """Real 8-device SPMD train step executes (not just lowers)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.models import init_params
        from repro.sharding import rules
        from repro.train.loop import TrainConfig, make_train_step
        from repro.train.optimizer import init_opt_state
        from repro.launch.mesh import _mesh

        mesh = _mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("llama3_8b").reduced(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
            d_ff=128, vocab_size=128, dtype="float32")
        cfg = dataclasses.replace(cfg, remat=False)
        params = init_params(jax.random.PRNGKey(0), cfg)
        p_sh = rules.param_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt = init_opt_state(params)
        opt = jax.device_put(opt, rules.opt_shardings(opt, p_sh))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, 128)
        batch = {"tokens": jax.device_put(tokens, rules.data_sharding(mesh, 2))}
        step = make_train_step(cfg, TrainConfig())
        with mesh:
            step_j = jax.jit(step)
            p2, o2, m = step_j(params, opt, batch)
        assert jnp.isfinite(m["loss"]), m
        # single-device reference matches
        p1, _, m1 = jax.jit(make_train_step(cfg, TrainConfig()))(
            jax.device_get(params), jax.device_get(opt),
            {"tokens": tokens})
        import numpy as np
        np.testing.assert_allclose(float(m["loss"]), float(m1["loss"]),
                                   rtol=2e-4)
        print("MULTIDEV_OK", float(m["loss"]))
    """)
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_multi_device_quantized_serve():
    """8-device quantized decode executes with EP/TP shardings."""
    if not hasattr(jax.sharding, "AxisType"):
        # without Auto axis types old jax propagates different layouts
        # through the quantized forward and the allclose check diverges;
        # the sharded==global equivalence is only meaningful with them
        pytest.skip("requires jax.sharding.AxisType (Auto axis sharding)")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro.launch.mesh import _mesh
        from repro.configs.registry import get_smoke_config
        from repro.models import init_params, init_caches, forward
        from repro.quant import PTQConfig, calibrate, quantize_model
        from repro.data.synthetic import SyntheticCorpus, CorpusConfig
        from repro.sharding import rules

        mesh = _mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_smoke_config("llama3_8b").reduced(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                d_ff=128, vocab_size=128), dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg)
        corpus = SyntheticCorpus(CorpusConfig(vocab_size=128))
        tape = calibrate(params, cfg, corpus.calibration_batches(1, 2, 16))
        qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=8,
                                                    outlier_f=8))
        ref, _, _ = forward(qp, cfg, corpus.sample(jnp.asarray(3), 4, 8))
        q_sh = rules.param_shardings(qp, mesh)
        qp_d = jax.device_put(qp, q_sh)
        toks = corpus.sample(jnp.asarray(3), 4, 8)
        with mesh:
            lg, _, _ = jax.jit(lambda p, t: forward(p, cfg, t))(qp_d, toks)
        import numpy as np
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        print("QSERVE_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert "QSERVE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_moe_shard_map_matches_global():
    """EP shard_map dispatch == portable global dispatch (8 devices)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro.launch.mesh import _mesh
        from repro.configs.registry import get_smoke_config
        from repro.models import init_params, forward
        from repro.sharding import rules
        mesh = _mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("moonshot_v1_16b"),
                                  dtype="float32", capacity_factor=64.0)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        ref, _, _ = forward(params, cfg, toks)
        params_d = jax.device_put(params, rules.param_shardings(params, mesh))
        with mesh:
            lg, _, _ = jax.jit(lambda p, t: forward(p, cfg, t))(params_d, toks)
        diff = float(jnp.max(jnp.abs(lg - ref)))
        assert diff < 2e-4, diff
        print("EP_OK", diff)
    """)
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert "EP_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launch_train_driver():
    """The distributed train driver runs end-to-end on a 4-device mesh."""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmo_1b",
         "--smoke", "--steps", "4", "--batch", "4", "--seq", "32",
         "--data-par", "2", "--model-par", "2"],
        capture_output=True, text=True, env=env, timeout=900)
    assert "[train] done" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launch_serve_driver():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "llama3_8b",
         "--smoke", "--method", "aser_as", "--requests", "2", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=900)
    assert "generations" in r.stdout, r.stdout + r.stderr


def test_adapter_pool_specs_mirror_base_lowrank():
    """alb/ala follow lb/la (model axis only with shard_lr, on the k / n
    dim respectively) with the pool-slot axis always replicated, and
    param_shardings covers a pooled quantized tree leaf-for-leaf."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _make_mesh((1, 1), ("data", "model"))

    def spec(path, ndim, shard_lr):
        return rules._spec_for_path(path, ndim, mesh, shard_lr)

    # wq is column-sharded (out dim n → ala), wo row-sharded (in dim
    # k → alb); the opposite factor and the pool-slot axis stay replicated
    assert spec("/groups/0/attn/wq/alb", 3, True) == P(None, None, None)
    assert spec("/groups/0/attn/wq/ala", 3, True) == P(None, None, "model")
    assert spec("/groups/0/attn/wo/alb", 3, True) == P(None, "model", None)
    assert spec("/groups/0/attn/wo/ala", 3, True) == P(None, None, None)
    # shard_lr off ⇒ fully replicated, like lb/la
    assert spec("/groups/0/attn/wq/ala", 3, False) == P(None, None, None)
    assert spec("/groups/0/attn/wo/alb", 3, False) == P(None, None, None)
    # scanned stacks add leading replicated dims
    assert spec("/groups/0/attn/wo/alb", 4, True) == \
        P(None, None, "model", None)

    # end to end: install pools on a quantized template and shard it
    import jax.numpy as jnp
    from repro.serve.adapters import install_pools
    cfg = get_smoke_config("llama3_8b")
    q_sds = quantized_template(params_template(cfg))
    q = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), q_sds)
    pooled = install_pools(q, slots=3, rank=8)
    sh = rules.param_shardings(pooled, mesh)
    assert jax.tree.structure(
        jax.tree.map(lambda x: 0, pooled)) == jax.tree.structure(
        jax.tree.map(lambda x: 0, sh,
                     is_leaf=lambda x: isinstance(x, NamedSharding)))
