"""Tests for the measured autotune cache (``repro.kernels.autotune``).

Covers the cache store (round-trip persistence, the degrade-to-empty
failure modes: stale version, wrong backend, corrupt JSON), the mode
contract (``autotune="off"`` reproduces the modeled decisions
bit-for-bit; ``"cache"`` consults measured winners), the KC001-style
entry validation the contract checker's KC005 cache mode shares, the
demotion tombstones serve_bench's routed-vs-displaced assertion writes,
the prepared decode plan (augmented-GEMM math and the engine hook), and
the two routing bugfixes shipped with the cache:

* ``select_gemm_blocks`` honored a ``GEMM_BLOCK_TABLE``/cache hit without
  checking the *caller's* budget — an entry recorded under the default
  8 MiB budget leaked through a reduced one.
* ``w4a8_fused`` re-derived ``bn`` from the default budget instead of
  taking the router's tile — the tile ``ops`` selected (and the contract
  checker validated) was not the tile the kernel ran.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import W4, pack_int4, quantize_weight
from repro.kernels import autotune, tuning, w4a8_fused
from repro.kernels import ref as kref


@pytest.fixture
def cache_tmp(tmp_path, monkeypatch):
    """Isolate the cache: fresh dir, no checked-in baseline, no singleton."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE_DIR", str(tmp_path))
    monkeypatch.setattr(autotune, "_BASELINE", tmp_path / "no_baseline.json")
    autotune.reset()
    yield tmp_path
    autotune.reset()


def _quant_leaf(rng, m, k, n, r):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    codes, sw = quantize_weight(w, W4)
    qw = pack_int4(codes).T
    mdiag = jnp.asarray(rng.uniform(0.5, 2.0, size=(k,)).astype(np.float32))
    lb = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32) * 0.02)
    la = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32) * 0.02)
    return x, qw, sw[:, 0], mdiag, lb, la


# -- cache store ------------------------------------------------------------

def test_cache_round_trip(cache_tmp):
    key = autotune.gemm_key(128, 2048, 2048, 64)
    cache = autotune.AutotuneCache("cpu")
    assert cache._loaded_from == "empty"
    cache.put(key, (128, 512, 1024), 12.5)
    path = cache.save()
    assert path == autotune.cache_path("cpu")

    reloaded = autotune.AutotuneCache("cpu")
    assert reloaded._loaded_from == "user"
    assert tuple(reloaded.lookup(key)) == (128, 512, 1024)
    assert reloaded.get(key)["source"] == "measured"


def test_put_refuses_off_lattice_entry(cache_tmp):
    cache = autotune.AutotuneCache("cpu")
    with pytest.raises(ValueError, match="lattice"):
        cache.put(autotune.gemm_key(128, 2048, 2048, 64), (100, 512, 1024),
                  1.0)


@pytest.mark.parametrize("payload", [
    '{"version": 999, "backend": "cpu", "entries": {}}',   # stale version
    '{"version": 1, "backend": "tpu", "entries": {}}',     # wrong backend
    '{"version": 1, "backend": "cpu"',                     # corrupt JSON
    '[1, 2, 3]',                                           # wrong shape
])
def test_bad_cache_file_degrades_to_empty(cache_tmp, payload):
    autotune.cache_path("cpu").parent.mkdir(parents=True, exist_ok=True)
    autotune.cache_path("cpu").write_text(payload)
    cache = autotune.AutotuneCache("cpu")   # must not raise
    assert cache._loaded_from == "empty"
    assert cache.entries == {}
    assert cache.lookup(autotune.gemm_key(128, 2048, 2048, 64)) is None


def test_demote_tombstones_entry(cache_tmp):
    key = autotune.decode_plan_key(8, 256, 512, 64, 4)
    cache = autotune.AutotuneCache("cpu")
    cache.put(key, "prepared", 100.0)
    assert cache.lookup(key) == "prepared"
    assert cache.demote(key, "slower than displaced path")
    assert cache.lookup(key) is None                  # consults skip it
    cache.save()
    reloaded = autotune.AutotuneCache("cpu")
    assert reloaded.get(key)["disabled"] is True      # tombstone persists
    assert reloaded.lookup(key) is None
    assert not cache.demote("decode_plan|m1|d8|ff8|r1|L1")   # unknown key


def test_lookup_skips_invalid_entry(cache_tmp):
    key = autotune.gemm_key(128, 2048, 2048, 64)
    cache = autotune.AutotuneCache("cpu")
    cache.entries[key] = {"choice": [100, 100, 100], "us": 1.0,
                          "source": "measured"}       # bypasses put()
    assert cache.lookup(key) is None


# -- entry validation (KC005's cache mode) ----------------------------------

def test_validate_entry_accepts_lattice_choices():
    ok = [
        (autotune.gemm_key(128, 2048, 2048, 64),
         {"choice": [128, 512, 1024]}),
        (autotune.fused_key(1, 2048, 2048, 64), {"choice": 2048}),
        (autotune.fused_tiles_key(64, 2048, 2048, 64),
         {"choice": [64, 512]}),     # bm clamped to m is still == lattice∩m
        (autotune.decode_plan_key(8, 256, 512, 64, 4),
         {"choice": "prepared"}),
        (autotune.paged_key(16, 2, 64, False), {"choice": False}),
    ]
    for key, entry in ok:
        assert autotune.validate_entry(key, entry) is None, key


def test_validate_entry_rejects_bad_choices():
    bad = [
        (autotune.gemm_key(128, 2048, 2048, 64), {"choice": [100, 512, 512]}),
        (autotune.gemm_key(128, 2048, 2048, 64), {"choice": [128, 512]}),
        (autotune.fused_key(1, 2048, 2048, 64), {"choice": 100}),
        (autotune.decode_plan_key(8, 256, 512, 64, 4), {"choice": "magic"}),
        ("warp_drive|m1|k2|n3|r4", {"choice": 1}),
        ("w4a8_gemm|mX|k2|n3|r4", {"choice": [128, 256, 512]}),
    ]
    for key, entry in bad:
        assert autotune.validate_entry(key, entry) is not None, key


def test_validate_entry_enforces_budget():
    key = autotune.gemm_key(512, 2048, 8192, 64)
    entry = {"choice": [512, 512, 1024]}
    assert autotune.validate_entry(key, entry) is None
    small = tuning.vmem_bytes(512, 512, 1024, 64) - 1
    assert "over budget" in autotune.validate_entry(key, entry, small)


def test_kc005_flags_invalid_cache_entry(cache_tmp):
    from repro.analysis import contracts
    cache = autotune.AutotuneCache()
    cache.put(autotune.fused_key(1, 2048, 2048, 64), 2048, 10.0)
    cache.save()
    assert contracts.check_autotune_cache() == []

    cache.entries[autotune.gemm_key(128, 2048, 2048, 64)] = \
        {"choice": [100, 100, 100], "us": 1.0, "source": "measured"}
    cache.save()
    findings = contracts.check_autotune_cache()
    assert len(findings) == 1
    assert findings[0].rule == "KC005"
    assert "lattice" in findings[0].message


# -- mode contract ----------------------------------------------------------

def test_off_mode_is_bit_for_bit_modeled(cache_tmp):
    """A populated cache must not perturb ``autotune="off"`` decisions."""
    shape = (128, 2048, 2048, 64)
    tuning.select_gemm_blocks.cache_clear()
    modeled = tuning.select_gemm_blocks(*shape)

    cache = autotune.get_cache()
    cache.put(autotune.gemm_key(*shape), (256, 256, 512), 1.0)
    cache.put(autotune.fused_key(1, 2048, 2048, 64), 128, 1.0)
    tuning.select_gemm_blocks.cache_clear()

    assert tuning.select_gemm_blocks(*shape, autotune="off") == modeled
    assert tuning.select_gemm_blocks(*shape) == modeled     # default is off
    assert tuning.fused_bn(1, 2048, 2048, 64, autotune="off") == \
        tuning.fused_bn(1, 2048, 2048, 64)


def test_cache_mode_prefers_measured_winner(cache_tmp):
    shape = (128, 2048, 2048, 64)
    cache = autotune.get_cache()
    cache.put(autotune.gemm_key(*shape), (256, 256, 512), 1.0)
    cache.put(autotune.fused_key(1, 2048, 2048, 64), 128, 1.0)
    tuning.select_gemm_blocks.cache_clear()
    # bm clamps to m=128; bn/bk ride through as cached
    assert tuning.select_gemm_blocks(*shape, autotune="cache") == \
        (128, 256, 512)
    assert tuning.fused_bn(1, 2048, 2048, 64, autotune="cache") == 128
    # off still modeled after the cache consult warmed the lru
    tuning.select_gemm_blocks.cache_clear()
    assert tuning.select_gemm_blocks(*shape, autotune="off") == \
        tuning.select_gemm_blocks(*shape)


def test_paged_verdict_trusts_lose_not_win(cache_tmp):
    cache = autotune.get_cache()
    cache.put(autotune.paged_key(16, 2, 64, False), False, 1.0)
    assert tuning.use_paged_kernel(4, 8, 16, 2, 64)           # modeled: fits
    assert not tuning.use_paged_kernel(4, 8, 16, 2, 64, autotune="cache")
    # a measured "win" cannot override a budget the modeled check rejects
    cache.put(autotune.paged_key(16, 2, 64, True), True, 1.0)
    tiny = 16
    assert not tuning.use_paged_kernel(4, 8, 16, 2, 64, budget=tiny,
                                       quantized=True, autotune="cache")


# -- bugfix 1: budget-blind table/cache hits --------------------------------

def test_select_gemm_blocks_respects_shrunken_budget():
    """Regression: the GEMM_BLOCK_TABLE hit for this shape overshoots a
    reduced budget and used to be returned anyway."""
    shape = (512, 2048, 8192, 64)
    assert (tuning._m_bucket(shape[0]),) + shape[1:] in \
        tuning.GEMM_BLOCK_TABLE
    table = tuning.GEMM_BLOCK_TABLE[(tuning._m_bucket(shape[0]),)
                                    + shape[1:]]
    small = tuning.vmem_bytes(*[min(t, s) for t, s in
                                zip(table, (shape[0], shape[2],
                                            shape[1]))], shape[3]) - 1
    tuning.select_gemm_blocks.cache_clear()
    bm, bn, bk = tuning.select_gemm_blocks(*shape, budget=small)
    assert tuning.vmem_bytes(min(bm, shape[0]), min(bn, shape[2]),
                             min(bk, shape[1]), shape[3]) <= small


def test_cached_gemm_hit_respects_shrunken_budget(cache_tmp):
    shape = (128, 2048, 2048, 64)
    cache = autotune.get_cache()
    cache.put(autotune.gemm_key(*shape), (128, 512, 1024), 1.0)
    small = tuning.vmem_bytes(128, 512, 1024, 64) - 1
    tuning.select_gemm_blocks.cache_clear()
    bm, bn, bk = tuning.select_gemm_blocks(*shape, budget=small,
                                           autotune="cache")
    assert tuning.vmem_bytes(min(bm, 128), min(bn, 2048),
                             min(bk, 2048), 64) <= small


# -- bugfix 2: the router's bn reaches the kernel ---------------------------

def test_ops_threads_router_bn_to_fused_kernel(cache_tmp, rng, monkeypatch):
    """Regression: ``ops.w4a8_linear`` gated on ``use_fused_decode`` but
    called the fused kernel WITHOUT the router's bn — the kernel
    re-derived it under the default budget, silently discarding a
    measured winner (pre-fix the call site passed no ``bn`` at all)."""
    from repro.kernels import ops
    from repro.runtime import RuntimeConfig
    x, qw, sw, mdiag, lb, la = _quant_leaf(rng, 4, 256, 512, 16)
    r_pad = ops.pad_lowrank(lb, la)[0].shape[1]
    cache = autotune.get_cache()
    cache.put(autotune.fused_key(4, 256, 512, r_pad), 128, 1.0)

    seen = {}
    real = ops._w4a8_fused_kernel

    def spy(*a, **kw):
        seen["bn"] = kw.get("bn")
        return real(*a, **kw)

    monkeypatch.setattr(ops, "_w4a8_fused_kernel", spy)
    rt = RuntimeConfig(use_pallas=True, autotune="cache")
    y = ops.w4a8_linear(x, qw, sw, mdiag, lb, la, rt=rt)
    assert seen.get("bn") == 128, \
        f"router tile not threaded to the kernel (saw {seen.get('bn')!r})"
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_w4a8_fused_honors_explicit_bn(rng):
    x, qw, sw, mdiag, lb, la = _quant_leaf(rng, 4, 256, 512, 16)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    for bn in (128, 256, 512):
        y = w4a8_fused(x, mdiag, qw, sw, lb, la, bn=bn)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3, err_msg=f"bn={bn}")


def test_w4a8_fused_tiled_m_matches_single_slab(rng):
    """The prefill-m (bm-tiled) variant computes what the one-slab kernel
    and the reference chain compute."""
    x, qw, sw, mdiag, lb, la = _quant_leaf(rng, 64, 256, 512, 16)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    y_slab = w4a8_fused(x, mdiag, qw, sw, lb, la, bn=256)
    for bm in (16, 32, 64):
        y = w4a8_fused(x, mdiag, qw, sw, lb, la, bn=256, bm=bm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_slab),
                                   rtol=1e-5, atol=1e-5, err_msg=f"bm={bm}")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3, err_msg=f"bm={bm}")


# -- the prepared decode plan -----------------------------------------------

def test_aug_linear_matches_reference(rng):
    x, qw, sw, mdiag, lb, la = _quant_leaf(rng, 4, 256, 512, 16)
    leaf = autotune.prepare_leaf({"qw": qw, "sw": sw, "m": mdiag,
                                  "lb": lb, "la": la})
    assert leaf["waug"].shape == (256 + 16, 512)
    assert leaf["blb"].shape == (256, 16)
    assert "qw" in leaf                       # originals kept for fallbacks
    y = autotune._aug_linear(x, leaf["waug"], leaf["blb"], mdiag)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_prepare_leaf_skips_adapter_leaves(rng):
    _, qw, sw, mdiag, lb, la = _quant_leaf(rng, 1, 64, 64, 8)
    leaf = {"qw": qw, "sw": sw, "m": mdiag, "lb": lb, "la": la,
            "alb": jnp.zeros((2, 64, 4))}
    assert autotune.prepare_leaf(leaf) is leaf    # pinned-reduction path


def test_prepare_params_unstacks_groups(rng):
    from repro.models.model import LayerList
    k, n, r, L = 64, 64, 8, 3
    stacked = {
        "qw": jnp.zeros((L, k // 2, n), jnp.int8),
        "sw": jnp.ones((L, n)), "m": jnp.ones((L, k)),
        "lb": jnp.zeros((L, k, r)), "la": jnp.zeros((L, r, n)),
    }
    params = {"groups": {"attn": stacked}, "emb": jnp.zeros((4, k))}
    out = autotune.prepare_params(params)
    assert isinstance(out["groups"], LayerList)
    assert len(out["groups"]) == L
    assert out["groups"][0]["attn"]["waug"].shape == (k + r, n)
    assert out["groups"][0]["attn"]["qw"].shape == (k // 2, n)
    # idempotent: preparing prepared params is a no-op shape-wise
    again = autotune.prepare_params(out)
    assert isinstance(again["groups"], LayerList)
    assert len(again["groups"]) == L
    # fp trees come back unchanged
    fp = {"groups": {"attn": {"w": jnp.zeros((L, k, n))}}}
    assert autotune.prepare_params(fp) is fp


# -- engine hook ------------------------------------------------------------

def _tiny_quant_model():
    import dataclasses
    from repro.configs.registry import get_smoke_config
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.models import init_params
    from repro.quant import calibrate, quantize_model, reduce_shared
    cfg = dataclasses.replace(
        get_smoke_config("llama3_8b").reduced(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
            d_ff=128, vocab_size=128, dtype="float32"), remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(1, 2, 16)), cfg)
    return cfg, quantize_model(params, tape, "aser_as")


@pytest.mark.slow
def test_engine_force_measures_persists_and_stays_token_exact(cache_tmp):
    from repro.runtime import RuntimeConfig
    from repro.serve.engine import Engine, ServeConfig
    cfg, qparams = _tiny_quant_model()
    scfg = ServeConfig(max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size)

    eng_off = Engine(qparams, cfg, scfg, rt=RuntimeConfig(use_pallas=False))
    assert eng_off.decode_plan == "default"
    out_off = np.asarray(eng_off.generate(prompts, 8))

    rt = RuntimeConfig(use_pallas=False, autotune="force")
    eng = Engine(qparams, cfg, scfg, rt=rt)
    key = autotune.engine_plan_key(qparams, cfg, scfg)
    assert key is not None and key.startswith("decode_plan|m8|d64|ff128|")
    # force measured and persisted a winner for this engine's key
    assert autotune.cache_path().exists()
    entry = autotune.AutotuneCache().get(key)
    assert entry is not None
    assert autotune.validate_entry(key, entry) is None
    assert eng.decode_plan == entry["choice"]
    # whichever plan won, decoded tokens are identical to the off path
    np.testing.assert_array_equal(np.asarray(eng.generate(prompts, 8)),
                                  out_off)

    # demotion flips the cache-mode engine back to the modeled plan
    cache = autotune.get_cache()
    cache.demote(key, "test demotion")
    eng2 = Engine(qparams, cfg, scfg,
                  rt=RuntimeConfig(use_pallas=False, autotune="cache"))
    assert eng2.decode_plan == "default"


@pytest.mark.slow
def test_engine_cache_mode_misses_quietly(cache_tmp):
    from repro.runtime import RuntimeConfig
    from repro.serve.engine import Engine, ServeConfig
    cfg, qparams = _tiny_quant_model()
    eng = Engine(qparams, cfg, ServeConfig(max_len=32),
                 rt=RuntimeConfig(use_pallas=False, autotune="cache"))
    assert eng.decode_plan == "default"       # miss → modeled routing
    assert not autotune.cache_path().exists()  # cache mode never measures
