"""Unit tests for the ``serve_bench`` report validator.

The validator is the CI gate between a benchmark run and the checked-in
baseline; it must accept every released schema generation (v1–v7) and
reject malformed payloads with errors that name the offending field —
a silent pass here would let a NaN or truncated report become the perf
baseline subsequent PRs are measured against. v6 adds the steady-state
sanitizer counters to continuous rows and pins them to exactly zero; v7
adds the chunked-prefill tail-latency rows (exact TTFT/TPOT percentiles
for both legs, ordering-checked, with the p95-TTFT and goodput
improvement gates enforced on non-smoke baselines only); v8 adds the
measured-autotune columns to static rows — routed-never-slower-than-
displaced always, and the quantized-decode-beats-fp tokens/sec gate on
non-smoke baselines.
"""
import math

import pytest

from benchmarks.serve_bench import (ADAPTER_ROW_FIELDS, CONT_ROW_FIELDS,
                                    CONT_ROW_FIELDS_V6, KV_ROW_FIELDS,
                                    LATENCY_ROW_FIELDS, PREFIX_ROW_FIELDS,
                                    ROW_FIELDS, ROW_FIELDS_V8,
                                    SANITIZER_FIELDS, validate)


def _static_row(mode="fp", **over):
    row = {"mode": mode, "batch": 4, "prompt": 16, "n_steps": 16,
           "prefill_ms": 3.0, "decode_ms_per_tok": 0.5, "tokens_per_s": 900.0,
           "scan_decode_ms_per_tok": 0.5, "step_decode_ms_per_tok": 1.0,
           "dispatch_overhead_ms_per_tok": 0.5, "scan_speedup": 2.0}
    assert set(row) == set(ROW_FIELDS)
    row.update(over)
    return row


def _static_row_v8(mode="fp", **over):
    quant = mode == "w4a8_aser"
    row = _static_row(mode)
    row.update({
        "decode_tokens_per_s": 8000.0 if quant else 2000.0,
        "autotune": "force" if quant else "off",
        "decode_plan": "prepared" if quant else "default",
        "displaced_decode_ms_per_tok": 2.0 if quant else 0.5,
        "autotune_demoted": False,
        "decode_vs_fp": 4.0 if quant else 1.0,
    })
    if quant:
        row["decode_ms_per_tok"] = 0.125
        row["scan_decode_ms_per_tok"] = 0.125
    assert set(row) == set(ROW_FIELDS_V8)
    row.update(over)
    return row


def _cont_row(mode="fp", v6=False, **over):
    row = {"mode": mode, "requests": 8, "batch_slots": 2, "chunk": 4,
           "prompt_len_min": 2, "prompt_len_max": 10, "new_tokens_min": 2,
           "new_tokens_max": 12, "useful_tokens": 64, "static_s": 0.2,
           "continuous_s": 0.1, "static_goodput_tok_s": 320.0,
           "goodput_tok_s": 640.0, "goodput_speedup": 2.0}
    assert set(row) == set(CONT_ROW_FIELDS)
    if v6:
        row.update({"recompiles_after_warmup": 0,
                    "h2d_transfers_per_step": 0.0})
        assert set(row) == set(CONT_ROW_FIELDS_V6)
    row.update(over)
    return row


def _prefix_row(mode="fp", **over):
    row = {"mode": mode, "requests": 8, "prefix_groups": 2, "prefix_len": 16,
           "batch_slots": 2, "chunk": 4, "block_size": 8, "num_blocks": 8,
           "useful_tokens": 40, "noreuse_s": 0.2, "reuse_s": 0.1,
           "noreuse_goodput_tok_s": 200.0, "goodput_tok_s": 400.0,
           "goodput_speedup": 2.0, "prefix_hit_rate": 0.6}
    assert set(row) == set(PREFIX_ROW_FIELDS)
    row.update(over)
    return row


def _kv_row(mode="fp", **over):
    row = {"mode": mode, "requests": 8, "batch_slots": 2, "chunk": 4,
           "block_size": 8, "hbm_budget_kb": 64.0, "bf16_blocks": 8,
           "int8_blocks": 28, "useful_tokens": 100, "bf16_s": 0.2,
           "int8_s": 0.1, "bf16_preemptions": 3, "int8_preemptions": 0,
           "bf16_goodput_tok_s": 500.0, "goodput_tok_s": 1000.0,
           "goodput_speedup": 2.0}
    assert set(row) == set(KV_ROW_FIELDS)
    row.update(over)
    return row


def _adapter_row(mode="w4a8_aser", **over):
    row = {"mode": mode, "requests": 9, "adapters": 4, "adapter_rank": 4,
           "adapter_slots": 3, "batch_slots": 2, "chunk": 4,
           "useful_tokens": 38, "base_s": 0.2, "mixed_s": 0.21,
           "base_goodput_tok_s": 190.0, "goodput_tok_s": 181.0,
           "goodput_ratio": 0.952, "adapter_loads": 4,
           "adapter_evictions": 2, "token_exact": True}
    assert set(row) == set(ADAPTER_ROW_FIELDS)
    row.update(over)
    return row


def _latency_row(mode="fp", **over):
    row = {"mode": mode, "requests": 8, "batch_slots": 2, "chunk": 4,
           "prefill_chunk": 8, "step_token_budget": 20, "block_size": 8,
           "wave": 3, "arrival_gap_tok": 40,
           "useful_tokens": 40, "oneshot_s": 0.2, "chunked_s": 0.25,
           "oneshot_tokens_dispatched": 320, "tokens_dispatched": 288,
           "oneshot_goodput_util": 0.125, "goodput_util": 0.139,
           "goodput_ratio": 1.11,
           "oneshot_ttft_p50_tok": 8.0, "oneshot_ttft_p95_tok": 40.0,
           "oneshot_ttft_p99_tok": 55.0,
           "oneshot_tpot_p50_tok": 1.0, "oneshot_tpot_p95_tok": 3.0,
           "oneshot_tpot_p99_tok": 4.0,
           "ttft_p50_tok": 0.0, "ttft_p95_tok": 25.0, "ttft_p99_tok": 30.0,
           "tpot_p50_tok": 1.1, "tpot_p95_tok": 2.5, "tpot_p99_tok": 3.5,
           "ttft_p95_speedup": 1.6,
           "chunked_recompiles_after_warmup": 0,
           "chunked_h2d_transfers_per_step": 0.0}
    assert set(row) == set(LATENCY_ROW_FIELDS)
    row.update(over)
    return row


def _report(schema, smoke=True):
    v8 = schema == "serve_bench/v8"
    mk_static = _static_row_v8 if v8 else _static_row
    rep = {"schema": schema, "smoke": smoke,
           "model": {"name": "t", "n_layers": 2, "d_model": 64,
                     "vocab_size": 128},
           "decode_loop_default": "scan",
           "rows": [mk_static("fp"), mk_static("w4a8_aser")]}
    if schema != "serve_bench/v1":
        v6 = schema in ("serve_bench/v6", "serve_bench/v7",
                        "serve_bench/v8")
        rep["continuous_rows"] = [_cont_row("fp", v6=v6),
                                  _cont_row("w4a8_aser", v6=v6)]
    if schema not in ("serve_bench/v1", "serve_bench/v2"):
        rep["prefix_rows"] = [_prefix_row("fp"), _prefix_row("w4a8_aser")]
    if schema not in ("serve_bench/v1", "serve_bench/v2",
                      "serve_bench/v3"):
        rep["kv_rows"] = [_kv_row("fp"), _kv_row("w4a8_aser")]
    if schema in ("serve_bench/v5", "serve_bench/v6", "serve_bench/v7",
                  "serve_bench/v8"):
        rep["adapter_rows"] = [_adapter_row()]
    if schema in ("serve_bench/v7", "serve_bench/v8"):
        rep["latency_rows"] = [_latency_row("fp"),
                               _latency_row("w4a8_aser")]
    return rep


# -- accepted generations ----------------------------------------------------

@pytest.mark.parametrize("schema", ["serve_bench/v1", "serve_bench/v2",
                                    "serve_bench/v3", "serve_bench/v4",
                                    "serve_bench/v5", "serve_bench/v6",
                                    "serve_bench/v7", "serve_bench/v8"])
def test_every_released_schema_validates(schema):
    assert validate(_report(schema)) is True


def test_v1_fixture_ignores_newer_sections():
    """A v1 file with stray newer keys is still just a v1 file."""
    rep = _report("serve_bench/v1")
    rep["continuous_rows"] = []            # would fail v2 validation
    assert validate(rep) is True


# -- rejected payloads -------------------------------------------------------

def test_wrong_schema_rejected():
    rep = _report("serve_bench/v4")
    rep["schema"] = "serve_bench/v99"
    with pytest.raises(ValueError, match="schema mismatch.*v99"):
        validate(rep)
    with pytest.raises(ValueError, match="schema mismatch"):
        validate({"rows": rep["rows"]})    # missing schema entirely
    # partial probe files are rejected by design
    with pytest.raises(ValueError, match="schema mismatch.*probe"):
        validate({**rep, "schema": "serve_bench/probe"})


def test_missing_field_rejected_with_field_name():
    rep = _report("serve_bench/v4")
    del rep["kv_rows"][0]["int8_blocks"]
    with pytest.raises(ValueError, match="missing fields.*int8_blocks"):
        validate(rep)
    rep = _report("serve_bench/v3")
    del rep["prefix_rows"][1]["prefix_hit_rate"]
    with pytest.raises(ValueError, match="missing fields.*prefix_hit_rate"):
        validate(rep)
    rep = _report("serve_bench/v1")
    del rep["rows"][0]["decode_ms_per_tok"]
    with pytest.raises(ValueError, match="missing fields.*decode_ms_per_tok"):
        validate(rep)


def test_missing_section_rejected():
    rep = _report("serve_bench/v4")
    del rep["kv_rows"]
    with pytest.raises(ValueError, match="no kv rows"):
        validate(rep)
    rep = _report("serve_bench/v2")
    rep["continuous_rows"] = []
    with pytest.raises(ValueError, match="no continuous rows"):
        validate(rep)


@pytest.mark.parametrize("bad", [float("nan"), float("inf"), "12.5", None,
                                 True])
def test_non_finite_or_non_numeric_metric_rejected(bad):
    rep = _report("serve_bench/v4")
    rep["kv_rows"][0]["goodput_tok_s"] = bad
    with pytest.raises(ValueError, match="non-finite goodput_tok_s"):
        validate(rep)


def test_non_positive_latency_rejected():
    rep = _report("serve_bench/v1")
    rep["rows"][0]["prefill_ms"] = 0.0
    with pytest.raises(ValueError, match="non-positive prefill_ms"):
        validate(rep)


def test_missing_mode_coverage_rejected():
    rep = _report("serve_bench/v4")
    rep["kv_rows"] = [_kv_row("fp")]
    with pytest.raises(ValueError, match="need fp and w4a8_aser kv rows"):
        validate(rep)


def test_prefix_hit_rate_bounds():
    rep = _report("serve_bench/v3")
    rep["prefix_rows"][0]["prefix_hit_rate"] = 1.5
    with pytest.raises(ValueError, match="prefix_hit_rate out of"):
        validate(rep)


def test_shrunken_int8_pool_rejected():
    """At equal HBM budget the int8 pool can never be smaller — a smaller
    pool means the budget math regressed."""
    rep = _report("serve_bench/v4")
    rep["kv_rows"][0]["int8_blocks"] = 4
    with pytest.raises(ValueError, match="int8 pool smaller"):
        validate(rep)


def test_nan_detection_is_not_string_typed():
    """The finite check must treat booleans and strings as malformed even
    when they'd compare truthy."""
    rep = _report("serve_bench/v2")
    rep["continuous_rows"][0]["useful_tokens"] = math.nan
    with pytest.raises(ValueError, match="non-finite useful_tokens"):
        validate(rep)


# -- adapter rows (v5) -------------------------------------------------------

def test_adapter_rows_gate_mode_exactness_and_goodput():
    rep = _report("serve_bench/v5")
    rep["adapter_rows"] = []
    with pytest.raises(ValueError, match="no adapter rows"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"] = [_adapter_row(mode="fp")]
    with pytest.raises(ValueError, match="w4a8_aser-only"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["token_exact"] = False
    with pytest.raises(ValueError, match="not token-exact"):
        validate(rep)
    # token_exact must be the bool True, not merely truthy
    rep["adapter_rows"][0]["token_exact"] = 1.0
    with pytest.raises(ValueError, match="not token-exact"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["goodput_ratio"] = 0.8
    with pytest.raises(ValueError, match="below 0.85x"):
        validate(rep)
    rep = _report("serve_bench/v5")
    del rep["adapter_rows"][0]["adapter_loads"]
    with pytest.raises(ValueError, match="missing fields.*adapter_loads"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["mixed_s"] = math.nan
    with pytest.raises(ValueError, match="non-finite mixed_s"):
        validate(rep)


def test_v4_fixture_ignores_adapter_rows():
    """A v4 file with stray adapter rows is still just a v4 file."""
    rep = _report("serve_bench/v4")
    rep["adapter_rows"] = []               # would fail v5 validation
    assert validate(rep) is True


# -- steady-state sanitizer counters (v6) ------------------------------------

def test_v6_requires_sanitizer_fields():
    rep = _report("serve_bench/v6")
    for field in SANITIZER_FIELDS:
        broken = _report("serve_bench/v6")
        del broken["continuous_rows"][0][field]
        with pytest.raises(ValueError, match=f"missing fields.*{field}"):
            validate(broken)
    assert validate(rep) is True


@pytest.mark.parametrize("field,bad", [
    ("recompiles_after_warmup", 1),
    ("recompiles_after_warmup", 3),
    ("h2d_transfers_per_step", 0.25),
    ("h2d_transfers_per_step", 1.0),
])
def test_v6_rejects_nonzero_sanitizer_counters(field, bad):
    rep = _report("serve_bench/v6")
    rep["continuous_rows"][1][field] = bad
    with pytest.raises(ValueError, match="steady-state decode is not "
                                         "clean"):
        validate(rep)


def test_v5_fixture_ignores_sanitizer_fields():
    """Pre-v6 baselines neither need the counters nor get them enforced:
    a v5 file with a stray nonzero counter is still just a v5 file."""
    rep = _report("serve_bench/v5")
    rep["continuous_rows"][0]["recompiles_after_warmup"] = 7
    assert validate(rep) is True


# -- chunked-prefill latency rows (v7) ----------------------------------------

def test_v7_requires_latency_rows():
    rep = _report("serve_bench/v7")
    del rep["latency_rows"]
    with pytest.raises(ValueError, match="no latency rows"):
        validate(rep)
    rep = _report("serve_bench/v7")
    rep["latency_rows"] = []
    with pytest.raises(ValueError, match="no latency rows"):
        validate(rep)


def test_v7_missing_percentile_field_named():
    rep = _report("serve_bench/v7")
    del rep["latency_rows"][0]["ttft_p95_tok"]
    with pytest.raises(ValueError, match="missing fields.*ttft_p95_tok"):
        validate(rep)
    rep = _report("serve_bench/v7")
    del rep["latency_rows"][1]["oneshot_tpot_p99_tok"]
    with pytest.raises(ValueError,
                       match="missing fields.*oneshot_tpot_p99_tok"):
        validate(rep)


@pytest.mark.parametrize("field,bad", [
    ("ttft_p95_tok", math.nan),
    ("oneshot_ttft_p50_tok", math.inf),
    ("tpot_p50_tok", "3.1"),
    ("ttft_p95_speedup", None),
])
def test_v7_non_finite_latency_metric_rejected(field, bad):
    rep = _report("serve_bench/v7")
    rep["latency_rows"][0][field] = bad
    with pytest.raises(ValueError, match=f"non-finite {field}"):
        validate(rep)


@pytest.mark.parametrize("field", ["prefill_chunk", "step_token_budget",
                                   "arrival_gap_tok", "tokens_dispatched",
                                   "goodput_util"])
def test_v7_non_positive_latency_metric_rejected(field):
    for bad in (0, -1.5):
        rep = _report("serve_bench/v7")
        rep["latency_rows"][1][field] = bad
        with pytest.raises(ValueError, match=f"non-positive {field}"):
            validate(rep)


def test_v7_percentiles_allow_zero_but_not_negative():
    """Token-time percentiles may legitimately be 0 (an uncontended
    request admitted the step after its arrival has TTFT 0 — events stamp
    at step granularity) but can never be negative."""
    rep = _report("serve_bench/v7", smoke=False)
    rep["latency_rows"][0]["oneshot_ttft_p50_tok"] = 0.0
    assert validate(rep) is True
    rep["latency_rows"][0]["oneshot_ttft_p50_tok"] = -1.0
    with pytest.raises(ValueError,
                       match="negative percentile oneshot_ttft_p50_tok"):
        validate(rep)


def test_v7_utilization_capped_at_one():
    """goodput_util = useful / dispatched can never exceed 1 — a value
    above it means the dispatched-token accounting dropped work."""
    rep = _report("serve_bench/v7")
    rep["latency_rows"][0]["goodput_util"] = 1.2
    with pytest.raises(ValueError, match="cannot exceed dispatched"):
        validate(rep)


@pytest.mark.parametrize("prefix", ["", "oneshot_"])
@pytest.mark.parametrize("fam", ["ttft", "tpot"])
def test_v7_percentile_ordering_enforced(prefix, fam):
    """p50 <= p95 <= p99 must hold for every percentile family — an exact
    nearest-rank reducer can never produce an inversion, so one in a
    report means the fields were scrambled during row assembly."""
    rep = _report("serve_bench/v7")
    row = rep["latency_rows"][0]
    row[f"{prefix}{fam}_p50_tok"] = row[f"{prefix}{fam}_p99_tok"] * 2
    with pytest.raises(ValueError, match=f"{prefix}{fam} percentiles out "
                                         f"of order"):
        validate(rep)


@pytest.mark.parametrize("field,bad", [
    ("chunked_recompiles_after_warmup", 1),
    ("chunked_h2d_transfers_per_step", 0.5),
])
def test_v7_rejects_dirty_chunked_steady_state(field, bad):
    rep = _report("serve_bench/v7")
    rep["latency_rows"][1][field] = bad
    with pytest.raises(ValueError, match="chunked steady state is not "
                                         "clean"):
        validate(rep)


def test_v7_mode_coverage_required():
    rep = _report("serve_bench/v7")
    rep["latency_rows"] = [_latency_row("fp")]
    with pytest.raises(ValueError,
                       match="need fp and w4a8_aser latency rows"):
        validate(rep)


def test_v7_improvement_gates_non_smoke_only():
    """The p95-TTFT and goodput gates are the shipping acceptance for
    chunked prefill — enforced on real baselines, waived for smoke runs
    whose 8-request tails are all noise (p95 of 8 samples is the max)."""
    # regressions pass while smoke...
    rep = _report("serve_bench/v7", smoke=True)
    rep["latency_rows"][0]["ttft_p95_speedup"] = 0.7
    rep["latency_rows"][1]["goodput_ratio"] = 0.9
    assert validate(rep) is True
    # ...and fail on a non-smoke baseline
    rep = _report("serve_bench/v7", smoke=False)
    assert validate(rep) is True           # healthy rows pass either way
    rep["latency_rows"][0]["ttft_p95_speedup"] = 0.99
    with pytest.raises(ValueError, match="did not improve p95 TTFT"):
        validate(rep)
    rep = _report("serve_bench/v7", smoke=False)
    rep["latency_rows"][1]["goodput_ratio"] = 0.97
    with pytest.raises(ValueError, match="goodput below one-shot"):
        validate(rep)


def test_v6_fixture_ignores_latency_rows():
    """Pre-v7 baselines neither need latency rows nor get them enforced:
    a v6 file with stray (even malformed) latency rows is still v6."""
    rep = _report("serve_bench/v6")
    rep["latency_rows"] = [_latency_row("fp", ttft_p95_tok=math.nan)]
    assert validate(rep) is True


# -- measured-autotune static columns (v8) ------------------------------------

def test_v8_missing_autotune_column_named():
    for field in ("decode_tokens_per_s", "autotune", "decode_plan",
                  "displaced_decode_ms_per_tok", "autotune_demoted",
                  "decode_vs_fp"):
        rep = _report("serve_bench/v8")
        del rep["rows"][1][field]
        with pytest.raises(ValueError, match=f"missing fields.*{field}"):
            validate(rep)


def test_v8_bad_autotune_mode_rejected():
    rep = _report("serve_bench/v8")
    rep["rows"][1]["autotune"] = "always"
    with pytest.raises(ValueError, match="bad autotune mode"):
        validate(rep)
    rep = _report("serve_bench/v8")
    rep["rows"][0]["decode_plan"] = 7
    with pytest.raises(ValueError, match="decode_plan must be a string"):
        validate(rep)
    rep = _report("serve_bench/v8")
    rep["rows"][1]["autotune_demoted"] = "no"
    with pytest.raises(ValueError, match="autotune_demoted must be a bool"):
        validate(rep)


def test_v8_routed_slower_than_displaced_rejected():
    """The satellite assertion: a row reporting the autotuned routing
    slower than the path it displaced means the bench's demotion fallback
    failed — the file must not become the baseline."""
    rep = _report("serve_bench/v8")
    rep["rows"][1]["displaced_decode_ms_per_tok"] = \
        rep["rows"][1]["decode_ms_per_tok"] / 2
    with pytest.raises(ValueError, match="slower than the displaced"):
        validate(rep)
    # equal-time (a demoted row reports displaced == routed) passes
    rep = _report("serve_bench/v8")
    rep["rows"][1]["displaced_decode_ms_per_tok"] = \
        rep["rows"][1]["decode_ms_per_tok"]
    rep["rows"][1]["autotune_demoted"] = True
    assert validate(rep) is True


def test_v8_quant_decode_beats_fp_gate_non_smoke_only():
    """The shipping acceptance: quantized decode tokens/sec >= fp on every
    quant row of a real baseline; smoke rows are noise and exempt."""
    rep = _report("serve_bench/v8", smoke=True)
    rep["rows"][1]["decode_vs_fp"] = 0.5
    assert validate(rep) is True
    rep = _report("serve_bench/v8", smoke=False)
    assert validate(rep) is True           # healthy rows pass either way
    rep["rows"][1]["decode_vs_fp"] = 0.98
    with pytest.raises(ValueError, match="quantized decode lost to fp"):
        validate(rep)
    # the gate reads quant rows only: an fp row below 1 is meaningless
    rep = _report("serve_bench/v8", smoke=False)
    rep["rows"][0]["decode_vs_fp"] = 0.5
    assert validate(rep) is True


def test_v7_fixture_ignores_autotune_columns():
    """Pre-v8 baselines neither need the autotune columns nor get them
    enforced: a v7 file with stray (even malformed) autotune fields is
    still just a v7 file."""
    rep = _report("serve_bench/v7")
    rep["rows"][1]["decode_vs_fp"] = 0.1
    rep["rows"][1]["autotune"] = "always"
    assert validate(rep) is True
