"""Unit tests for the ``serve_bench`` report validator.

The validator is the CI gate between a benchmark run and the checked-in
baseline; it must accept every released schema generation (v1–v6) and
reject malformed payloads with errors that name the offending field —
a silent pass here would let a NaN or truncated report become the perf
baseline subsequent PRs are measured against. v6 adds the steady-state
sanitizer counters to continuous rows and pins them to exactly zero.
"""
import math

import pytest

from benchmarks.serve_bench import (ADAPTER_ROW_FIELDS, CONT_ROW_FIELDS,
                                    CONT_ROW_FIELDS_V6, KV_ROW_FIELDS,
                                    PREFIX_ROW_FIELDS, ROW_FIELDS,
                                    SANITIZER_FIELDS, validate)


def _static_row(mode="fp", **over):
    row = {"mode": mode, "batch": 4, "prompt": 16, "n_steps": 16,
           "prefill_ms": 3.0, "decode_ms_per_tok": 0.5, "tokens_per_s": 900.0,
           "scan_decode_ms_per_tok": 0.5, "step_decode_ms_per_tok": 1.0,
           "dispatch_overhead_ms_per_tok": 0.5, "scan_speedup": 2.0}
    assert set(row) == set(ROW_FIELDS)
    row.update(over)
    return row


def _cont_row(mode="fp", v6=False, **over):
    row = {"mode": mode, "requests": 8, "batch_slots": 2, "chunk": 4,
           "prompt_len_min": 2, "prompt_len_max": 10, "new_tokens_min": 2,
           "new_tokens_max": 12, "useful_tokens": 64, "static_s": 0.2,
           "continuous_s": 0.1, "static_goodput_tok_s": 320.0,
           "goodput_tok_s": 640.0, "goodput_speedup": 2.0}
    assert set(row) == set(CONT_ROW_FIELDS)
    if v6:
        row.update({"recompiles_after_warmup": 0,
                    "h2d_transfers_per_step": 0.0})
        assert set(row) == set(CONT_ROW_FIELDS_V6)
    row.update(over)
    return row


def _prefix_row(mode="fp", **over):
    row = {"mode": mode, "requests": 8, "prefix_groups": 2, "prefix_len": 16,
           "batch_slots": 2, "chunk": 4, "block_size": 8, "num_blocks": 8,
           "useful_tokens": 40, "noreuse_s": 0.2, "reuse_s": 0.1,
           "noreuse_goodput_tok_s": 200.0, "goodput_tok_s": 400.0,
           "goodput_speedup": 2.0, "prefix_hit_rate": 0.6}
    assert set(row) == set(PREFIX_ROW_FIELDS)
    row.update(over)
    return row


def _kv_row(mode="fp", **over):
    row = {"mode": mode, "requests": 8, "batch_slots": 2, "chunk": 4,
           "block_size": 8, "hbm_budget_kb": 64.0, "bf16_blocks": 8,
           "int8_blocks": 28, "useful_tokens": 100, "bf16_s": 0.2,
           "int8_s": 0.1, "bf16_preemptions": 3, "int8_preemptions": 0,
           "bf16_goodput_tok_s": 500.0, "goodput_tok_s": 1000.0,
           "goodput_speedup": 2.0}
    assert set(row) == set(KV_ROW_FIELDS)
    row.update(over)
    return row


def _adapter_row(mode="w4a8_aser", **over):
    row = {"mode": mode, "requests": 9, "adapters": 4, "adapter_rank": 4,
           "adapter_slots": 3, "batch_slots": 2, "chunk": 4,
           "useful_tokens": 38, "base_s": 0.2, "mixed_s": 0.21,
           "base_goodput_tok_s": 190.0, "goodput_tok_s": 181.0,
           "goodput_ratio": 0.952, "adapter_loads": 4,
           "adapter_evictions": 2, "token_exact": True}
    assert set(row) == set(ADAPTER_ROW_FIELDS)
    row.update(over)
    return row


def _report(schema):
    rep = {"schema": schema, "smoke": True,
           "model": {"name": "t", "n_layers": 2, "d_model": 64,
                     "vocab_size": 128},
           "decode_loop_default": "scan",
           "rows": [_static_row("fp"), _static_row("w4a8_aser")]}
    if schema != "serve_bench/v1":
        v6 = schema == "serve_bench/v6"
        rep["continuous_rows"] = [_cont_row("fp", v6=v6),
                                  _cont_row("w4a8_aser", v6=v6)]
    if schema not in ("serve_bench/v1", "serve_bench/v2"):
        rep["prefix_rows"] = [_prefix_row("fp"), _prefix_row("w4a8_aser")]
    if schema not in ("serve_bench/v1", "serve_bench/v2",
                      "serve_bench/v3"):
        rep["kv_rows"] = [_kv_row("fp"), _kv_row("w4a8_aser")]
    if schema in ("serve_bench/v5", "serve_bench/v6"):
        rep["adapter_rows"] = [_adapter_row()]
    return rep


# -- accepted generations ----------------------------------------------------

@pytest.mark.parametrize("schema", ["serve_bench/v1", "serve_bench/v2",
                                    "serve_bench/v3", "serve_bench/v4",
                                    "serve_bench/v5", "serve_bench/v6"])
def test_every_released_schema_validates(schema):
    assert validate(_report(schema)) is True


def test_v1_fixture_ignores_newer_sections():
    """A v1 file with stray newer keys is still just a v1 file."""
    rep = _report("serve_bench/v1")
    rep["continuous_rows"] = []            # would fail v2 validation
    assert validate(rep) is True


# -- rejected payloads -------------------------------------------------------

def test_wrong_schema_rejected():
    rep = _report("serve_bench/v4")
    rep["schema"] = "serve_bench/v99"
    with pytest.raises(ValueError, match="schema mismatch.*v99"):
        validate(rep)
    with pytest.raises(ValueError, match="schema mismatch"):
        validate({"rows": rep["rows"]})    # missing schema entirely
    # partial probe files are rejected by design
    with pytest.raises(ValueError, match="schema mismatch.*probe"):
        validate({**rep, "schema": "serve_bench/probe"})


def test_missing_field_rejected_with_field_name():
    rep = _report("serve_bench/v4")
    del rep["kv_rows"][0]["int8_blocks"]
    with pytest.raises(ValueError, match="missing fields.*int8_blocks"):
        validate(rep)
    rep = _report("serve_bench/v3")
    del rep["prefix_rows"][1]["prefix_hit_rate"]
    with pytest.raises(ValueError, match="missing fields.*prefix_hit_rate"):
        validate(rep)
    rep = _report("serve_bench/v1")
    del rep["rows"][0]["decode_ms_per_tok"]
    with pytest.raises(ValueError, match="missing fields.*decode_ms_per_tok"):
        validate(rep)


def test_missing_section_rejected():
    rep = _report("serve_bench/v4")
    del rep["kv_rows"]
    with pytest.raises(ValueError, match="no kv rows"):
        validate(rep)
    rep = _report("serve_bench/v2")
    rep["continuous_rows"] = []
    with pytest.raises(ValueError, match="no continuous rows"):
        validate(rep)


@pytest.mark.parametrize("bad", [float("nan"), float("inf"), "12.5", None,
                                 True])
def test_non_finite_or_non_numeric_metric_rejected(bad):
    rep = _report("serve_bench/v4")
    rep["kv_rows"][0]["goodput_tok_s"] = bad
    with pytest.raises(ValueError, match="non-finite goodput_tok_s"):
        validate(rep)


def test_non_positive_latency_rejected():
    rep = _report("serve_bench/v1")
    rep["rows"][0]["prefill_ms"] = 0.0
    with pytest.raises(ValueError, match="non-positive prefill_ms"):
        validate(rep)


def test_missing_mode_coverage_rejected():
    rep = _report("serve_bench/v4")
    rep["kv_rows"] = [_kv_row("fp")]
    with pytest.raises(ValueError, match="need fp and w4a8_aser kv rows"):
        validate(rep)


def test_prefix_hit_rate_bounds():
    rep = _report("serve_bench/v3")
    rep["prefix_rows"][0]["prefix_hit_rate"] = 1.5
    with pytest.raises(ValueError, match="prefix_hit_rate out of"):
        validate(rep)


def test_shrunken_int8_pool_rejected():
    """At equal HBM budget the int8 pool can never be smaller — a smaller
    pool means the budget math regressed."""
    rep = _report("serve_bench/v4")
    rep["kv_rows"][0]["int8_blocks"] = 4
    with pytest.raises(ValueError, match="int8 pool smaller"):
        validate(rep)


def test_nan_detection_is_not_string_typed():
    """The finite check must treat booleans and strings as malformed even
    when they'd compare truthy."""
    rep = _report("serve_bench/v2")
    rep["continuous_rows"][0]["useful_tokens"] = math.nan
    with pytest.raises(ValueError, match="non-finite useful_tokens"):
        validate(rep)


# -- adapter rows (v5) -------------------------------------------------------

def test_adapter_rows_gate_mode_exactness_and_goodput():
    rep = _report("serve_bench/v5")
    rep["adapter_rows"] = []
    with pytest.raises(ValueError, match="no adapter rows"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"] = [_adapter_row(mode="fp")]
    with pytest.raises(ValueError, match="w4a8_aser-only"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["token_exact"] = False
    with pytest.raises(ValueError, match="not token-exact"):
        validate(rep)
    # token_exact must be the bool True, not merely truthy
    rep["adapter_rows"][0]["token_exact"] = 1.0
    with pytest.raises(ValueError, match="not token-exact"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["goodput_ratio"] = 0.8
    with pytest.raises(ValueError, match="below 0.85x"):
        validate(rep)
    rep = _report("serve_bench/v5")
    del rep["adapter_rows"][0]["adapter_loads"]
    with pytest.raises(ValueError, match="missing fields.*adapter_loads"):
        validate(rep)
    rep = _report("serve_bench/v5")
    rep["adapter_rows"][0]["mixed_s"] = math.nan
    with pytest.raises(ValueError, match="non-finite mixed_s"):
        validate(rep)


def test_v4_fixture_ignores_adapter_rows():
    """A v4 file with stray adapter rows is still just a v4 file."""
    rep = _report("serve_bench/v4")
    rep["adapter_rows"] = []               # would fail v5 validation
    assert validate(rep) is True


# -- steady-state sanitizer counters (v6) ------------------------------------

def test_v6_requires_sanitizer_fields():
    rep = _report("serve_bench/v6")
    for field in SANITIZER_FIELDS:
        broken = _report("serve_bench/v6")
        del broken["continuous_rows"][0][field]
        with pytest.raises(ValueError, match=f"missing fields.*{field}"):
            validate(broken)
    assert validate(rep) is True


@pytest.mark.parametrize("field,bad", [
    ("recompiles_after_warmup", 1),
    ("recompiles_after_warmup", 3),
    ("h2d_transfers_per_step", 0.25),
    ("h2d_transfers_per_step", 1.0),
])
def test_v6_rejects_nonzero_sanitizer_counters(field, bad):
    rep = _report("serve_bench/v6")
    rep["continuous_rows"][1][field] = bad
    with pytest.raises(ValueError, match="steady-state decode is not "
                                         "clean"):
        validate(rep)


def test_v5_fixture_ignores_sanitizer_fields():
    """Pre-v6 baselines neither need the counters nor get them enforced:
    a v5 file with a stray nonzero counter is still just a v5 file."""
    rep = _report("serve_bench/v5")
    rep["continuous_rows"][0]["recompiles_after_warmup"] = 7
    assert validate(rep) is True
