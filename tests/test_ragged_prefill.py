"""Ragged-batch serving correctness.

The definitive guard for the pad-position sampling bug: for ANY mix of
prompt lengths in one padded batch, every row of
``Engine.generate(..., prompt_lens=...)`` must equal the single-request run
of that row — on both decode loops. The seed engine sampled ``logits[:, -1]``
after prefill, i.e. shorter prompts sampled their first token from a pad
position and then decoded from the padded width.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig


MAX_PROMPT = 8
BATCH = 3


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def engines():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, {loop: Engine(params, cfg,
                              ServeConfig(max_len=32, decode_loop=loop))
                 for loop in ("scan", "step")}


def _ragged_batch(cfg, seed: int):
    """Random per-row lengths in [1, MAX_PROMPT] + right-padded prompts."""
    key = jax.random.PRNGKey(seed)
    lens = np.asarray(jax.random.randint(key, (BATCH,), 1, MAX_PROMPT + 1))
    padded = np.zeros((BATCH, MAX_PROMPT), np.int32)
    rows = []
    for i, L in enumerate(lens):
        row = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                            (int(L),), 0, cfg.vocab_size))
        padded[i, :int(L)] = row
        rows.append(row)
    return lens.astype(np.int32), padded, rows


# ---------------------------------------------------------------------------
# Property: ragged batch ≡ per-request runs (the pad-position guard)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_ragged_rows_match_single_request(engines, seed):
    cfg, engs = engines
    lens, padded, rows = _ragged_batch(cfg, seed)
    for loop, eng in engs.items():
        out = np.asarray(eng.generate(jnp.asarray(padded), 6,
                                      prompt_lens=lens))
        for i, row in enumerate(rows):
            ref = np.asarray(eng.generate(jnp.asarray(row[None]), 6))[0]
            assert np.array_equal(out[i], ref), (loop, seed, i, lens)


def test_ragged_scan_matches_step():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    lens, padded, _ = _ragged_batch(cfg, seed=7)
    outs = {}
    for loop in ("scan", "step"):
        eng = Engine(params, cfg, ServeConfig(max_len=32, decode_loop=loop))
        outs[loop] = np.asarray(eng.generate(jnp.asarray(padded), 8,
                                             prompt_lens=lens))
    assert np.array_equal(outs["scan"], outs["step"])


def test_ragged_differs_from_padded_run(engines):
    """The bug this PR fixes: running the padded batch WITHOUT prompt_lens
    samples shorter rows from pad positions. With mixed lengths the fixed
    ragged path must disagree with that on the short rows."""
    cfg, engs = engines
    lens, padded, _ = _ragged_batch(cfg, seed=3)
    if len(set(lens.tolist())) == 1:      # make lengths genuinely mixed
        lens[0] = 1
    eng = engs["scan"]
    fixed = np.asarray(eng.generate(jnp.asarray(padded), 6,
                                    prompt_lens=lens))
    buggy = np.asarray(eng.generate(jnp.asarray(padded), 6))
    assert not np.array_equal(fixed, buggy)
    # rows already at full width are unaffected by the fix
    for i, L in enumerate(lens):
        if int(L) == MAX_PROMPT:
            assert np.array_equal(fixed[i], buggy[i])


def test_uniform_lens_match_legacy_path(engines):
    """prompt_lens == padded width reduces to the legacy uniform path."""
    cfg, engs = engines
    prompts = jax.random.randint(jax.random.PRNGKey(11), (BATCH, 5), 0,
                                 cfg.vocab_size)
    lens = np.full((BATCH,), 5, np.int32)
    for loop, eng in engs.items():
        a = np.asarray(eng.generate(prompts, 6, prompt_lens=lens))
        b = np.asarray(eng.generate(prompts, 6))
        assert np.array_equal(a, b), loop


# ---------------------------------------------------------------------------
# eos + ragged interact correctly (masked continuation per row)
# ---------------------------------------------------------------------------

def test_ragged_eos_masked_continuation():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    lens, padded, rows = _ragged_batch(cfg, seed=5)
    free = np.asarray(Engine(params, cfg, ServeConfig(max_len=32)).generate(
        jnp.asarray(padded), 8, prompt_lens=lens))
    eos = int(free[0, 3])
    for loop in ("scan", "step"):
        eng = Engine(params, cfg, ServeConfig(max_len=32, eos_id=eos,
                                              decode_loop=loop))
        got = np.asarray(eng.generate(jnp.asarray(padded), 8,
                                      prompt_lens=lens))
        for row in got:
            hits = np.nonzero(row == eos)[0]
            if hits.size:
                assert np.all(row[hits[0]:] == eos), (loop, row)
        assert np.all(got[0, 3:] == eos), loop


# ---------------------------------------------------------------------------
# unsupported families fail loudly, not silently wrong
# ---------------------------------------------------------------------------

def test_ragged_rejects_bad_prompt_lens(engines):
    """Out-of-range lens would silently re-introduce pad-position sampling
    (the jitted gather clamps) — they must raise host-side instead."""
    cfg, engs = engines
    eng = engs["scan"]
    prompts = jnp.zeros((BATCH, 4), jnp.int32)
    with pytest.raises(ValueError, match="padded width"):
        eng.generate(prompts, 2, prompt_lens=np.array([2, 5, 3]))
    with pytest.raises(ValueError, match="padded width"):
        eng.generate(prompts, 2, prompt_lens=np.array([0, 2, 3]))
    with pytest.raises(ValueError, match="shape"):
        eng.generate(prompts, 2, prompt_lens=np.array([2, 3]))
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(prompts, 64, prompt_lens=np.array([2, 3, 4]))


def test_ragged_rejects_ring_and_stateful_families():
    cfg = dataclasses.replace(_tiny_cfg(), sliding_window=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=32))
    with pytest.raises(NotImplementedError, match="sliding-window"):
        eng.generate(jnp.zeros((2, 4), jnp.int32), 2,
                     prompt_lens=np.array([2, 4], np.int32))

    ssm_cfg = get_smoke_config("mamba2_780m").reduced(d_model=32, n_layers=2)
    ssm_params = init_params(jax.random.PRNGKey(0), ssm_cfg)
    ssm_eng = Engine(ssm_params, ssm_cfg, ServeConfig(max_len=32))
    with pytest.raises(NotImplementedError, match="family"):
        ssm_eng.generate(jnp.zeros((2, 4), jnp.int32), 2,
                         prompt_lens=np.array([2, 4], np.int32))
