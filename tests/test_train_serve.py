"""Training loop convergence, grad-accum equivalence, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")


def test_loss_decreases():
    cfg = dataclasses.replace(_tiny_cfg(), remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    losses = []
    for i in range(40):
        batch = {"tokens": corpus.sample(jnp.asarray(i), 8, 33)}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accum_equivalence():
    """1 step × batch 8 == 1 step × (2 microbatches of 4), same data."""
    cfg = dataclasses.replace(_tiny_cfg(), remat=False)
    params = init_params(jax.random.PRNGKey(1), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    batch = {"tokens": corpus.sample(jnp.asarray(0), 8, 33)}
    opt = init_opt_state(params)

    p1, _, m1 = make_train_step(cfg, TrainConfig())(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, TrainConfig(grad_accum=2))(params, opt, batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-5


def test_engine_generate_greedy_deterministic():
    cfg = dataclasses.replace(_tiny_cfg(), remat=False)
    params = init_params(jax.random.PRNGKey(2), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 0, cfg.vocab_size)
    out1 = eng.generate(prompts, n_steps=6)
    out2 = eng.generate(prompts, n_steps=6)
    assert out1.shape == (2, 6)
    assert jnp.all(out1 == out2)


def test_engine_matches_manual_decode():
    from repro.models import forward, init_caches
    cfg = dataclasses.replace(_tiny_cfg(), remat=False)
    params = init_params(jax.random.PRNGKey(4), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, cfg.vocab_size)
    gen = eng.generate(prompts, n_steps=3)
    # manual: full forward on [prompt + generated[:-1]] reproduces argmaxes
    seq = jnp.concatenate([prompts, gen[:, :-1]], axis=1)
    logits, _, _ = forward(params, cfg, seq)
    expect = jnp.argmax(logits[:, prompts.shape[1] - 1:], axis=-1)
    assert jnp.all(expect == gen)
