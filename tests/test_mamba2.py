"""SSD chunked scan vs naive sequential recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked


def naive_ssm(x, dt, a_log, b_mat, c_mat, d_skip, init_state=None):
    """Direct recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_tᵀ; y=C h."""
    bsz, l, nh, hd = x.shape
    ng, ds = b_mat.shape[2], b_mat.shape[3]
    rep = nh // ng
    a = -np.exp(np.asarray(a_log, np.float64))
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    b_mat = np.repeat(np.asarray(b_mat, np.float64), rep, axis=2)
    c_mat = np.repeat(np.asarray(c_mat, np.float64), rep, axis=2)
    h = np.zeros((bsz, nh, hd, ds)) if init_state is None \
        else np.asarray(init_state, np.float64)
    ys = []
    for t in range(l):
        decay = np.exp(dt[:, t] * a[None, :])                  # [b, nh]
        upd = np.einsum("bhp,bhd,bh->bhpd", x[:, t], b_mat[:, t], dt[:, t])
        h = h * decay[:, :, None, None] + upd
        y = np.einsum("bhpd,bhd->bhp", h, c_mat[:, t])
        ys.append(y)
    y = np.stack(ys, axis=1) + np.asarray(d_skip)[None, None, :, None] * x
    return y, h


@pytest.mark.parametrize("l,chunk,nh,hd,ds,ng", [
    (32, 8, 4, 16, 8, 1), (64, 16, 8, 8, 16, 1), (48, 12, 4, 16, 8, 2),
    (16, 16, 2, 8, 4, 1),
])
def test_ssd_chunked_vs_naive(rng, l, chunk, nh, hd, ds, ng):
    bsz = 2
    x = jnp.asarray(rng.normal(size=(bsz, l, nh, hd)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bsz, l, nh)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(nh,)).astype(np.float32))
    b_mat = jnp.asarray(rng.normal(size=(bsz, l, ng, ds)).astype(np.float32))
    c_mat = jnp.asarray(rng.normal(size=(bsz, l, ng, ds)).astype(np.float32))
    d_skip = jnp.asarray(rng.normal(size=(nh,)).astype(np.float32))

    y, state = ssd_chunked(x, dt, a_log, b_mat, c_mat, d_skip, chunk)
    y_ref, state_ref = naive_ssm(x, dt, a_log, b_mat, c_mat, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-3, atol=2e-3)


def test_ssd_initial_state_continuation(rng):
    """Running [first half] then [second half with carried state] == full."""
    bsz, l, nh, hd, ds, ng, chunk = 1, 32, 4, 8, 8, 1, 8
    x = jnp.asarray(rng.normal(size=(bsz, l, nh, hd)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bsz, l, nh)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(nh,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(bsz, l, ng, ds)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(bsz, l, ng, ds)).astype(np.float32))
    d = jnp.zeros((nh,), jnp.float32)

    y_full, s_full = ssd_chunked(x, dt, a_log, b, c, d, chunk)
    h = l // 2
    y1, s1 = ssd_chunked(x[:, :h], dt[:, :h], a_log, b[:, :h], c[:, :h], d, chunk)
    y2, s2 = ssd_chunked(x[:, h:], dt[:, h:], a_log, b[:, h:], c[:, h:], d,
                         chunk, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)
